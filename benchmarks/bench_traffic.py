"""Workload-DSE benchmark: per-workload/per-length compile farm vs ONE
compiled executable, plus the streaming-session throughput.

Traffic-driven studies (the whole point of ReSiPI, §4) sweep *workloads*:
different applications, different synthetic patterns, different trace
lengths. Without the workload-polymorphic engine every distinct trace
length is its own jit executable and every workload its own call — a
compile farm. This benchmark times a mixed PARSEC + synthetic workload set
(five distinct trace lengths) three ways:

  * compile farm    — one `simulate` per workload (caches cleared first):
                      every distinct T pays trace + compile + run.
  * workload cold   — the whole set as ONE `sweep_workload` executable
                      (time-padded under t_mask), including its single
                      compilation.
  * workload warm   — the same call re-keyed against a hot cache: the
                      steady-state workload-DSE cost.

Also measures the ragged `simulate_batch` path against its per-length farm
and the `SimSession` streaming path (chunked, donated carry) against the
one-shot run. Results land in benchmarks/results/BENCH_traffic.json with
an appended `history` entry per run.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import traffic
from repro.core.simulator import (Arch, SimConfig, SimSession,
                                  clear_engine_caches, engine_stats,
                                  reset_engine_stats, simulate,
                                  simulate_batch, sweep_workload)
from benchmarks.common import save_json_history, timed_s, warm_median

# Mixed workload set: calibrated apps + canonical synthetics, five distinct
# trace lengths so the farm pays five distinct-shape compiles.
WORKLOADS = (
    traffic.ParsecSpec(app="blackscholes", n_intervals=48),
    traffic.ParsecSpec(app="dedup", n_intervals=64),
    traffic.ParsecSpec(app="facesim", n_intervals=32),
    traffic.UniformSpec(n_intervals=40),
    traffic.HotspotSpec(n_intervals=48),
    traffic.PermutationSpec(pattern="transpose", n_intervals=56),
    traffic.PermutationSpec(pattern="tornado", n_intervals=40),
    traffic.BurstySpec(n_intervals=64),
)


def run(seed: int = 11, chunk: int = 16, stream_chunks: int = 24) -> dict:
    base = SimConfig().with_arch(Arch.RESIPI)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(WORKLOADS))
    traces = [traffic.generate(s, k) for s, k in zip(WORKLOADS, keys)]
    n_lengths = len({tr["ext_load"].shape[0] for tr in traces})

    # -- per-workload compile farm (one executable per distinct T) ----------
    clear_engine_caches()
    farm_s = timed_s(lambda: [simulate(tr, base)["summary"]["mean_latency"]
                              for tr in traces])

    # -- workload engine: cold (single compile) then warm re-keyed ----------
    clear_engine_caches()
    reset_engine_stats()
    sweep = lambda s: sweep_workload(list(WORKLOADS), base, seed=s)[
        "summary"]["mean_latency"]
    workload_cold_s = timed_s(lambda: sweep(seed))
    scan_body_traces = engine_stats()["simulate_traces"]
    workload_warm_s = warm_median(lambda: sweep(seed + 1))

    # -- ragged batch vs its per-length farm --------------------------------
    clear_engine_caches()
    ragged_farm_s = timed_s(
        lambda: [simulate(tr, base)["summary"]["mean_latency"]
                 for tr in traces])
    clear_engine_caches()
    ragged = lambda: simulate_batch(traces, base)["summary"]["mean_latency"]
    ragged_cold_s = timed_s(ragged)
    ragged_warm_s = warm_median(ragged)

    # -- streaming session: chunked one-pass vs one-shot --------------------
    stream_spec = traffic.ParsecSpec(app="dedup",
                                     n_intervals=chunk * stream_chunks)
    stream_tr = traffic.generate(stream_spec, jax.random.PRNGKey(seed))
    chunks = list(traffic.chunk_trace(stream_tr, chunk))

    def stream():
        session = SimSession.init(base)
        for ch in chunks:
            session.step_chunk(ch)
        return session.summary()["mean_latency"]

    oneshot = lambda: simulate(stream_tr, base)["summary"]["mean_latency"]
    stream();  oneshot()                       # warm both paths
    stream_warm_s = warm_median(stream)
    oneshot_warm_s = warm_median(oneshot)
    drift = abs(float(np.asarray(stream())) - float(np.asarray(oneshot())))

    t_max = max(s.n_intervals for s in WORKLOADS)
    result = {
        "backend": jax.default_backend(),
        "n_workloads": len(WORKLOADS),
        "n_distinct_lengths": n_lengths,
        "t_max": t_max,
        "workloads": [s.name for s in WORKLOADS],
        "scan_body_traces": scan_body_traces,
        "farm_s": farm_s,
        "workload_cold_s": workload_cold_s,
        "workload_warm_s": workload_warm_s,
        "speedup_cold": farm_s / workload_cold_s,
        "speedup_warm": farm_s / workload_warm_s,
        "warm_intervals_per_sec": sum(s.n_intervals for s in WORKLOADS)
                                  / workload_warm_s,
        "ragged_farm_s": ragged_farm_s,
        "ragged_cold_s": ragged_cold_s,
        "ragged_warm_s": ragged_warm_s,
        "ragged_speedup_warm": ragged_farm_s / ragged_warm_s,
        "stream_chunk": chunk,
        "stream_intervals": chunk * stream_chunks,
        "stream_warm_s": stream_warm_s,
        "oneshot_warm_s": oneshot_warm_s,
        "stream_intervals_per_sec": chunk * stream_chunks / stream_warm_s,
        "stream_vs_oneshot_drift": drift,
    }
    save_json_history("BENCH_traffic.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"workload DSE ({r['n_workloads']} workloads, "
          f"{r['n_distinct_lengths']} trace lengths): compile farm "
          f"{r['farm_s']:.2f}s -> one padded executable cold "
          f"{r['workload_cold_s']:.2f}s ({r['speedup_cold']:.1f}x), warm "
          f"{r['workload_warm_s']:.3f}s ({r['speedup_warm']:.0f}x); "
          f"{r['scan_body_traces']} scan-body trace(s)")
    print(f"ragged batch: farm {r['ragged_farm_s']:.2f}s -> warm "
          f"{r['ragged_warm_s']:.3f}s ({r['ragged_speedup_warm']:.0f}x)")
    print(f"streaming: {r['stream_intervals']} intervals in chunks of "
          f"{r['stream_chunk']} at {r['stream_intervals_per_sec']:.0f} "
          f"intervals/s (one-shot {r['oneshot_warm_s']:.3f}s, drift "
          f"{r['stream_vs_oneshot_drift']:.2e})")
