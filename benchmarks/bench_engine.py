"""Engine benchmark: cold-compile vs warm-call vs batched throughput.

Times the Fig. 10 design-space exploration three ways:

  * seed loop   — `simulate_eager`: the pre-engine path that rebuilds the
                  selection tables and re-traces the scan on every one of the
                  app x gateway-count calls (8 x 4 = 32 calls).
  * engine cold — `sweep_batch`: the whole apps x gateway-counts grid as
                  ONE compiled call, including jit compilation (caches
                  cleared first).
  * engine warm — the same call against a hot compile cache: the
                  steady-state cost of every subsequent DSE.

plus single-call jit latency and a 64-point `sweep` over L_m. Results land
in benchmarks/results/BENCH_engine.json so later PRs have a perf trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import traffic
from repro.core.simulator import (clear_engine_caches, simulate,
                                  simulate_eager, stack_traces, sweep)
from benchmarks.common import (fixed_gateway_config, save_json_history,
                               timed_s, warm_median)
from benchmarks.fig10_lm_dse import GATEWAY_COUNTS, dse_grid


def _dse_seed_loop(traces: dict) -> float:
    def go():
        outs = []
        for tr in traces.values():
            for g in GATEWAY_COUNTS:
                outs.append(simulate_eager(tr, fixed_gateway_config(g))
                            ["summary"]["mean_latency"])
        return outs
    return timed_s(go)


def _dse_engine(batch: dict) -> float:
    return timed_s(lambda: dse_grid(batch)["summary"]["mean_latency"])


def run(n_intervals: int = 60, seed: int = 7) -> dict:
    traces = traffic.all_app_traces(n_intervals, seed=seed)
    apps = list(traces)
    batch = stack_traces([traces[a] for a in apps])
    n_sims = len(apps) * len(GATEWAY_COUNTS)
    sim0 = fixed_gateway_config(2)
    tr0 = traces[apps[0]]

    # -- seed-parity baseline (per-call retrace loop) -----------------------
    seed_loop_s = _dse_seed_loop(traces)

    # -- engine: cold (compile) then warm (cache hit, median-of-N) ----------
    clear_engine_caches()
    engine_cold_s = _dse_engine(batch)
    engine_warm_s = warm_median(
        lambda: dse_grid(batch)["summary"]["mean_latency"])

    # -- single-call latency ------------------------------------------------
    clear_engine_caches()
    single_cold_s = timed_s(lambda: simulate(tr0, sim0)["summary"])
    single_warm_s = warm_median(
        lambda: simulate(tr0, sim0)["summary"])

    # -- vmapped parameter sweep (64-point L_m grid) ------------------------
    lm_grid = jnp.linspace(0.004, 0.032, 64)
    sweep_cold_s = timed_s(
        lambda: sweep(tr0, sim0, l_m=lm_grid)["summary"]["mean_latency"])
    sweep_warm_s = warm_median(
        lambda: sweep(tr0, sim0, l_m=lm_grid)["summary"]["mean_latency"])

    result = {
        "backend": jax.default_backend(),
        "n_intervals": n_intervals,
        "n_apps": len(apps),
        "fig10_dse": {
            "n_simulations": n_sims,
            "seed_loop_s": seed_loop_s,
            "engine_cold_s": engine_cold_s,
            "engine_warm_s": engine_warm_s,
            "speedup_cold": seed_loop_s / engine_cold_s,
            "speedup_warm": seed_loop_s / engine_warm_s,
            "warm_intervals_per_sec": n_sims * n_intervals / engine_warm_s,
        },
        "single_call": {
            "cold_s": single_cold_s,
            "warm_s": single_warm_s,
            "warm_intervals_per_sec": n_intervals / single_warm_s,
        },
        "lm_sweep_64": {
            "cold_s": sweep_cold_s,
            "warm_s": sweep_warm_s,
            "warm_intervals_per_sec": 64 * n_intervals / sweep_warm_s,
        },
    }
    save_json_history("BENCH_engine.json", result)
    return result


if __name__ == "__main__":
    r = run()
    d = r["fig10_dse"]
    print(f"fig10 DSE ({d['n_simulations']} sims): seed loop "
          f"{d['seed_loop_s']:.2f}s -> engine warm {d['engine_warm_s']:.3f}s "
          f"({d['speedup_warm']:.1f}x, cold {d['speedup_cold']:.1f}x); "
          f"{d['warm_intervals_per_sec']:.0f} intervals/s")
