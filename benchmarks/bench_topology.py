"""Topology-DSE benchmark: per-topology compile farm vs ONE padded compile.

The scaling studies ReSiPI-class papers live on (HexaMesh-style
hundreds-of-chiplet scans, PlaceIT-style topology DSE) sweep *shape-changing*
axes: chiplet count, gateways per chiplet, mesh radix. Without padded
batching every topology is its own jit executable — a compile farm. This
benchmark times a 4..64-chiplet x gateway-clamp grid both ways:

  * compile farm — one `simulate` jit per topology (caches cleared first):
                   every grid point pays trace + compile + run.
  * padded cold  — the whole grid as ONE `sweep_topology` executable,
                   including its single compilation.
  * padded warm  — the same call against a hot cache: steady-state DSE cost.

Also measures the sharded path (shard_sweep) on whatever devices exist.
Results land in benchmarks/results/BENCH_topology.json with an appended
`history` entry per run (the cross-PR perf trajectory).
"""
from __future__ import annotations

import jax

from repro.core import traffic
from repro.core.constants import NETWORK
from repro.core.simulator import (Arch, SimConfig, clear_engine_caches,
                                  engine_stats, reset_engine_stats, simulate,
                                  shard_sweep, sweep_topology,
                                  topology_point_config)
from benchmarks.common import save_json_history, timed_s, warm_median

CHIPLET_COUNTS = (4, 8, 9, 16, 25, 36, 49, 64)
GATEWAY_CLAMPS = (2, 4)


def topology_grid():
    """The flattened 16-point (chiplets x gateways) grid, zip-style lists."""
    cs = [c for c in CHIPLET_COUNTS for _ in GATEWAY_CLAMPS]
    gs = [g for _ in CHIPLET_COUNTS for g in GATEWAY_CLAMPS]
    return cs, gs


def _farm(trace: dict, base: SimConfig, cs, gs) -> float:
    """Per-topology compile farm: distinct shapes/configs, one jit each."""
    def go():
        outs = []
        for c, g in zip(cs, gs):
            sim = topology_point_config(base, n_chiplets=c,
                                        gateways_per_chiplet=g)
            outs.append(simulate(traffic.slice_trace(trace, c), sim)
                        ["summary"]["mean_latency"])
        return outs
    return timed_s(go)


def run(n_intervals: int = 40, seed: int = 7) -> dict:
    c_max = max(CHIPLET_COUNTS)
    cfg = NETWORK.with_topology(n_chiplets=c_max)
    trace = traffic.generate_trace("dedup", n_intervals,
                                   jax.random.PRNGKey(seed), cfg)
    cs, gs = topology_grid()
    base = SimConfig().with_arch(Arch.RESIPI)
    n_topo = len(cs)

    # -- compile farm baseline (one executable per topology) ----------------
    clear_engine_caches()
    farm_s = _farm(trace, base, cs, gs)

    # -- padded engine: cold (single compile) then warm ---------------------
    clear_engine_caches()
    reset_engine_stats()
    padded = lambda: sweep_topology(trace, base, n_chiplets=cs,
                                    gateways_per_chiplet=gs)[
                                        "summary"]["mean_latency"]
    padded_cold_s = timed_s(padded)
    scan_body_traces = engine_stats()["simulate_traces"]
    padded_warm_s = warm_median(padded)

    # -- sharded path (graceful single-device fallback) ---------------------
    devices = jax.devices()
    shard = lambda: shard_sweep(trace, base, n_chiplets=cs,
                                gateways_per_chiplet=gs)[
                                    "summary"]["mean_latency"]
    shard(); sharded_warm_s = warm_median(shard)

    result = {
        "backend": jax.default_backend(),
        "n_devices": len(devices),
        "n_intervals": n_intervals,
        "n_topologies": n_topo,
        "chiplet_counts": list(CHIPLET_COUNTS),
        "gateway_clamps": list(GATEWAY_CLAMPS),
        "max_chiplets": c_max,
        "scan_body_traces": scan_body_traces,
        "farm_s": farm_s,
        "padded_cold_s": padded_cold_s,
        "padded_warm_s": padded_warm_s,
        "sharded_warm_s": sharded_warm_s,
        "speedup_cold": farm_s / padded_cold_s,
        "speedup_warm": farm_s / padded_warm_s,
        "warm_intervals_per_sec": n_topo * n_intervals / padded_warm_s,
    }
    save_json_history("BENCH_topology.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"topology DSE ({r['n_topologies']} topologies, 4..{r['max_chiplets']}"
          f" chiplets): compile farm {r['farm_s']:.2f}s -> one padded "
          f"executable cold {r['padded_cold_s']:.2f}s "
          f"({r['speedup_cold']:.1f}x), warm {r['padded_warm_s']:.3f}s "
          f"({r['speedup_warm']:.1f}x); {r['scan_body_traces']} scan-body "
          f"trace(s); {r['warm_intervals_per_sec']:.0f} intervals/s")
