"""Fig. 13 reproduction: per-router flit residency maps (one chiplet).

Uses the Pallas flit-level kernel (kernels/noc_step) under dedup-class
traffic: PROWAVES routes everything through one 16-wavelength gateway
(port-bound), ReSiPI distributes over its active gateways with 4
wavelengths each. The paper shows heavy residency at PROWAVES' gateway
router spreading back-pressure across the mesh, vs a flat ReSiPI map.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.noc_step.ops import simulate_residency
from benchmarks.common import save_json


def run(load: float = 0.10, cycles: int = 8192, seed: int = 5) -> dict:
    pro, pro_drained = simulate_residency(load, g_active=1, wavelengths=16,
                                          cycles=cycles, seed=seed)
    res, res_drained = simulate_residency(load, g_active=2, wavelengths=4,
                                          cycles=cycles, seed=seed)
    result = {
        "prowaves_residency": pro.tolist(),
        "resipi_residency": res.tolist(),
        "prowaves_max": float(pro.max()),
        "prowaves_mean": float(pro.mean()),
        "resipi_max": float(res.max()),
        "resipi_mean": float(res.mean()),
        "max_ratio_pro_over_resipi": float(pro.max() / max(res.max(), 1e-9)),
        "drained": {"prowaves": pro_drained, "resipi": res_drained},
        "note": ("paper Fig. 13 shows the G-router residency in PROWAVES "
                 "far above every ReSiPI router; ratio > 1 reproduces the "
                 "congestion-distribution claim"),
    }
    save_json("fig13.json", result)
    return result


def _render(m: np.ndarray) -> str:
    return "\n".join("  " + " ".join(f"{v:6.2f}" for v in row)
                     for row in m)


if __name__ == "__main__":
    r = run()
    print("PROWAVES residency (flits, 4x4 mesh):")
    print(_render(np.array(r["prowaves_residency"])))
    print("ReSiPI residency:")
    print(_render(np.array(r["resipi_residency"])))
    print(f"max residency ratio PROWAVES/ReSiPI: "
          f"{r['max_ratio_pro_over_resipi']:.2f}x")
