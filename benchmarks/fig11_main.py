"""Fig. 11 reproduction: latency / power / energy, 8 PARSEC apps x 4
architectures (ReSiPI, ReSiPI-all-gateways, PROWAVES, AWGR).

The paper's headline claims vs PROWAVES (best prior): -37% latency,
-25% power, -53% energy on average. This benchmark reports our per-app
numbers and the measured average deltas.
"""
from __future__ import annotations

import numpy as np

from repro.core import traffic
from repro.core.simulator import SimConfig, simulate_all_archs
from benchmarks.common import save_json


def run(n_intervals: int = 100, seed: int = 1) -> dict:
    rows = {}
    for app in traffic.APP_NAMES:
        import jax
        tr = traffic.generate_trace(app, n_intervals,
                                    jax.random.PRNGKey(seed))
        out = simulate_all_archs(tr)
        rows[app] = {a: {k: float(v) for k, v in s.items()}
                     for a, s in out.items()}

    def delta(metric):
        return float(np.mean([1 - rows[a]["resipi"][metric]
                              / rows[a]["prowaves"][metric]
                              for a in rows]))

    summary = {
        "latency_reduction_vs_prowaves": delta("mean_latency"),
        "power_reduction_vs_prowaves": delta("mean_power_mw"),
        "energy_reduction_vs_prowaves": delta("mean_energy"),
        "paper_claims": {"latency": 0.37, "power": 0.25, "energy": 0.53},
        "energy_reduction_vs_resipi_all": float(np.mean(
            [1 - rows[a]["resipi"]["mean_energy"]
             / rows[a]["resipi_all"]["mean_energy"] for a in rows])),
    }
    result = {"per_app": rows, "summary": summary}
    save_json("fig11.json", result)
    return result


if __name__ == "__main__":
    r = run()
    s = r["summary"]
    print(f"vs PROWAVES: latency -{s['latency_reduction_vs_prowaves']:.1%} "
          f"(paper -37%), power -{s['power_reduction_vs_prowaves']:.1%} "
          f"(paper -25%), energy -{s['energy_reduction_vs_prowaves']:.1%} "
          f"(paper -53%)")
