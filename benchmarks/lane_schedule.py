"""Level-2 benchmark: ReSiPI lane controller on training traffic.

Feeds the lane controller (core/reconfig_runtime.py) a synthetic multi-phase
collective-traffic trace — the Level-2 analogue of Fig. 12's application
sequence — and compares against static lane policies. The metric pair is the
paper's: traffic-weighted completion proxy (latency) and lane energy from
the photonic power model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import reconfig_runtime as lanes
from benchmarks.common import save_json


def traffic_trace(steps: int = 600, seed: int = 0) -> np.ndarray:
    """Per-step collective bytes: three phases (dense sync / MoE-heavy /
    light), mirroring blackscholes/facesim/dedup loads."""
    rng = np.random.default_rng(seed)
    phases = [2.0e8, 0.2e8, 0.8e8]
    out = []
    for mean in phases:
        out.append(mean * rng.lognormal(0, 0.4, steps // 3))
    return np.concatenate(out)


def run(epoch_steps: int = 20) -> dict:
    cfg = lanes.LaneConfig()
    trace = traffic_trace()

    def run_policy(policy: str):
        state = lanes.LaneState.init(cfg)
        widths, loads = [], []
        for i, b in enumerate(trace):
            state = lanes.meter_step(state, jnp.float32(b))
            if (i + 1) % epoch_steps == 0:
                if policy == "resipi":
                    state, rec = lanes.epoch_update(state, cfg)
                else:
                    fixed = int(policy)
                    state = lanes.LaneState(
                        lanes=jnp.int32(fixed),
                        bytes_seen=jnp.float32(0.0),
                        steps_seen=jnp.int32(0), epoch=state.epoch + 1)
                widths.append(int(state.lanes))
            loads.append(b / (cfg.lane_bytes_per_step
                              * max(int(state.lanes), 1)))
        widths_arr = jnp.asarray(widths)
        energy = lanes.lane_energy_report(widths_arr, cfg)
        # completion proxy: per-step time grows superlinearly past the knee
        rho = np.clip(np.asarray(loads), 0, 3.0)
        t = 1.0 + rho + 2.0 * np.square(np.clip(rho - cfg.l_m, 0, None))
        return {"mean_lanes": float(energy["mean_lanes"]),
                "power_mw": float(energy["mean_power_mw"]),
                "reconfig_nj": float(energy["reconfig_nj"]),
                "mean_step_time": float(np.mean(t))}

    out = {p: run_policy(p) for p in ("resipi", "1", "4")}
    out["note"] = ("resipi should match lane-4 latency within ~10% at "
                   "materially lower power, and beat lane-1 latency "
                   "outright — the Fig. 11 trade-off at Level 2")
    save_json("lane_schedule.json", out)
    return out


if __name__ == "__main__":
    r = run()
    for k in ("resipi", "1", "4"):
        v = r[k]
        print(f"policy {k:7s}: lanes {v['mean_lanes']:.2f} "
              f"power {v['power_mw']:7.1f} mW "
              f"step-time {v['mean_step_time']:.3f}")
