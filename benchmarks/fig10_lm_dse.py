"""Fig. 10 reproduction: design-space exploration for the optimal L_m.

Runs every PARSEC app at every fixed gateway count g in 1..4, collects
(average gateway load L_c, average latency) points, and applies the paper's
selection rule: accept up to 10% latency overhead relative to the best
same-g point, then L_m = max accepted L_c (§4.2; the paper lands on 0.0152).
"""
from __future__ import annotations

import jax

from repro.core import traffic
from benchmarks.common import fixed_gateway_config, save_json
from repro.core.simulator import simulate


def run(n_intervals: int = 60, seed: int = 7) -> dict:
    points = []
    traces = traffic.all_app_traces(n_intervals, seed=seed)
    for app, tr in traces.items():
        for g in range(1, 5):
            out = simulate(tr, fixed_gateway_config(g))["summary"]
            lc = float(out["mean_latency"])
            # mean per-gateway load over the run
            load = float(jax.numpy.mean(
                jax.numpy.stack(tr["ext_load"])) / g)
            points.append({"app": app, "g": g, "load": load,
                           "latency": lc})

    # paper's rule: within each g, find min latency; accept points with
    # <= 10% overhead; L_m = max load among accepted points.
    accepted = []
    for g in range(1, 5):
        pg = [p for p in points if p["g"] == g]
        best = min(p["latency"] for p in pg)
        accepted += [p for p in pg if p["latency"] <= 1.1 * best]
    l_m = max(p["load"] for p in accepted)
    result = {"points": points, "l_m_selected": l_m,
              "l_m_paper": 0.0152,
              "n_accepted": len(accepted)}
    save_json("fig10.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"L_m selected: {r['l_m_selected']:.4f} (paper: 0.0152), "
          f"{r['n_accepted']} points in the 10% band")
