"""Fig. 10 reproduction: design-space exploration for the optimal L_m.

Runs every PARSEC app at every fixed gateway count g in 1..4, collects
(average gateway load L_c, average latency) points, and applies the paper's
selection rule: accept up to 10% latency overhead relative to the best
same-g point, then L_m = max accepted L_c (§4.2; the paper lands on 0.0152).

Engine path: the 8 apps share a trace shape and the fixed gateway counts
are runtime controller clamps, so the whole app x g grid is ONE compiled
`sweep_batch` call (vmap over apps x vmap over g) replacing the seed's 32
re-traced ones (timed by benchmarks/bench_engine.py). Per-gateway load comes
straight from the simulator's `gw_load` records (Eq. 5 numerator/denominator
as actually simulated), not recomputed from the raw trace.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import traffic
from benchmarks.common import save_json
from repro.core.simulator import Arch, SimConfig, stack_traces, sweep_batch

GATEWAY_COUNTS = (1, 2, 3, 4)


def dse_grid(batch: dict, base: SimConfig = None) -> dict:
    """The full Fig. 10 grid in one compiled call: [n_apps, n_g] results."""
    base = base or SimConfig().with_arch(Arch.RESIPI)
    gs = jnp.asarray(GATEWAY_COUNTS)
    return sweep_batch(batch, base, max_gateways=gs, min_gateways=gs)


def run(n_intervals: int = 60, seed: int = 7) -> dict:
    traces = traffic.all_app_traces(n_intervals, seed=seed)
    apps = list(traces)
    batch = stack_traces([traces[a] for a in apps])

    out = dse_grid(batch)
    lat = out["summary"]["mean_latency"]                       # [N, G]
    # mean per-gateway load over the run: gw_load records are [N, G, T, C]
    load = jnp.mean(out["records"]["gw_load"], axis=(2, 3))    # [N, G]
    points = []
    for gi, g in enumerate(GATEWAY_COUNTS):
        for i, app in enumerate(apps):
            points.append({"app": app, "g": g,
                           "load": float(load[i, gi]),
                           "latency": float(lat[i, gi])})

    # paper's rule: within each g, find min latency; accept points with
    # <= 10% overhead; L_m = max load among accepted points.
    accepted = []
    for g in range(1, 5):
        pg = [p for p in points if p["g"] == g]
        best = min(p["latency"] for p in pg)
        accepted += [p for p in pg if p["latency"] <= 1.1 * best]
    l_m = max(p["load"] for p in accepted)
    result = {"points": points, "l_m_selected": l_m,
              "l_m_paper": 0.0152,
              "n_accepted": len(accepted)}
    save_json("fig10.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"L_m selected: {r['l_m_selected']:.4f} (paper: 0.0152), "
          f"{r['n_accepted']} points in the 10% band")
