"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Figures 10-13 + Table 2 are
the paper artifacts; roofline + lane_schedule are the framework-level
additions (EXPERIMENTS.md indexes them).
"""
from __future__ import annotations

import sys
import time


def r_traces(r):
    return (f"{r['scan_body_traces']} trace, "
            f"{r['search_dispatches']} dispatch")


def _run(name, fn, derived_fn):
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derived_fn(out)}", flush=True)
    return out


def main() -> None:
    from benchmarks import (bench_distributed, bench_engine, bench_faults,
                            bench_kernels, bench_pareto, bench_placement,
                            bench_search, bench_serve, bench_topology,
                            bench_traffic,
                            fig10_lm_dse, fig11_main, fig12_adaptivity,
                            fig13_residency, table2_overhead, lane_schedule)

    print("name,us_per_call,derived")
    eng = _run("bench_engine", bench_engine.run,
               lambda r: (f"warm_speedup="
                          f"{r['fig10_dse']['speedup_warm']:.0f}x,"
                          f"{r['fig10_dse']['warm_intervals_per_sec']:.0f}"
                          f"intervals/s"))
    d = eng["fig10_dse"]
    print(f"# engine: fig10 DSE warm-call {d['speedup_warm']:.0f}x faster "
          f"than the unbatched per-call loop "
          f"({d['seed_loop_s']:.2f}s -> {d['engine_warm_s']:.3f}s)",
          flush=True)
    topo = _run("bench_topology", bench_topology.run,
                lambda r: (f"cold_speedup={r['speedup_cold']:.1f}x,"
                           f"{r['scan_body_traces']}trace/"
                           f"{r['n_topologies']}topologies"))
    print(f"# topology: {topo['n_topologies']}-point 4..{topo['max_chiplets']}"
          f"-chiplet DSE is ONE padded executable "
          f"({topo['scan_body_traces']} scan-body trace): compile farm "
          f"{topo['farm_s']:.2f}s -> cold {topo['padded_cold_s']:.2f}s "
          f"({topo['speedup_cold']:.1f}x), warm {topo['padded_warm_s']:.3f}s",
          flush=True)
    plc = _run("bench_placement", bench_placement.run,
               lambda r: (f"gen_per_s={r['generations_per_sec_warm']:.0f},"
                          f"inter_lat{r['inter_latency_delta_frac']:+.1%}"
                          f"vs_default"))
    print(f"# placement: {plc['generations']}x{plc['population']}-candidate "
          f"search is ONE executable ({plc['scan_body_traces']} scan-body "
          f"trace): warm {plc['search_warm_s']:.3f}s "
          f"({plc['speedup_warm_vs_farm']:.0f}x vs per-placement compiles); "
          f"best placement {plc['inter_latency_delta_frac']:+.1%} "
          f"inter-chiplet latency vs default edges", flush=True)
    sea = _run("bench_search", bench_search.run,
               lambda r: (f"device="
                          f"{r['speedup_device_vs_pr3_recorded']:.1f}"
                          f"x_vs_pr3,meets_10x={r['meets_10x']}"))
    print(f"# search: whole annealed search is ONE dispatch "
          f"({r_traces(sea)}): PR-3 recorded "
          f"{sea['pr3_recorded_evals_per_sec']:.0f} -> host+fix "
          f"{sea['host_evals_per_sec']:.0f} -> device "
          f"{sea['device_evals_per_sec']:.0f} evals/s "
          f"({sea['speedup_device_vs_pr3_recorded']:.1f}x vs PR-3); "
          f"{sea['islands']} islands "
          f"{sea['islands_evals_per_sec']:.0f} evals/s", flush=True)
    par = _run("bench_pareto", bench_pareto.run,
               lambda r: (f"speedup="
                          f"{r['speedup_codesign_vs_sequential']:.1f}x,"
                          f"meets_5x={r['meets_5x']},"
                          f"front={r['front_size']}"))
    print(f"# pareto: {par['n_topologies']} topologies x {par['islands']} "
          f"islands x {par['workloads']} workloads joint co-design is ONE "
          f"dispatch ({r_traces(par)}): sequential loop "
          f"{par['seq_evals_per_sec']:.0f} -> codesign "
          f"{par['codesign_evals_per_sec']:.0f} candidate-evals/s "
          f"({par['speedup_codesign_vs_sequential']:.1f}x); front "
          f"{par['front_size']} points, hypervolume "
          f"{par['hypervolume']:.3g}", flush=True)
    tra = _run("bench_traffic", bench_traffic.run,
               lambda r: (f"warm_speedup={r['speedup_warm']:.0f}x,"
                          f"{r['scan_body_traces']}trace/"
                          f"{r['n_workloads']}workloads"))
    print(f"# traffic: {tra['n_workloads']}-workload mixed-length DSE is ONE "
          f"padded executable ({tra['scan_body_traces']} scan-body trace): "
          f"compile farm {tra['farm_s']:.2f}s -> warm "
          f"{tra['workload_warm_s']:.3f}s ({tra['speedup_warm']:.0f}x); "
          f"streaming {tra['stream_intervals_per_sec']:.0f} intervals/s in "
          f"chunks of {tra['stream_chunk']}", flush=True)
    _run("fig10_lm_dse", fig10_lm_dse.run,
         lambda r: f"L_m={r['l_m_selected']:.4f}(paper 0.0152)")
    _run("fig11_main", fig11_main.run,
         lambda r: (f"lat-{r['summary']['latency_reduction_vs_prowaves']:.0%}"
                    f"/pow-{r['summary']['power_reduction_vs_prowaves']:.0%}"
                    f"/en-{r['summary']['energy_reduction_vs_prowaves']:.0%}"
                    f"(paper 37/25/53)"))
    _run("fig12_adaptivity", fig12_adaptivity.run,
         lambda r: (f"settle={r['adaptation']['resipi_settle'][0]}"
                    f"intervals(paper~3),maxGW={r['max_gateways_used']}"))
    _run("fig13_residency", fig13_residency.run,
         lambda r: (f"residency_ratio="
                    f"{r['max_ratio_pro_over_resipi']:.2f}x"))
    _run("table2_overhead", table2_overhead.run,
         lambda r: (f"ctl_power={r['model']['total_power_uw']:.0f}uW"
                    f"(paper 959uW)"))
    _run("lane_schedule", lane_schedule.run,
         lambda r: (f"lanes={r['resipi']['mean_lanes']:.2f},"
                    f"power={r['resipi']['power_mw']:.0f}mW"))
    def _faults_derived(r):
        c = r["closed_loop"]
        return (f"detect={c['detection_latency_chunks']}chunk,"
                f"avail={c['availability']:.0%},"
                f"recovered={r['recovered_within_band']}")

    flt = _run("bench_faults", bench_faults.run, _faults_derived)
    c = flt["closed_loop"]
    print(f"# faults: storm detected+healed in "
          f"{c['detection_latency_chunks']} chunk(s), recovered in "
          f"{c['recovery_time_chunks']} (availability "
          f"{c['availability']:.0%}); PCM bill {c['total_pcm_nj']:.0f} nJ, "
          f"fault-path warm overhead "
          f"{flt['engine']['fault_overhead_frac']:+.1%}", flush=True)

    def _kernels_derived(r):
        s = r["single"]
        return (f"mode={r['kernel_mode']},"
                f"scan={s['scan_body']['warm_intervals_per_sec']:.0f}i/s,"
                f"fused={s['fused_kernel']['warm_intervals_per_sec']:.0f}"
                f"i/s")

    ker = _run("bench_kernels", bench_kernels.run, _kernels_derived)
    ks = ker["single"]
    print(f"# kernels: epoch_step [{ker['kernel_mode']}/{ker['backend']}] "
          f"warm scan body "
          f"{ks['scan_body']['warm_intervals_per_sec']:.0f} -> fused "
          f"{ks['fused_kernel']['warm_intervals_per_sec']:.0f} intervals/s "
          f"(ratio {ks['warm_ratio_kernel_over_scan']:.2f}x; interpret "
          f"mode is the correctness regime, compiled numbers need a TPU)",
          flush=True)

    def _serve_derived(r):
        n, o, s = r["nominal"], r["overload"], r["storm"]
        return (f"{n['sessions_per_s']:.1f}sess/s,"
                f"bounded={o['queue_bounded']},"
                f"storm_recovered={s['recovered_within_band']}")

    def _dist_derived(r):
        s, c = r["scaling"], r["cold_vs_warm"]
        worst = max(e["warm_over_cold"] for e in c["entries"].values())
        return (f"scale_2w={s['ratio_2v1']:.2f}x,"
                f"parity={r['distributed_2proc']['parity']},"
                f"warm_frac={worst:.0%}")

    dst = _run("bench_distributed", bench_distributed.run, _dist_derived)
    ds, dp, dc = (dst["scaling"], dst["distributed_2proc"],
                  dst["cold_vs_warm"])
    print(f"# distributed: {ds['grid_points']}-point fleet grid "
          f"[{ds['mode']}] 1w "
          f"{ds['workers']['1']['aggregate_points_per_sec']:.0f} -> 2w "
          f"{ds['workers']['2']['aggregate_points_per_sec']:.0f} points/s "
          f"({ds['ratio_2v1']:.2f}x); real 2-proc mesh parity={dp['parity']}"
          f"; AOT cache-warm first dispatch "
          f"{max(e['warm_over_cold'] for e in dc['entries'].values()):.0%} "
          f"of cold", flush=True)

    srv = _run("bench_serve", bench_serve.run, _serve_derived)
    n, o, s = srv["nominal"], srv["overload"], srv["storm"]
    print(f"# serve: nominal {n['sessions_per_s']:.1f} sessions/s "
          f"({n['intervals_per_s']:.0f} intervals/s, p50 "
          f"{n['p50_chunk_s'] * 1e3:.1f}ms, {n['scan_body_traces']} "
          f"scan-body trace); overload shed "
          f"{o['shed_queue_full'] + o['shed_priority']} of "
          f"{o['submitted']} bounded={o['queue_bounded']}; storm healed "
          f"tick {s['heal_tick']} availability {s['availability']:.0%} "
          f"dropped {s['healthy_dropped']} healthy; replay parity "
          f"{n['parity_clean'] and o['parity_clean'] and s['parity_clean']}",
          flush=True)


if __name__ == "__main__":
    main()
