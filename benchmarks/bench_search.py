"""Search-engine benchmark: device-resident vs host-loop placement search.

ReSiPI's run-time reconfiguration story makes placement search a
serving-path workload: the searcher must keep up with observed traffic, not
run overnight. The PR-3 host loop (retained as `engine="host"`) proposes
candidates in numpy and pays one dispatch plus host syncs per generation;
the PR-5 device engine (`repro.core.search`) runs the whole annealed search
— proposals, traceable placement tables, scoring, annealed acceptance,
elitist history — inside ONE compiled `lax.scan`: a search is a single
dispatch, and K island chains share that executable.

Measured on the paper's Table 1 system at the SAME configuration as the
recorded PR-3 baseline (BENCH_placement.json history: 8 generations x 12
candidates on a 32-interval dedup trace), so every number below is directly
comparable with the PR-3 trajectory:

  * host warm        — `engine="host"` steady-state search (median of N):
                       the PR-3 loop *after* the PR-5 one-`device_get`
                       sync fix.
  * device cold/warm — the one-dispatch search, compile included/excluded.
  * islands warm     — ISLANDS independent chains in one dispatch (the
                       throughput configuration for parallel restarts).
  * acceptance       — warm device candidate-evals/sec >= 10x the recorded
                       PR-3 host loop's (`speedup_device_vs_pr3_recorded`;
                       `scan_body_traces == 1` and `search_dispatches == 1`
                       prove the one-dispatch / zero-roundtrip claim).

Results land in benchmarks/results/BENCH_search.json with an appended
`history` entry per run (the cross-PR perf trajectory).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import traffic
from repro.core.simulator import (Arch, SimConfig, clear_engine_caches,
                                  engine_stats, reset_engine_stats,
                                  search_placement, search_placement_islands)
from benchmarks.common import (save_json_history, timed_result_s, timed_s,
                               warm_median)

# Same knobs as bench_placement.py, whose history holds the PR-3 numbers.
GENERATIONS = 8
POPULATION = 12
ISLANDS = 8

# The PR-3 host loop as recorded in BENCH_placement.json history
# (2026-07-31T14:50:14, the last pre-device-engine entry: 117.9
# generations/sec warm -> 1415 candidate evals/sec at this exact
# generations/population/trace configuration). Pinned here so the
# acceptance ratio stays anchored to the PR-3 engine after later runs
# append device-engine entries to that file. Like every BENCH speedup in
# this repo's history the number is machine-bound (the container the
# BENCH trajectory comes from); on foreign hardware read the same-run
# `speedup_*_vs_host` ratios instead of `meets_10x`.
PR3_RECORDED_EVALS_PER_SEC = 1415.0


def run(n_intervals: int = 32, seed: int = 3) -> dict:
    trace = traffic.generate_trace("dedup", n_intervals,
                                   jax.random.PRNGKey(seed))
    base = SimConfig().with_arch(Arch.RESIPI)
    evals = GENERATIONS * POPULATION

    host = lambda s: search_placement(
        trace, base, generations=GENERATIONS, population=POPULATION,
        seed=s, engine="host")
    device = lambda s: search_placement(
        trace, base, generations=GENERATIONS, population=POPULATION, seed=s)
    islands = lambda s: search_placement_islands(
        trace, base, islands=ISLANDS, generations=GENERATIONS,
        population=POPULATION, seed=s)

    # -- host loop (PR-3 semantics + the PR-5 one-device_get sync fix) ------
    clear_engine_caches()
    host_cold_s = timed_s(lambda: host(seed))
    host_warm_s = warm_median(lambda: host(seed + 1))

    # -- device-resident engine: one dispatch per search --------------------
    clear_engine_caches()
    reset_engine_stats()
    res, device_cold_s = timed_result_s(lambda: device(seed))
    stats = engine_stats()
    device_warm_s = warm_median(lambda: device(seed + 1))

    # -- island chains: K searches, still one dispatch ----------------------
    res_isl, islands_cold_s = timed_result_s(lambda: islands(seed))
    islands_warm_s = warm_median(lambda: islands(seed + 1))

    host_eps = evals / host_warm_s
    device_eps = evals / device_warm_s
    islands_eps = ISLANDS * evals / islands_warm_s
    result = {
        "backend": jax.default_backend(),
        "n_intervals": n_intervals,
        "generations": GENERATIONS,
        "population": POPULATION,
        "islands": ISLANDS,
        "scan_body_traces": stats["simulate_traces"],
        "search_dispatches": stats["search_dispatches"],
        "pr3_recorded_evals_per_sec": PR3_RECORDED_EVALS_PER_SEC,
        "host_cold_s": host_cold_s,
        "host_warm_s": host_warm_s,
        "host_evals_per_sec": host_eps,
        "device_cold_s": device_cold_s,
        "device_warm_s": device_warm_s,
        "device_evals_per_sec": device_eps,
        "islands_cold_s": islands_cold_s,
        "islands_warm_s": islands_warm_s,
        "islands_evals_per_sec": islands_eps,
        "sync_fix_speedup_host_vs_pr3":
            host_eps / PR3_RECORDED_EVALS_PER_SEC,
        "speedup_device_vs_pr3_recorded":
            device_eps / PR3_RECORDED_EVALS_PER_SEC,
        "speedup_islands_vs_pr3_recorded":
            islands_eps / PR3_RECORDED_EVALS_PER_SEC,
        "speedup_device_vs_host": device_eps / host_eps,
        "speedup_islands_vs_host": islands_eps / host_eps,
        "meets_10x": bool(device_eps >= 10 * PR3_RECORDED_EVALS_PER_SEC),
        "best_score": res["best_score"],
        "default_score": res["default_score"],
        "improvement_frac": res["improvement_frac"],
        "islands_best_score": res_isl["best_score"],
        "islands_improvement_frac": res_isl["improvement_frac"],
    }
    save_json_history("BENCH_search.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"placement search ({r['generations']}x{r['population']} "
          f"candidate evals): PR-3 recorded "
          f"{r['pr3_recorded_evals_per_sec']:.0f} evals/s -> host+sync-fix "
          f"{r['host_warm_s']:.3f}s ({r['host_evals_per_sec']:.0f} evals/s) "
          f"-> device {r['device_warm_s']:.4f}s "
          f"({r['device_evals_per_sec']:.0f} evals/s, "
          f"{r['speedup_device_vs_pr3_recorded']:.1f}x vs PR-3, "
          f"{r['speedup_device_vs_host']:.1f}x vs host, "
          f"{r['scan_body_traces']} trace / {r['search_dispatches']} "
          f"dispatch); {r['islands']} islands {r['islands_warm_s']:.3f}s "
          f"({r['islands_evals_per_sec']:.0f} evals/s, "
          f"{r['speedup_islands_vs_pr3_recorded']:.1f}x vs PR-3, "
          f"{r['speedup_islands_vs_host']:.1f}x vs host); best vs default "
          f"{-r['islands_improvement_frac']:+.1%} inter-chiplet latency; "
          f"meets_10x={r['meets_10x']}")
