"""Fault-injection + closed-loop self-healing benchmark.

Two halves, mirroring the fault subsystem's two layers:

  * engine overhead — the fault-frame path (`simulate` with attached
    frames, `sweep_faults` grids) against the clean path on the same
    trace: the frames ride the same masked scan, so the warm overhead
    should be a few percent, and a K-frame fault grid should cost one
    vmapped call, not K.
  * closed loop — a fault storm kills the routers under half the live
    gateways mid-stream; the `ResilienceRuntime` detects the breach from
    chunk telemetry, re-places gateways off the dead routers (blocked
    device search), swaps the placement live, and pays the PCM bill.
    Reported: detection latency (chunks from onset to the heal firing),
    recovery time (chunks from onset back under the 10% band), availability
    (fraction of chunks inside the band over the whole storm run), and the
    physical recovery cost (PCM nJ, stall cycles, post-heal power delta).

Results land in benchmarks/results/BENCH_faults.json with an appended
`history` entry per run (commit-stamped).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, traffic
from repro.core.simulator import (SimSession, clear_engine_caches,
                                  engine_stats, reset_engine_stats,
                                  simulate, sweep_faults)
from repro.serve.resilience import ResiliencePolicy, ResilienceRuntime
from benchmarks.common import (fixed_gateway_config, save_json_history,
                               timed_s, warm_median)

CHUNK = 8
T_TOTAL = 64
STORM_T0 = 32
BAND = 0.10              # the acceptance band: within 10% of pre-fault


def _trace(seed: int, t: int = T_TOTAL) -> dict:
    # x2 load so losing gateways is a real capacity loss (see
    # tests/test_resilience.py calibration note).
    tr = traffic.generate_trace("dedup", t, jax.random.PRNGKey(seed))
    for k in ("ext_load", "mem_load", "int_load"):
        tr[k] = jnp.asarray(tr[k]) * 2.0
    return tr


def _engine_overhead(sim, tr) -> dict:
    """Warm fault-path cost vs the clean path on identical traffic."""
    clean_frame = faults.no_faults(sim.cfg, T_TOTAL)
    grid = [clean_frame,
            faults.compile_faults([faults.GatewayFault(start=8, chiplet=0,
                                                       slot=0)], sim.cfg,
                                  T_TOTAL),
            faults.compile_faults([faults.LossDrift(start=0,
                                                    db_per_interval=0.2)],
                                  sim.cfg, T_TOTAL),
            faults.compile_faults([faults.LinkFlap(start=16, chiplet=1,
                                                   p_down=0.5, p_up=0.5)],
                                  sim.cfg, T_TOTAL)]
    attached = faults.attach_faults(tr, clean_frame)

    simulate(tr, sim)                               # warm both paths
    simulate(attached, sim)
    clean_s = warm_median(
        lambda: simulate(tr, sim)["summary"]["mean_latency"])
    fault_s = warm_median(
        lambda: simulate(attached, sim)["summary"]["mean_latency"])

    reset_engine_stats()
    grid_cold_s = timed_s(
        lambda: sweep_faults(tr, sim, grid)["summary"]["mean_latency"])
    grid_traces = engine_stats()["simulate_traces"]
    grid_warm_s = warm_median(
        lambda: sweep_faults(tr, sim, grid)["summary"]["mean_latency"])
    return {
        "clean_warm_s": clean_s,
        "fault_warm_s": fault_s,
        "fault_overhead_frac": fault_s / clean_s - 1.0,
        "grid_k": len(grid),
        "grid_cold_s": grid_cold_s,
        "grid_warm_s": grid_warm_s,
        "grid_scan_body_traces": grid_traces,
        "grid_warm_per_frame_s": grid_warm_s / len(grid),
    }


def _closed_loop(sim, tr, seed: int) -> dict:
    """One fault-storm run under the ResilienceRuntime."""
    runtime = ResilienceRuntime(
        SimSession.init(sim),
        ResiliencePolicy(threshold_frac=BAND, hysteresis=2, cooldown=1,
                         search_generations=8, search_population=8,
                         search_seed=seed))
    victims = runtime.session.placement[:2]
    injector = faults.FaultInjector(
        [faults.GatewayFault(start=STORM_T0, position=p) for p in victims],
        T_TOTAL, seed=seed)

    heal_chunk, prefault_baseline, heal_s = None, None, 0.0
    for i, ch in enumerate(traffic.chunk_trace(tr, CHUNK)):
        t0 = i * CHUNK
        if t0 == STORM_T0:
            prefault_baseline = runtime.baseline
        faulted = injector.inject(ch, runtime.current_cfg, t0)
        runtime.report_failed_positions(injector.failed_positions(t0))
        out, dt = _timed_observe(runtime, faulted)
        if out["healed"] is not None and heal_chunk is None:
            heal_chunk, heal_s = i, dt

    storm_chunk = STORM_T0 // CHUNK
    lats = [e["latency"] for e in runtime.events]
    band_hi = (1.0 + BAND) * prefault_baseline
    in_band = [lat <= band_hi for lat in lats]
    recovery = next((i - storm_chunk for i in range(storm_chunk, len(lats))
                     if in_band[i]), None)
    return {
        "storm_chunk": storm_chunk,
        "heal_chunk": heal_chunk,
        "detection_latency_chunks":
            None if heal_chunk is None else heal_chunk - storm_chunk,
        "recovery_time_chunks": recovery,
        "availability": float(np.mean(in_band)),
        "prefault_baseline": prefault_baseline,
        "post_heal_mean_latency":
            float(np.mean(lats[heal_chunk + 1:]))
            if heal_chunk is not None and heal_chunk + 1 < len(lats)
            else None,
        "replacements": runtime.replacements,
        "total_pcm_nj": runtime.total_pcm_nj,
        "total_stall_cycles": runtime.total_stall_cycles,
        "heal_dispatch_s": heal_s,
    }


def _timed_observe(runtime, chunk):
    import time

    t0 = time.perf_counter()
    out = runtime.observe(chunk)
    return out, time.perf_counter() - t0


def run(seed: int = 0) -> dict:
    sim = fixed_gateway_config(4)
    tr = _trace(seed)

    clear_engine_caches()
    overhead = _engine_overhead(sim, tr)
    loop = _closed_loop(sim, tr, seed)

    # Energy overhead of surviving the storm: the faulted closed-loop run's
    # mean power vs the fault-free run of the same traffic (spare routing
    # is longer + the PCM switches are extra energy on top).
    clean_power = float(simulate(tr, sim)["summary"]["mean_power_mw"])
    result = {
        "engine": overhead,
        "closed_loop": loop,
        "clean_mean_power_mw": clean_power,
        "recovered_within_band":
            loop["post_heal_mean_latency"] is not None
            and loop["post_heal_mean_latency"]
            <= (1.0 + BAND) * loop["prefault_baseline"],
        "chunk": CHUNK,
        "t_total": T_TOTAL,
        "band_frac": BAND,
    }
    save_json_history("BENCH_faults.json", result)
    return result


if __name__ == "__main__":
    r = run()
    e, c = r["engine"], r["closed_loop"]
    print(f"fault path: warm overhead {e['fault_overhead_frac']:+.1%} vs "
          f"clean ({e['clean_warm_s']:.3f}s -> {e['fault_warm_s']:.3f}s); "
          f"{e['grid_k']}-frame grid {e['grid_scan_body_traces']} scan-body "
          f"trace, warm {e['grid_warm_per_frame_s'] * 1e3:.1f}ms/frame")
    print(f"closed loop: storm at chunk {c['storm_chunk']}, detected+healed "
          f"in {c['detection_latency_chunks']} chunk(s), recovered in "
          f"{c['recovery_time_chunks']} chunk(s), availability "
          f"{c['availability']:.0%}, bill {c['total_pcm_nj']:.0f} nJ PCM + "
          f"{c['total_stall_cycles']} stall cycles "
          f"(recovered_within_band={r['recovered_within_band']})")
