"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.gateway_controller import ControllerConfig
from repro.core.simulator import Arch, SimConfig, simulate

RESULTS = Path(__file__).resolve().parent / "results"
RESULTS.mkdir(parents=True, exist_ok=True)


def fixed_gateway_config(g: int, base: SimConfig = SimConfig()) -> SimConfig:
    """ReSiPI datapath with the controller pinned at exactly g gateways."""
    ctl = ControllerConfig(l_m=base.ctl.l_m, max_gateways=g, min_gateways=g)
    return dataclasses.replace(base.with_arch(Arch.RESIPI), ctl=ctl)


def timed(fn, *args, repeat: int = 1, **kwargs):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kwargs)
    out = jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
        else out
    return out, (time.perf_counter() - t0) / repeat * 1e6   # us per call


def timed_s(fn) -> float:
    """One blocking wall-clock measurement of fn() in seconds
    (`time.perf_counter`, monotonic — cold sections / one-shot costs)."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def timed_result_s(fn):
    """`timed_s` that also hands back fn()'s (blocked) result, so benches
    that need both the timing and the output do not run fn() twice."""
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return out, time.perf_counter() - t0


# Median-of-N repetitions for every *warm* (hot-path) measurement: single
# warm samples in the BENCH history swung 3-6x between runs (scheduler
# noise at millisecond scale), which buried real regressions. N >= 5 keeps
# the bench fast while the median rejects the outlier tail.
WARM_REPS = 5


def warm_median(fn, reps: int = WARM_REPS) -> float:
    """Median of `reps` blocking wall-clock runs of fn(), in seconds.

    Assumes fn() is already warm (compiled); run it once beforehand if the
    preceding code has not. The per-run result is discarded — time only.
    """
    import statistics

    return statistics.median(timed_s(fn) for _ in range(reps))


def save_json(name: str, data) -> Path:
    path = RESULTS / name
    path.write_text(json.dumps(data, indent=1, default=float))
    return path


def git_commit() -> "str | None":
    """Short hash of the checked-out commit, or None outside a git repo.

    Cached per process — `save_json_history` stamps it on every entry so a
    BENCH_*.json trajectory is attributable to the PR that produced it.
    """
    global _GIT_COMMIT
    if _GIT_COMMIT is _UNSET:
        import subprocess
        try:
            _GIT_COMMIT = subprocess.check_output(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=Path(__file__).resolve().parent,
                stderr=subprocess.DEVNULL, text=True).strip() or None
        except (OSError, subprocess.CalledProcessError):
            _GIT_COMMIT = None
    return _GIT_COMMIT


_UNSET = object()
_GIT_COMMIT = _UNSET


def save_json_history(name: str, data: dict) -> Path:
    """Write `data` but APPEND this run to the file's `history` list.

    The BENCH_*.json files are the cross-PR perf trajectory: the top-level
    keys always reflect the latest run, while `history` accumulates one
    entry per run (latest last), surviving overwrites, each stamped with
    the UTC timestamp and the git commit it ran at. Corrupt or legacy
    files without a history list start a fresh one.
    """
    import datetime

    path = RESULTS / name
    history = []
    if path.exists():
        try:
            prior = json.loads(path.read_text())
            history = list(prior.get("history", []))
        except (json.JSONDecodeError, AttributeError):
            history = []
    entry = {k: v for k, v in data.items() if k != "history"}
    entry["timestamp"] = datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds")
    entry["commit"] = git_commit()
    out = dict(data)
    out["history"] = history + [entry]
    return save_json(name, out)
