"""CPU verification gate: tier-1 pytest + a fast padded-sweep smoke.

`make verify` (or `python benchmarks/smoke.py`) is the pre-merge check:

  1. the repo's tier-1 test suite (ROADMAP.md) via pytest, and
  2. a ~5 s compiled padded-topology-sweep smoke that asserts the engine's
     two load-bearing invariants on CPU — the whole topology grid runs as
     ONE scan-body trace, and padded results match unpadded `simulate` —
     so regressions in the compiled padded path are caught without a TPU,
  3. the same pair of invariants for the gateway-placement axis
     (`sweep_placement`: K placements, one trace, unpadded parity),
  4. the workload/time axis: a mixed-length `sweep_workload` runs as one
     scan-body trace with T-padded lanes matching unpadded `simulate`,
     and a chunked `SimSession` bit-matches the one-shot records,
  5. the device-resident placement search: a whole annealed search is ONE
     scan-body trace and ONE dispatch, and its best score matches a fresh
     host-oracle `simulate` of the found placement (device/host parity),
  6. the Pareto co-design engine: a joint (topology x placement x knob)
     `search_codesign` is ONE dispatch, its front is mutually
     non-dominated, and a host-oracle re-score of every front entry
     reproduces the archived objectives at 1e-6,
  7. the fault-injection path: a fault frame masked at t == T matches the
     fault-free `simulate`, a firing fault reuses the same executable, and
     the fault grid vmaps as one more sweep axis (one scan-body trace),
  8. the session server: a short continuous-batching soak — nominal load
     drops zero healthy sessions on one shared executable, an overload
     burst sheds by policy with the queue staying bounded,
  9. the fused epoch_step kernel: `epoch_kernel=True` reproduces the scan
     body at 1e-6 through `simulate` — clean, destination-aware, and
     faulted — in interpret mode (the engine-parity gate off-TPU),
 10. the fleet: a REAL 2-process `jax.distributed` CPU mesh (gloo
     collectives, local coordinator) runs a small co-design grid through
     `python -m repro.launch.fleet` and must reproduce the single-process
     run per-point at 1e-6 (the GSPMD-sharded-executable parity gate).

`--smoke-only` skips the pytest stage (used by CI wrappers that already
ran the suite, and for quick local iteration).
"""
from __future__ import annotations

import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
if str(REPO / "src") not in sys.path:        # standalone-invocation bootstrap
    sys.path.insert(0, str(REPO / "src"))


def padded_sweep_smoke() -> None:
    import jax
    import numpy as np

    from repro.core import traffic
    from repro.core.constants import NETWORK
    from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                      reset_engine_stats, simulate,
                                      sweep_topology, topology_point_config)

    t0 = time.time()
    grid_c, grid_g = [4, 9, 16, 25], [4, 2, 4, 2]
    cfg = NETWORK.with_topology(n_chiplets=max(grid_c))
    tr = traffic.generate_trace("dedup", 16, jax.random.PRNGKey(0), cfg)
    base = SimConfig().with_arch(Arch.RESIPI)

    reset_engine_stats()
    out = sweep_topology(tr, base, n_chiplets=grid_c,
                         gateways_per_chiplet=grid_g)
    lat = np.asarray(out["summary"]["mean_latency"])
    traces = engine_stats()["simulate_traces"]
    assert lat.shape == (len(grid_c),) and np.all(np.isfinite(lat)), lat
    assert traces == 1, f"expected ONE scan-body trace, got {traces}"

    # padded-vs-unpadded parity on one mid-grid point
    c, g, i = grid_c[1], grid_g[1], 1
    ref = simulate(traffic.slice_trace(tr, c),
                   topology_point_config(base, n_chiplets=c,
                                         gateways_per_chiplet=g))["summary"]
    for k in ("mean_latency", "mean_power_mw", "mean_gateways"):
        np.testing.assert_allclose(
            np.asarray(out["summary"][k][i]), np.asarray(ref[k]),
            rtol=1e-4, atol=1e-4,
            err_msg=f"padded grid point (c={c}, g={g}) diverged on {k}")

    # warm re-call must not re-trace
    before = engine_stats()["simulate_traces"]
    sweep_topology(tr, base, n_chiplets=grid_c, gateways_per_chiplet=grid_g)
    assert engine_stats()["simulate_traces"] == before, "warm call re-traced"
    print(f"padded-sweep smoke OK in {time.time() - t0:.1f}s "
          f"({len(grid_c)} topologies, 1 trace, parity holds)")


def placement_sweep_smoke() -> None:
    """Compiled placement path: K placements, one trace, unpadded parity."""
    import dataclasses

    import jax
    import numpy as np

    from repro.core import traffic
    from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                      reset_engine_stats, simulate,
                                      sweep_placement)

    t0 = time.time()
    tr = traffic.generate_trace("dedup", 12, jax.random.PRNGKey(1))
    base = SimConfig().with_arch(Arch.RESIPI)
    center = ((1, 1), (2, 2), (1, 2), (2, 1))

    reset_engine_stats()
    out = sweep_placement(tr, base, [None, center])
    assert engine_stats()["simulate_traces"] == 1, "placement sweep re-traced"
    ref = simulate(tr, dataclasses.replace(
        base, cfg=base.cfg.with_placement(center)))["summary"]
    np.testing.assert_allclose(
        np.asarray(out["summary"]["mean_latency"][1]),
        np.asarray(ref["mean_latency"]), rtol=1e-6,
        err_msg="placement lane diverged from unpadded simulate")
    print(f"placement-sweep smoke OK in {time.time() - t0:.1f}s "
          f"(2 placements, 1 trace, parity holds)")


def traffic_stream_smoke() -> None:
    """Workload/time axis: T-padded parity + streaming-vs-oneshot match."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import traffic
    from repro.core.simulator import (Arch, SimConfig, SimSession,
                                      engine_stats, reset_engine_stats,
                                      simulate, sweep_workload)

    t0 = time.time()
    base = SimConfig().with_arch(Arch.RESIPI)
    specs = [traffic.ParsecSpec(app="dedup", n_intervals=10),
             traffic.UniformSpec(n_intervals=16),
             traffic.BurstySpec(n_intervals=12)]

    # mixed-length workload sweep: ONE scan-body trace, padded-lane parity
    reset_engine_stats()
    out = sweep_workload(specs, base, seed=0)
    traces = engine_stats()["simulate_traces"]
    assert traces == 1, f"expected ONE scan-body trace, got {traces}"
    keys = jax.random.split(jax.random.PRNGKey(0), len(specs))
    for i, (sp, ky) in enumerate(zip(specs, keys)):
        ref = simulate(traffic.generate(sp, ky), base)["summary"]
        np.testing.assert_allclose(
            np.asarray(out["summary"]["mean_latency"][i]),
            np.asarray(ref["mean_latency"]), rtol=1e-6,
            err_msg=f"padded workload lane {sp.name} diverged")

    # streaming session: chunked records bit-match the one-shot scan
    tr = traffic.generate_trace("canneal", 24, jax.random.PRNGKey(1))
    one = simulate(tr, base)
    session = SimSession.init(base)
    recs = [session.step_chunk(ch)["records"]
            for ch in traffic.chunk_trace(tr, 8)]
    cat = jax.tree.map(lambda *xs: jnp.concatenate(xs), *recs)
    for k in ("latency", "power_mw", "g"):
        assert np.array_equal(np.asarray(cat[k]),
                              np.asarray(one["records"][k])), \
            f"streamed records[{k}] diverged from one-shot simulate"
    np.testing.assert_allclose(
        np.asarray(session.summary()["mean_latency"]),
        np.asarray(one["summary"]["mean_latency"]), rtol=1e-6)
    print(f"traffic/streaming smoke OK in {time.time() - t0:.1f}s "
          f"({len(specs)} mixed-length workloads, 1 trace, chunked "
          f"records bit-match)")


def search_smoke() -> None:
    """Device-resident search: one trace + one dispatch + oracle parity."""
    import dataclasses

    import jax
    import numpy as np

    from repro.core import traffic
    from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                      reset_engine_stats, search_placement,
                                      simulate)

    t0 = time.time()
    tr = traffic.generate_trace("dedup", 12, jax.random.PRNGKey(2))
    base = SimConfig().with_arch(Arch.RESIPI)

    reset_engine_stats()
    res = search_placement(tr, base, generations=4, population=6, seed=0)
    stats = engine_stats()
    assert stats["simulate_traces"] <= 1, \
        f"search re-traced per generation: {stats}"
    assert stats["search_dispatches"] == 1, \
        f"search was not ONE dispatch: {stats}"
    assert res["best_score"] <= res["default_score"]

    # Host-oracle parity: re-score the found placement through unpadded
    # simulate (numpy tables) — must match the device path's traced tables.
    # (This traces its own single-config executable, so the warm-search
    # accounting below starts from a fresh reset.)
    single = simulate(tr, dataclasses.replace(
        base, cfg=base.cfg.with_placement(res["best_placement"])))
    ref = float(np.mean(np.asarray(single["records"]["mean_inter_latency"])))
    np.testing.assert_allclose(
        res["best_score"], ref, rtol=1e-5,
        err_msg="device search score diverged from the host oracle")

    # Warm re-seeded search: zero new traces, exactly one dispatch.
    reset_engine_stats()
    search_placement(tr, base, generations=4, population=6, seed=3)
    stats2 = engine_stats()
    assert stats2["simulate_traces"] == 0, "warm search re-traced"
    assert stats2["search_dispatches"] == 1
    print(f"search smoke OK in {time.time() - t0:.1f}s "
          f"(4x6 annealed search, 1 dispatch, oracle parity holds)")


def pareto_smoke() -> None:
    """Pareto co-design: the joint (topology x placement x knob) search is
    ONE dispatch, the returned front is mutually non-dominated, and a
    host-oracle re-score of every front entry reproduces its archived
    objectives at 1e-6 (the device/host co-design parity gate)."""
    import jax
    import numpy as np

    from repro.core import pareto, traffic
    from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                      reset_engine_stats)

    t0 = time.time()
    base = SimConfig().with_arch(Arch.RESIPI)
    grid_c = [9, 16]
    cfg = base.cfg.with_topology(n_chiplets=max(grid_c))
    traces = [traffic.generate_trace(a, 8, jax.random.PRNGKey(i), cfg)
              for i, a in enumerate(["dedup", "streamcluster"])]

    reset_engine_stats()
    res = pareto.search_codesign(traces, base, n_chiplets=grid_c,
                                 islands=2, generations=4, population=4,
                                 archive=16, seed=0)
    stats = engine_stats()
    assert stats["search_dispatches"] == 1, \
        f"co-design search was not ONE dispatch: {stats}"
    assert stats["simulate_traces"] <= 1, \
        f"co-design search re-traced the scan body: {stats}"
    assert res["front"], "co-design search returned an empty front"

    # The front is mutually non-dominated.
    obj = np.asarray([[e["objectives"][k] for k in
                       ("latency", "power_mw", "energy")]
                      for e in res["front"]])
    le = (obj[:, None] <= obj[None, :]).all(-1)
    lt = (obj[:, None] < obj[None, :]).any(-1)
    dominated = (le & lt).any(axis=0)
    assert not dominated.any(), "device front contains a dominated point"

    # Host-oracle parity: unpadded re-simulation of every front entry.
    rescored = pareto.rescore_front_host(res, traces, base)
    np.testing.assert_allclose(rescored, obj, rtol=1e-6, atol=1e-9,
                               err_msg="device front diverged from the "
                                       "host-oracle re-score")
    print(f"pareto smoke OK in {time.time() - t0:.1f}s "
          f"({len(grid_c)} topologies x 2 islands, 1 dispatch, "
          f"{len(res['front'])}-point front, oracle parity holds)")


def fault_smoke() -> None:
    """Compiled fault path: one trace per entry point + never-fire parity.

    The parity half is the fault-masking contract on CPU: a fault frame
    whose window starts at t == T (so it never fires inside the simulated
    horizon) must match the fault-free `simulate` — same executable
    discipline as the padded-lane invariants above.
    """
    import jax
    import numpy as np

    from repro.core import faults, traffic
    from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                      reset_engine_stats, simulate,
                                      sweep_faults)

    t0 = time.time()
    base = SimConfig().with_arch(Arch.RESIPI)
    T = 16
    tr = traffic.generate_trace("dedup", T, jax.random.PRNGKey(3))
    clean = simulate(tr, base)["summary"]

    # Fault masked at t == T: in-window never fires -> fault-free parity.
    masked = faults.compile_faults(
        [faults.GatewayFault(start=T, chiplet=0, slot=0),
         faults.LossDrift(start=T, db_per_interval=0.5)], base.cfg, T)
    reset_engine_stats()
    out = simulate(faults.attach_faults(tr, masked), base)["summary"]
    assert engine_stats()["simulate_traces"] == 1
    for k in ("mean_latency", "mean_power_mw", "mean_energy"):
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(clean[k]), rtol=1e-6,
            err_msg=f"never-firing fault frame diverged from fault-free "
                    f"simulate on {k}")

    # A firing fault reuses the same executable and moves the result.
    firing = faults.compile_faults(
        [faults.GatewayFault(start=2, chiplet=0, slot=0)], base.cfg, T)
    before = engine_stats()["simulate_traces"]
    hurt = simulate(faults.attach_faults(tr, firing), base)["summary"]
    assert engine_stats()["simulate_traces"] == before, \
        "a different fault pattern re-traced the fault path"
    assert float(hurt["mean_gateways"]) < float(clean["mean_gateways"]), \
        "hard gateway failure did not reduce effective gateways"

    # The fault grid is one more vmapped axis: K frames, one new trace.
    reset_engine_stats()
    sw = sweep_faults(tr, base, [masked, firing])
    assert engine_stats()["simulate_traces"] == 1
    lat = np.asarray(sw["summary"]["mean_latency"])
    np.testing.assert_allclose(lat[0], np.asarray(clean["mean_latency"]),
                               rtol=1e-6)
    print(f"fault smoke OK in {time.time() - t0:.1f}s "
          f"(t==T parity, 1 trace per entry point, fault grid vmaps)")


def serve_soak_smoke() -> None:
    """Session-server soak: shared executable, zero healthy drops at
    nominal load, nonzero policy shed under an overload burst."""
    import jax
    import numpy as np

    from repro.core import traffic
    from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                      reset_engine_stats)
    from repro.serve.engine import SessionServer, replay_standalone
    from repro.serve.policies import ServerPolicy
    from repro.serve.scheduler import SessionRequest

    t0 = time.time()
    base = SimConfig().with_arch(Arch.RESIPI)

    # Nominal: a mixed-length mix well inside capacity — every admitted
    # session completes, the whole run is ONE scan-body trace, and a
    # sampled session bit-matches its standalone replay.
    server = SessionServer(base, ServerPolicy(lanes=3, chunk_intervals=6,
                                              queue_capacity=8))
    reset_engine_stats()
    for i in range(5):
        tr = traffic.generate_trace("dedup", 5 + 3 * i, jax.random.PRNGKey(i))
        server.submit(SessionRequest(trace=tr, priority=i % 3))
    server.drain()
    traces = engine_stats()["simulate_traces"]
    assert traces <= 1, f"serve soak re-traced per tick: {traces}"
    m = server.metrics()
    assert m["completed"] == m["admitted"] == 5, \
        f"nominal load dropped healthy sessions: {m}"
    sess = server.completed[0]
    ref = replay_standalone(base, sess)
    for k in ("mean_latency", "mean_energy", "valid_intervals"):
        assert float(ref[k]) == sess.summary()[k], \
            f"packed lane diverged from standalone replay on {k}"

    # Overload: a burst over queue capacity sheds by policy and the queue
    # never grows past its bound.
    over = SessionServer(base, ServerPolicy(lanes=2, chunk_intervals=6,
                                            queue_capacity=3))
    for i in range(10):
        tr = traffic.generate_trace("canneal", 12, jax.random.PRNGKey(i))
        over.submit(SessionRequest(trace=tr))
    over.drain()
    mo = over.metrics()
    shed = mo["shed_queue_full"] + mo["shed_memory"] + mo["shed_priority"]
    assert shed > 0, f"overload burst shed nothing: {mo}"
    depths = [e["queue_depth"] for e in over.events]
    assert max(depths) <= 3, f"queue grew past capacity: {max(depths)}"
    assert mo["completed"] == mo["admitted"], \
        f"overload dropped admitted sessions: {mo}"
    assert np.isfinite([s.summary()["mean_latency"]
                        for s in over.sessions.values()]).all()
    print(f"serve soak smoke OK in {time.time() - t0:.1f}s "
          f"(1 trace, 0 healthy drops, {shed} shed under overload, "
          f"replay parity holds)")


def kernel_parity_smoke() -> None:
    """Fused epoch_step kernel vs the lax.scan body through `simulate`:
    summaries agree at 1e-6 on the clean, destination-aware, and faulted
    paths (interpret mode — the off-TPU engine-parity gate)."""
    import dataclasses

    import jax
    import numpy as np

    from repro.core import traffic
    from repro.core.faults import GatewayFault, attach_faults, compile_faults
    from repro.core.simulator import SUMMARY_KEYS, SimConfig, simulate

    t0 = time.time()
    sim = SimConfig()
    sim_k = dataclasses.replace(sim, epoch_kernel=True)
    clean = traffic.generate(traffic.UniformSpec(n_intervals=24),
                             jax.random.PRNGKey(0))
    dest = traffic.generate(
        traffic.PermutationSpec(pattern="transpose", mean_load=0.05,
                                n_intervals=24),
        jax.random.PRNGKey(1), dest=True)
    frame = compile_faults((GatewayFault(chiplet=0, slot=0, start=4),),
                           sim.cfg, 24, seed=3)
    for name, tr in (("clean", clean), ("dest", dest),
                     ("faults", attach_faults(dict(clean), frame))):
        a, b = simulate(tr, sim_k), simulate(tr, sim)
        for k in SUMMARY_KEYS:
            np.testing.assert_allclose(
                np.asarray(a["summary"][k]), np.asarray(b["summary"][k]),
                rtol=1e-6, atol=1e-6,
                err_msg=f"kernel parity broke: {name} summary[{k}]")
    print(f"epoch_step kernel parity smoke OK in {time.time() - t0:.1f}s "
          f"(clean/dest/faulted summaries match the scan body at 1e-6)")


def distributed_smoke() -> None:
    """Real 2-process jax.distributed fleet vs the single-process run:
    the same small co-design grid, per-point parity at 1e-6."""
    import json
    import os
    import tempfile

    t0 = time.time()
    grid = ["--chiplets", "4,9", "--placements", "2",
            "--workloads", "uniform,bursty", "--intervals", "6",
            "--seed", "0", "--dump-points"]
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    with tempfile.TemporaryDirectory(prefix="fleet-smoke-") as td:
        outs = {}
        for tag, extra in (("single", ["--shard", "0:1"]),
                           ("dist", ["--processes", "2"])):
            out = Path(td) / f"{tag}.json"
            cmd = [sys.executable, "-m", "repro.launch.fleet",
                   "--cache-dir", f"{td}/cache", "--out", str(out)] \
                + grid + extra
            proc = subprocess.run(cmd, cwd=REPO, env=env, timeout=600,
                                  capture_output=True, text=True)
            assert proc.returncode == 0, \
                f"fleet {tag} run failed:\n{proc.stdout}\n{proc.stderr}"
            outs[tag] = json.loads(out.read_text())
    single, dist = outs["single"], outs["dist"]
    assert dist["process_count"] == 2 and dist["device_count"] >= 2, dist
    assert single["labels"] == dist["labels"]
    for lbl, a, b in zip(single["labels"], single["mean_latency"],
                         dist["mean_latency"]):
        assert abs(a - b) <= 1e-6 * max(abs(a), 1.0), \
            f"fleet point {lbl} diverged: single {a} vs 2-process {b}"
    print(f"distributed smoke OK in {time.time() - t0:.1f}s "
          f"({single['grid_points']} grid points, 2-process gloo mesh, "
          f"per-point parity holds)")


def main(argv) -> int:
    if "--smoke-only" not in argv:
        rc = subprocess.call(
            [sys.executable, "-m", "pytest", "-x", "-q"], cwd=REPO)
        if rc != 0:
            print("tier-1 pytest FAILED", file=sys.stderr)
            return rc
    padded_sweep_smoke()
    placement_sweep_smoke()
    traffic_stream_smoke()
    search_smoke()
    pareto_smoke()
    fault_smoke()
    serve_soak_smoke()
    kernel_parity_smoke()
    distributed_smoke()
    print("verify OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
