"""Kernel benchmark: fused `epoch_step` Pallas body vs the XLA scan body.

Times the same RESIPI workloads through both engines — `SimConfig.
epoch_kernel=False` (the `lax.scan(make_step)` body) and `=True` (the
fused `kernels.epoch_step` pallas_call) — as warm-call medians through the
public `simulate` / `sweep` / `simulate_batch` entry points, so the numbers
include exactly what users pay: jit dispatch, record assembly, summary
reductions.

Off-TPU the kernel runs in interpret mode, which is a *correctness* vehicle
(every grid step is re-evaluated in Python), so the interpret column is
expected to be slow — it is reported for the trajectory, not as a win. On a
TPU backend the kernel compiles through Mosaic and the compiled column is
the number that matters; `backend` in the JSON says which regime a history
entry measured. Results append to benchmarks/results/BENCH_kernels.json.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import traffic
from repro.core.simulator import (SimConfig, clear_engine_caches, simulate,
                                  simulate_batch, sweep)
from benchmarks.common import save_json_history, timed_s, warm_median


def _engine_pair(run_fn, n_intervals: int) -> dict:
    """cold/warm seconds + warm intervals/s for scan body vs fused kernel."""
    out = {}
    for name, kernel in (("scan_body", False), ("fused_kernel", True)):
        sim = dataclasses.replace(SimConfig(), epoch_kernel=kernel)
        clear_engine_caches()
        cold_s = timed_s(lambda: run_fn(sim))
        warm_s = warm_median(lambda: run_fn(sim))
        out[name] = {"cold_s": cold_s, "warm_s": warm_s,
                     "warm_intervals_per_sec": n_intervals / warm_s}
    out["warm_ratio_kernel_over_scan"] = (
        out["fused_kernel"]["warm_s"] / out["scan_body"]["warm_s"])
    return out


def run(n_intervals: int = 96, seed: int = 7) -> dict:
    key = jax.random.PRNGKey(seed)
    tr = traffic.generate(
        traffic.UniformSpec(mean_load=0.03, n_intervals=n_intervals), key)
    tr_dest = traffic.generate(
        traffic.PermutationSpec(pattern="transpose", mean_load=0.03,
                                n_intervals=n_intervals),
        key, dest=True)
    batch = [traffic.generate(traffic.UniformSpec(mean_load=0.03,
                                                  n_intervals=n_intervals),
                              jax.random.PRNGKey(seed + i))
             for i in range(8)]
    lm_grid = jnp.linspace(0.004, 0.032, 16)

    result = {
        "backend": jax.default_backend(),
        # off-TPU the pallas_call runs interpreted: correctness regime, the
        # timing is a floor check, not a speedup claim (see module doc)
        "kernel_mode": "compiled" if jax.default_backend() == "tpu"
        else "interpret",
        "n_intervals": n_intervals,
        "single": _engine_pair(
            lambda sim: simulate(tr, sim)["summary"]["mean_latency"],
            n_intervals),
        "single_dest": _engine_pair(
            lambda sim: simulate(tr_dest, sim)["summary"]["mean_latency"],
            n_intervals),
        "sweep_16": _engine_pair(
            lambda sim: sweep(tr, sim, l_m=lm_grid)
            ["summary"]["mean_latency"],
            16 * n_intervals),
        "batch_8": _engine_pair(
            lambda sim: simulate_batch(batch, sim)
            ["summary"]["mean_latency"],
            8 * n_intervals),
    }
    save_json_history("BENCH_kernels.json", result)
    return result


if __name__ == "__main__":
    r = run()
    s = r["single"]
    print(f"epoch_step [{r['kernel_mode']}/{r['backend']}]: scan body "
          f"{s['scan_body']['warm_intervals_per_sec']:.0f} intervals/s, "
          f"fused kernel "
          f"{s['fused_kernel']['warm_intervals_per_sec']:.0f} intervals/s "
          f"(ratio {s['warm_ratio_kernel_over_scan']:.2f}x warm)")
