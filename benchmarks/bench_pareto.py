"""Pareto co-design benchmark: one-dispatch joint search vs the loop.

Before PR 10 the only way to explore the (topology x placement x knob)
design space was a host loop: pick a topology point, run the PR-5
`search_placement` engine, repeat — T x K separate dispatches, each
paying its own host sync and per-call preprocessing, each scoring a
single workload trace. `repro.core.pareto.search_codesign` folds the
whole joint search into ONE compiled dispatch: an outer `lax.scan` over
the padded topology grid, K annealed island chains per point (ring
migration every M generations), W workload traces per candidate, and a
device-resident Pareto archive over (latency, power, energy) — the final
result pytree is the only device->host transfer.

Measured here, on the paper's Table 1 system:

  * sequential warm — the pre-PR-10 loop: for every topology point and
                      every island seed, one `search_placement` dispatch
                      on the dominant workload (T*K dispatches).
  * codesign cold/warm — the one-dispatch joint search, compile
                      included/excluded, scoring all W workloads.
  * acceptance      — warm codesign candidate-evals/sec >= 5x the
                      sequential loop's (`meets_5x`). A candidate eval is
                      one (placement, topology, knob, workload) scoring;
                      the codesign engine scores W workloads per
                      candidate where the loop scores one — that
                      amortization is precisely the batching win being
                      claimed. `search_dispatches == 1` and
                      `simulate_traces <= 1` prove one-trace/one-dispatch.
  * hypervolume     — dominated volume of the returned front against a
                      reference point at 2x the worst finite objective
                      (scale-free progress number for the history).

Like every BENCH speedup in this repo the ratio is machine-bound; read
`speedup_codesign_vs_sequential` from the same run, not across machines.
Results land in benchmarks/results/BENCH_pareto.json with an appended
`history` entry per run.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import pareto, traffic
from repro.core.simulator import (Arch, SimConfig, clear_engine_caches,
                                  engine_stats, reset_engine_stats,
                                  search_placement, topology_point_config)
from benchmarks.common import (save_json_history, timed_result_s, timed_s,
                               warm_median)

N_CHIPLETS = [16, 36, 64]
WORKLOADS = ["dedup", "streamcluster", "canneal", "bodytrack"]
GENERATIONS = 6
POPULATION = 8
ISLANDS = 8
ARCHIVE = 32
L_M_GRID = [0.008, 0.012, 0.02, 0.03, 0.01, 0.015, 0.025, 0.035]


def run(n_intervals: int = 16, seed: int = 7) -> dict:
    base = SimConfig().with_arch(Arch.RESIPI)
    cfg_max = base.cfg.with_topology(n_chiplets=max(N_CHIPLETS))
    traces = [traffic.generate_trace(app, n_intervals,
                                     jax.random.PRNGKey(seed + i), cfg_max)
              for i, app in enumerate(WORKLOADS)]

    codesign = lambda s: pareto.search_codesign(
        traces, base, n_chiplets=N_CHIPLETS, islands=ISLANDS,
        generations=GENERATIONS, population=POPULATION, archive=ARCHIVE,
        knob_grids={"l_m": L_M_GRID}, seed=s)

    def sequential(s):
        """The pre-PR-10 loop: T*K separate search_placement dispatches,
        each scoring the dominant workload only."""
        best = []
        for c in N_CHIPLETS:
            sim_c = topology_point_config(base, n_chiplets=c)
            tr_c = traffic.slice_trace(traces[0], c)
            for k in range(ISLANDS):
                best.append(search_placement(
                    tr_c, sim_c, generations=GENERATIONS,
                    population=POPULATION, seed=s + k)["best_score"])
        return np.asarray(best)

    t_pts = len(N_CHIPLETS)
    seq_evals = t_pts * ISLANDS * GENERATIONS * POPULATION

    # -- sequential per-topology loop (the pre-codesign workflow) -----------
    clear_engine_caches()
    seq_cold_s = timed_s(lambda: sequential(seed))
    seq_warm_s = warm_median(lambda: sequential(seed + 1))

    # -- one-dispatch co-design search --------------------------------------
    clear_engine_caches()
    reset_engine_stats()
    res, codesign_cold_s = timed_result_s(lambda: codesign(seed))
    stats = engine_stats()
    assert stats["search_dispatches"] == 1, \
        f"co-design search was not ONE dispatch: {stats}"
    assert stats["simulate_traces"] <= 1, \
        f"co-design search re-traced the scan body: {stats}"
    codesign_warm_s = warm_median(lambda: codesign(seed + 1))

    evals = res["candidate_evals"]
    assert evals == t_pts * GENERATIONS * ISLANDS * POPULATION \
        * len(WORKLOADS), res["candidate_evals"]
    seq_eps = seq_evals / seq_warm_s
    codesign_eps = evals / codesign_warm_s

    # -- front quality: hypervolume against a 2x-worst reference ------------
    front = np.asarray([[e["objectives"][k] for k in
                         ("latency", "power_mw", "energy")]
                        for e in res["front"]])
    ref = tuple(2.0 * front.max(axis=0))
    hv = pareto.hypervolume(front, ref)

    result = {
        "backend": jax.default_backend(),
        "n_intervals": n_intervals,
        "n_topologies": t_pts,
        "workloads": len(WORKLOADS),
        "generations": GENERATIONS,
        "population": POPULATION,
        "islands": ISLANDS,
        "archive_capacity": ARCHIVE,
        "scan_body_traces": stats["simulate_traces"],
        "search_dispatches": stats["search_dispatches"],
        "seq_cold_s": seq_cold_s,
        "seq_warm_s": seq_warm_s,
        "seq_evals_per_sec": seq_eps,
        "codesign_cold_s": codesign_cold_s,
        "codesign_warm_s": codesign_warm_s,
        "codesign_evals_per_sec": codesign_eps,
        "candidate_evals": evals,
        "speedup_codesign_vs_sequential": codesign_eps / seq_eps,
        "meets_5x": bool(codesign_eps >= 5 * seq_eps),
        "front_size": len(res["front"]),
        "hypervolume": hv,
        "hypervolume_ref": list(ref),
    }
    save_json_history("BENCH_pareto.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"pareto co-design ({r['n_topologies']} topologies x "
          f"{r['islands']} islands x {r['generations']}x{r['population']} "
          f"x {r['workloads']} workloads = {r['candidate_evals']} candidate "
          f"evals): sequential loop {r['seq_warm_s']:.3f}s "
          f"({r['seq_evals_per_sec']:.0f} evals/s) -> one-dispatch "
          f"{r['codesign_warm_s']:.4f}s "
          f"({r['codesign_evals_per_sec']:.0f} evals/s, "
          f"{r['speedup_codesign_vs_sequential']:.1f}x, "
          f"{r['scan_body_traces']} trace / {r['search_dispatches']} "
          f"dispatch); front {r['front_size']} points, hypervolume "
          f"{r['hypervolume']:.3g}; meets_5x={r['meets_5x']}")
