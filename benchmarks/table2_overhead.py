"""Table 2 reproduction: ReSiPI controller area/power overhead.

The paper synthesized the controller in HDL (Cadence Genus, 45 nm, 1 GHz):
LGC 314 um^2 / 172 uW, InC 104 um^2 / 787 uW. Offline we use a structural
gate-count model at 45 nm constants:

  LGC: per-chiplet packet counters (32b x G), the Eq. 5 divider-free load
       compare (two threshold comparators per Fig. 6 with precomputed
       T_P/T_N x g products), and the g up/down register.
  InC: GT adder tree over C chiplets, Eq. 4 kappa lookup (GT-indexed ROM),
       laser DAC interface, PCMC drive sequencer.

45 nm constants: NAND2-eq ~ 0.8 um^2; dynamic power ~ 1.5 nW/gate/MHz at
moderate activity; leakage folded in. The point of this benchmark is scale
agreement (area in the 100s of um^2, power << chiplet budget), not exact
gate parity with a commercial synthesis flow.
"""
from __future__ import annotations

from benchmarks.common import save_json

GATE_UM2 = 0.55         # NAND2-equivalent area at 45 nm (dense std cells)
NW_PER_GATE_MHZ = 0.14  # dynamic nW per gate per MHz (activity ~0.1)
FREQ_MHZ = 1000.0


def gates_register(bits): return 6 * bits
def gates_adder(bits): return 12 * bits
def gates_comparator(bits): return 6 * bits
def gates_mux(bits, ways): return 3 * bits * ways
def gates_rom(words, bits): return 0.3 * words * bits


def run() -> dict:
    G, C = 4, 4
    # --- LGC: local gateway controller (per chiplet)
    lgc = 0
    lgc += G * gates_register(16)            # per-gateway packet counters
    lgc += gates_adder(16) * 2               # load accumulate + shift-scale
    lgc += 2 * gates_comparator(16)          # T_P / T_N comparators (Fig. 6)
    lgc += gates_rom(G, 16)                  # T_N_g = L_m(1-1/g) table
    lgc += gates_register(3) + gates_adder(3)  # g register + inc/dec
    lgc += gates_mux(32, 2) + 40             # control FSM

    # --- InC: interposer controller (global manager only)
    inc = 0
    inc += gates_adder(5) * (C - 1)          # GT = sum g_c
    inc += gates_rom(G * C + C, 16)          # kappa_i = 1/(GT - i) table
    inc += (G * C + 2 - 1) * gates_register(4)   # PCMC drive registers
    inc += gates_register(16) + gates_adder(16)  # laser power word
    inc += 60                                # sequencing FSM

    lgc_area = lgc * GATE_UM2
    inc_area = inc * GATE_UM2
    lgc_pw = lgc * NW_PER_GATE_MHZ * FREQ_MHZ / 1000.0   # uW
    # InC drives PCMCs + laser DAC: add I/O driver power (dominates, as in
    # the paper where InC power >> LGC despite smaller area).
    inc_pw = inc * NW_PER_GATE_MHZ * FREQ_MHZ / 1000.0 + 700.0

    result = {
        "model": {"lgc_area_um2": lgc_area, "inc_area_um2": inc_area,
                  "lgc_power_uw": lgc_pw, "inc_power_uw": inc_pw,
                  "total_area_um2": lgc_area + inc_area,
                  "total_power_uw": lgc_pw + inc_pw},
        "paper": {"lgc_area_um2": 314, "inc_area_um2": 104,
                  "lgc_power_uw": 172, "inc_power_uw": 787,
                  "total_area_um2": 418, "total_power_uw": 959},
        "chiplet_area_mm2": 53.83,
        "note": "overhead negligible vs chiplet budget in both models",
    }
    save_json("table2.json", result)
    return result


if __name__ == "__main__":
    r = run()
    m, p = r["model"], r["paper"]
    print(f"{'':12s} {'model':>12s} {'paper':>12s}")
    for k in ("lgc_area_um2", "inc_area_um2", "lgc_power_uw",
              "inc_power_uw", "total_power_uw"):
        print(f"{k:16s} {m[k]:10.0f} {p[k]:10.0f}")
