"""Placement-DSE benchmark: compiled placement search on the Table 1 system.

PlaceIT-style placement exploration is a generate-and-score loop: propose
candidate gateway placements, simulate each, keep the best. Without the
placement-polymorphic engine every candidate placement is a distinct
`NetworkConfig`, hence a distinct jit executable — a compile per candidate.
`search_placement` (device engine, PR 5) goes further: the entire annealed
search — proposals, traceable placement tables, scoring, acceptance — is
ONE compiled `lax.scan`, a single dispatch per search. This bench tracks
the *product* search path; the device-vs-host engine comparison lives in
bench_search.py -> BENCH_search.json.

Measured here on the paper's Table 1 system (4 chiplets, 4x4 mesh, 4 gateway
slots):

  * search cold  — full `search_placement` including its one compile.
  * search warm  — the same search against a hot cache (steady-state DSE,
                   median of N warm runs).
  * farm         — the same number of candidate evaluations as unpadded
                   per-placement `simulate` calls (compile farm baseline).
  * best-vs-default deltas — latency/power/energy of the found placement
    against the default edge scheme (the acceptance check: inter-chiplet
    latency must not regress).

Results land in benchmarks/results/BENCH_placement.json with an appended
`history` entry per run (the cross-PR perf trajectory).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import traffic
from repro.core.simulator import (Arch, SimConfig, clear_engine_caches,
                                  engine_stats, reset_engine_stats,
                                  search_placement, simulate)
from benchmarks.common import (save_json_history, timed_result_s, timed_s,
                               warm_median)

GENERATIONS = 8
POPULATION = 12


def _farm_baseline(trace, base: SimConfig, placements) -> float:
    """Per-candidate unpadded simulate calls: one compile per placement."""
    def go():
        outs = []
        for p in placements:
            sim = dataclasses.replace(base, cfg=base.cfg.with_placement(p))
            outs.append(simulate(trace, sim)["summary"]["mean_latency"])
        return outs
    return timed_s(go)


def run(n_intervals: int = 32, seed: int = 3) -> dict:
    trace = traffic.generate_trace("dedup", n_intervals,
                                   jax.random.PRNGKey(seed))
    base = SimConfig().with_arch(Arch.RESIPI)
    search = lambda s: search_placement(
        trace, base, generations=GENERATIONS, population=POPULATION, seed=s)

    # -- compiled search: cold (includes its ONE compile), then warm --------
    clear_engine_caches()
    reset_engine_stats()
    res, search_cold_s = timed_result_s(lambda: search(seed))
    scan_body_traces = engine_stats()["simulate_traces"]
    res_warm, _ = timed_result_s(lambda: search(seed + 1))
    search_warm_s = warm_median(lambda: search(seed + 1))
    if res_warm["best_score"] < res["best_score"]:
        res = res_warm

    # -- farm baseline: the generation-0 candidate set, one jit each --------
    clear_engine_caches()
    gen0 = {res["default_placement"], res["best_placement"]}
    rng = np.random.RandomState(0)
    while len(gen0) < POPULATION:        # pad with synthetic variants
        gen0.add(tuple(map(tuple, rng.permutation(
            [(x, y) for x in range(4) for y in range(4)])[:4].tolist())))
    farm_s = _farm_baseline(trace, base, sorted(gen0))

    default = simulate(trace, dataclasses.replace(
        base, cfg=base.cfg.with_placement(res["default_placement"])))
    best = simulate(trace, dataclasses.replace(
        base, cfg=base.cfg.with_placement(res["best_placement"])))
    d_sum = {k: float(v) for k, v in default["summary"].items()}
    b_sum = {k: float(v) for k, v in best["summary"].items()}

    evals = GENERATIONS * POPULATION
    result = {
        "backend": jax.default_backend(),
        "n_intervals": n_intervals,
        "generations": GENERATIONS,
        "population": POPULATION,
        "objective": res["objective"],
        "scan_body_traces": scan_body_traces,
        "search_cold_s": search_cold_s,
        "search_warm_s": search_warm_s,
        "generations_per_sec_warm": GENERATIONS / search_warm_s,
        "candidate_evals_per_sec_warm": evals / search_warm_s,
        "farm_one_generation_s": farm_s,
        "speedup_warm_vs_farm": farm_s * GENERATIONS / search_warm_s,
        "best_placement": [list(p) for p in res["best_placement"]],
        "default_score": res["default_score"],
        "best_score": res["best_score"],
        "improvement_frac": res["improvement_frac"],
        "inter_latency_delta_frac": res["best_score"] / res["default_score"]
                                    - 1.0,
        "latency_delta_frac": b_sum["mean_latency"] / d_sum["mean_latency"]
                              - 1.0,
        "power_delta_frac": b_sum["mean_power_mw"] / d_sum["mean_power_mw"]
                            - 1.0,
        "energy_delta_frac": b_sum["mean_energy"] / d_sum["mean_energy"]
                             - 1.0,
    }
    save_json_history("BENCH_placement.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"placement search ({r['generations']} generations x "
          f"{r['population']} candidates): cold {r['search_cold_s']:.2f}s, "
          f"warm {r['search_warm_s']:.3f}s "
          f"({r['generations_per_sec_warm']:.1f} gen/s, "
          f"{r['candidate_evals_per_sec_warm']:.0f} placements/s, "
          f"{r['scan_body_traces']} scan-body trace); "
          f"farm baseline {r['farm_one_generation_s']:.2f}s per generation "
          f"({r['speedup_warm_vs_farm']:.0f}x warm); best vs default edges: "
          f"inter-latency {r['inter_latency_delta_frac']:+.1%}, "
          f"power {r['power_delta_frac']:+.1%}, "
          f"energy {r['energy_delta_frac']:+.1%}")
