"""Fleet benchmark: host scaling, distributed parity, cold-start removal.

Three measurements, all subprocess-based (each worker is a REAL fresh
process — the regime a fleet actually runs in), landing in
benchmarks/results/BENCH_distributed.json with an appended history entry:

  * emulated-hosts scaling — the co-design grid split into the exact
    contiguous shards a 1- and 2-worker fleet owns
    (`python -m repro.launch.fleet --shard i:n`), each shard run to its
    warm sweep wall. On a box with enough cores the workers co-schedule
    and the fleet wall is max(worker walls); here every worker gets the
    whole machine sequentially, so max(worker walls) is the faithful
    stand-in for that wall and aggregate grid-points/sec is
    K / max(walls). The JSON says so (`mode: emulated-hosts`) and records
    the core count — no silent claims of concurrency the hardware
    cannot host.
  * distributed parity — a REAL 2-process `jax.distributed` fleet
    (gloo collectives, local coordinator) over a small grid, compared
    per-point against the single-process run: the GSPMD-sharded
    executable must reproduce the single-host numbers.
  * cold vs cache-warm first dispatch — a fresh process compiles
    `simulate` + `sweep_topology` into an empty persistent cache (cold
    wall), then a second fresh process repeats the identical calls
    against the now-populated cache (warm wall). The acceptance bar is
    warm <= 25% of cold on both entry points.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# The scaling grid: 4 chiplet counts x 8 placements x 4 workloads = 128
# co-design points (the same axes a full-scale thousands-of-points fleet
# run sweeps, sized so per-worker walls dwarf dispatch overhead + timer
# noise on a CI box).
SCALING = ["--chiplets", "4,9,16,25", "--placements", "8",
           "--workloads", "uniform,bursty,dedup,canneal",
           "--intervals", "12", "--reps", "7", "--seed", "0"]
SCALING_K = 4 * 8 * 4

# The parity grid: small enough that the 2-process run stays fast.
PARITY = ["--chiplets", "4,9", "--placements", "2",
          "--workloads", "uniform,bursty", "--intervals", "8",
          "--seed", "0", "--dump-points"]


def _fleet(extra, out_path, cache_dir, timeout=900) -> dict:
    env = dict(os.environ, PYTHONPATH=f"{REPO}/src")
    cmd = [sys.executable, "-m", "repro.launch.fleet",
           "--cache-dir", str(cache_dir), "--out", str(out_path)] + extra
    proc = subprocess.run(cmd, cwd=REPO, env=env, timeout=timeout,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"fleet run failed ({cmd}):\n{proc.stdout}\n"
                           f"{proc.stderr}")
    return json.loads(Path(out_path).read_text())


def emulated_scaling(cache_dir, tmp) -> dict:
    """Warm sweep walls for the 1-worker and 2-worker shardings of the
    same grid; aggregate points/sec = K / max(worker walls)."""
    out = {"mode": "emulated-hosts", "grid_points": SCALING_K,
           "host_cpu_count": os.cpu_count(),
           "host_cores_available": len(os.sched_getaffinity(0))
           if hasattr(os, "sched_getaffinity") else os.cpu_count(),
           "workers": {}}
    for n in (1, 2):
        shards = []
        for i in range(n):
            j = _fleet(SCALING + ["--shard", f"{i}:{n}"],
                       tmp / f"scale_{n}_{i}.json", cache_dir)
            shards.append({"shard": f"{i}:{n}",
                           "grid_points": j["grid_points"],
                           "first_call_s": j["first_call_s"],
                           "sweep_wall_s": j["sweep_wall_s"],
                           "points_per_sec": j["points_per_sec"]})
        wall = max(s["sweep_wall_s"] for s in shards)
        out["workers"][str(n)] = {
            "shards": shards, "fleet_wall_s": wall,
            "aggregate_points_per_sec": SCALING_K / wall}
    a1 = out["workers"]["1"]["aggregate_points_per_sec"]
    a2 = out["workers"]["2"]["aggregate_points_per_sec"]
    out["ratio_2v1"] = a2 / a1
    out["meets_1p7x"] = out["ratio_2v1"] >= 1.7
    return out


def distributed_parity(cache_dir, tmp) -> dict:
    """One real 2-process jax.distributed run vs the single-process run."""
    single = _fleet(PARITY + ["--shard", "0:1"],
                    tmp / "par_single.json", cache_dir)
    dist = _fleet(PARITY + ["--processes", "2"],
                  tmp / "par_dist.json", cache_dir)
    diffs = [abs(a - b) / max(abs(a), 1e-12) for a, b in
             zip(single["mean_latency"], dist["mean_latency"])]
    return {"grid_points": single["grid_points"],
            "process_count": dist["process_count"],
            "device_count": dist["device_count"],
            "pad_lanes": dist["pad_lanes"],
            "first_call_s": dist["first_call_s"],
            "sweep_wall_s": dist["sweep_wall_s"],
            "max_rel_diff": max(diffs),
            "parity": max(diffs) < 1e-6}


_CHILD_SRC = r"""
import json, sys, time
sys.path.insert(0, sys.argv[2])
from repro.runtime import cache as rcache
rcache.enable_persistent_cache(sys.argv[1])
import jax
from repro.core import traffic
from repro.core.simulator import Arch, SimConfig, simulate, sweep_topology
sim = SimConfig().with_arch(Arch.RESIPI)
mode = sys.argv[3]   # "aot" (serialized executables) | "jit" (jit + cache)
# Traces are prepared BEFORE the timers: each wall is that entry point's
# first dispatch in a fresh process. Cold = trace + XLA compile (+ AOT
# serialize). Warm/aot = deserialize the persisted executable (no tracing,
# no XLA); warm/jit = re-trace + persistent-cache hit.
grid = [4, 9, 16, 25, 36, 49]
tr49 = traffic.generate(traffic.UniformSpec(n_intervals=24),
                        jax.random.PRNGKey(0),
                        sim.cfg.with_topology(n_chiplets=max(grid)))
tr = traffic.generate(traffic.UniformSpec(n_intervals=64),
                      jax.random.PRNGKey(0), sim.cfg)
walls = {}
t0 = time.perf_counter()
if mode == "aot":
    exe = rcache.aot_compile("sweep_topology", tr49, sim, n_chiplets=grid)
    jax.block_until_ready(exe(tr49, sim, n_chiplets=grid))
else:
    jax.block_until_ready(sweep_topology(tr49, sim, n_chiplets=grid))
walls["sweep_topology"] = time.perf_counter() - t0
t0 = time.perf_counter()
if mode == "aot":
    exe = rcache.aot_compile("simulate", tr, sim)
    jax.block_until_ready(exe(tr, sim))
else:
    jax.block_until_ready(simulate(tr, sim))
walls["simulate"] = time.perf_counter() - t0
print("WALLS " + json.dumps(walls))
"""


def _coldwarm_child(cache_dir, mode) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_SRC, str(cache_dir),
         str(REPO / "src"), mode],
        cwd=REPO, timeout=900, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"cold/warm child failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith("WALLS "):
            return json.loads(line[len("WALLS "):])
    raise RuntimeError(f"no WALLS line in child output:\n{proc.stdout}")


def cold_vs_warm(tmp) -> dict:
    """First-dispatch wall in a fresh process: empty cache vs populated.

    The acceptance measurement is the AOT path (serialized executables —
    the second process neither traces nor compiles); the jit-level
    persistent cache is measured alongside for context (it removes XLA
    compilation but still pays re-tracing).
    """
    out = {}
    for mode in ("aot", "jit"):
        cdir = tmp / f"coldwarm-cache-{mode}"
        cold = _coldwarm_child(cdir, mode)   # populates the empty cache
        warm = _coldwarm_child(cdir, mode)   # fresh process, cache hits
        out[mode] = {k: {"cold_s": cold[k], "warm_s": warm[k],
                         "warm_over_cold": warm[k] / cold[k]}
                     for k in cold}
    return {"method": "aot serialized executables "
                      "(jit+persistent-cache shown for context)",
            "entries": out["aot"],
            "jit_cache_only": out["jit"],
            "meets_25pct": all(e["warm_over_cold"] <= 0.25
                               for e in out["aot"].values())}


def run() -> dict:
    t0 = time.time()
    with tempfile.TemporaryDirectory(prefix="bench-dist-") as td:
        tmp = Path(td)
        cache_dir = tmp / "fleet-cache"
        scaling = emulated_scaling(cache_dir, tmp)
        parity = distributed_parity(cache_dir, tmp)
        coldwarm = cold_vs_warm(tmp)
    result = {
        "scaling": scaling,
        "distributed_2proc": parity,
        "cold_vs_warm": coldwarm,
        "total_bench_s": time.time() - t0,
    }
    from benchmarks.common import save_json_history
    save_json_history("BENCH_distributed.json", result)
    return result


if __name__ == "__main__":
    r = run()
    s, p, c = r["scaling"], r["distributed_2proc"], r["cold_vs_warm"]
    print(f"scaling [{s['mode']}]: {s['grid_points']} points, "
          f"1w {s['workers']['1']['aggregate_points_per_sec']:.0f} -> "
          f"2w {s['workers']['2']['aggregate_points_per_sec']:.0f} "
          f"points/s (ratio {s['ratio_2v1']:.2f}x, "
          f">=1.7x: {s['meets_1p7x']})")
    print(f"distributed 2-proc: {p['process_count']} proc x "
          f"{p['device_count']} dev, parity={p['parity']} "
          f"(max rel diff {p['max_rel_diff']:.2e})")
    for k, e in c["entries"].items():
        print(f"cold/warm {k}: {e['cold_s']:.2f}s -> {e['warm_s']:.2f}s "
              f"({e['warm_over_cold']:.0%})")
    print(f"cache-warm first dispatch <=25% of cold: {c['meets_25pct']}")
