"""Fig. 12 reproduction: adaptivity across an application sequence.

Runs blackscholes -> facesim -> dedup (highest / lowest / median load, 100
intervals each, §4.5) through ReSiPI and PROWAVES; records per-interval
latency, power, active gateways (ReSiPI) and wavelengths (PROWAVES), and
measures the adaptation time after each switch. The paper reports ReSiPI
settling within ~3 intervals while PROWAVES stays unstable for ~5.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import traffic
from repro.core.simulator import Arch, SimConfig, simulate
from benchmarks.common import save_json

SEQUENCE = ("blackscholes", "facesim", "dedup")


def settle_time(series: np.ndarray, start: int, window: int = 30,
                tol: float = 0.5) -> int:
    """Intervals after `start` until the series stays within +-tol of its
    eventual steady value for 3 consecutive intervals."""
    steady = np.median(series[start + window // 2: start + window])
    run = 0
    for i in range(start, min(start + window, len(series))):
        if abs(series[i] - steady) <= tol:
            run += 1
            if run >= 3:
                return max(i - start - 2, 1)
        else:
            run = 0
    return window


def run(per_app: int = 100, seed: int = 3) -> dict:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(SEQUENCE))
    tr = traffic.concat_traces([
        traffic.generate_trace(app, per_app, k)
        for app, k in zip(SEQUENCE, keys)])

    res = simulate(tr, SimConfig().with_arch(Arch.RESIPI))["records"]
    pro = simulate(tr, SimConfig().with_arch(Arch.PROWAVES))["records"]

    g_total = np.asarray(res["g"]).sum(axis=1) + 2      # + memory gateways
    lam = np.asarray(pro["wavelengths"]).mean(axis=1)

    switches = [per_app, 2 * per_app]
    adapt = {
        # first switch (blackscholes -> facesim) is the one §4.5 quantifies:
        # "ReSiPI adapts within three reconfiguration intervals only,
        # whereas PROWAVES is unstable for five".
        "resipi_settle": [settle_time(g_total, s) for s in switches],
        "prowaves_settle": [settle_time(lam, s) for s in switches],
    }
    result = {
        "latency_resipi": np.asarray(res["latency"]).tolist(),
        "latency_prowaves": np.asarray(pro["latency"]).tolist(),
        "power_resipi": np.asarray(res["power_mw"]).tolist(),
        "power_prowaves": np.asarray(pro["power_mw"]).tolist(),
        "gateways_resipi": g_total.tolist(),
        "wavelengths_prowaves": lam.tolist(),
        "adaptation": adapt,
        "paper": {"resipi_settle": 3, "prowaves_settle": 5,
                  "max_gateways": 18},
        "max_gateways_used": int(g_total.max()),
    }
    save_json("fig12.json", result)
    return result


if __name__ == "__main__":
    r = run()
    print(f"ReSiPI settle times after switches: "
          f"{r['adaptation']['resipi_settle']} (paper ~3)")
    print(f"PROWAVES settle times: {r['adaptation']['prowaves_settle']} "
          f"(paper ~5)")
    print(f"max gateways used during blackscholes: "
          f"{r['max_gateways_used']} (paper: 18)")
