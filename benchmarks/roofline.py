"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_dev / peak_bf16
    memory     = HLO_bytes_per_dev / HBM_bw
    collective = wire_bytes_per_dev / ICI_link_bw

plus MODEL_FLOPS (6 N D train / 2 N D prefill / 2 N B decode, N = active
params), the useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat and
padding waste), the dominant term, and the roofline fraction

    rf = ideal_compute_time / max(term)   where
    ideal = MODEL_FLOPS / (chips * peak)

— the MFU upper bound this program could reach on the target mesh. Emits a
markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import get_config, shape_by_name
from repro.core.constants import TPU

RESULTS = Path(__file__).resolve().parent / "results"


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = shape_by_name(shape)
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch          # decode: one token per seq


def analyze(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    ndev = rec["n_devices"]
    # trip-count-corrected static analysis (launch/hlo_analysis.py);
    # rec["cost"] keeps XLA's raw numbers (which count while bodies once)
    cor = rec.get("corrected")
    if cor:
        fl = cor["flops_per_device"]
        by = cor["bytes_per_device"]
    else:
        fl = rec["cost"]["flops_per_device"]
        by = rec["cost"]["bytes_accessed_per_device"]
    wire = rec["collectives"]["total_wire_bytes"]

    t_compute = fl / TPU.peak_bf16_flops
    t_memory = by / TPU.hbm_bytes_per_s
    t_coll = wire / TPU.ici_bytes_per_s_per_link

    mf = model_flops(arch, shape)
    hlo_total = fl * ndev
    useful = mf / hlo_total if hlo_total > 0 else 0.0
    ideal = mf / (ndev * TPU.peak_bf16_flops)
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    rf = ideal / t_bound if t_bound > 0 else 0.0
    return {"arch": arch, "shape": shape, "mesh": rec["mesh"],
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf, "useful_ratio": useful,
            "roofline_fraction": rf,
            "peak_gib": rec["memory"]["peak_per_device"] / 2**30}


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "memory":
        if row["useful_ratio"] < 0.5:
            return ("memory-bound with low useful-FLOP ratio: cut remat "
                    "recompute / fuse the SSD-or-attention intermediates "
                    "(Pallas kernel keeps the O(Q^2) block in VMEM)")
        return ("memory-bound: raise arithmetic intensity — larger "
                "microbatch per chip, fuse elementwise chains, bf16 "
                "optimizer state reads")
    if d == "collective":
        return ("collective-bound: reshard to cut wire bytes (reduce-"
                "scatter instead of all-reduce, keep activations sharded "
                "through norms/embedding), or widen ReSiPI lanes to "
                "overlap chunks with compute")
    return ("compute-bound: already near the right wall — check "
            "useful_ratio for padding waste (uneven head sharding)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default=str(RESULTS / "dryrun.json"))
    ap.add_argument("--mesh", default="16x16",
                    help="roofline table mesh (single-pod per the brief)")
    ap.add_argument("--out", default=str(RESULTS / "roofline.json"))
    ap.add_argument("--md", default=str(RESULTS / "roofline.md"))
    args = ap.parse_args()

    data = json.loads(Path(args.dryrun).read_text())
    rows = []
    for key, rec in sorted(data.items()):
        if rec["status"] != "ok" or rec["mesh"] != args.mesh:
            continue
        rows.append(analyze(rec))

    Path(args.out).write_text(json.dumps(rows, indent=1))

    md = ["| arch | shape | compute s | memory s | coll s | dominant | "
          "useful | RF | peak GiB | what moves the dominant term |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['peak_gib']:.2f} "
            f"| {suggestion(r)} |")
    Path(args.md).write_text("\n".join(md))

    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"RF={r['roofline_fraction']:.3f} useful={r['useful_ratio']:.2f} "
              f"peak={r['peak_gib']:.1f}GiB")
    print(f"\nwrote {args.out} and {args.md}")


if __name__ == "__main__":
    main()
