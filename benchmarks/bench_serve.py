"""Continuous-batching session-server benchmark (BENCH_serve.json).

Three phases over the same `SessionServer`, mirroring the robustness
story end to end:

  * nominal — a steady bursty multi-tenant mix below capacity: sustained
    sessions/sec and intervals/sec, p50/p99 dispatch wall latency, zero
    shed, and the whole run on ONE compiled executable;
  * overload — a burst far over queue capacity: the server sheds by
    policy (bounded queue — max observed depth never exceeds capacity),
    enters coalesced degraded mode, drains, and exits degraded mode;
  * fault storm — routers under half the live gateways die mid-serve
    with the closed-loop healer on: detection/heal tick, availability
    recovery inside the band, the PCM bill, and ZERO healthy sessions
    dropped.

Every phase ends with the acceptance-criterion audit: each completed
session's accumulated sums bit-match a standalone `SimSession` replay of
the same chunks/placements/frames (`replay_standalone`) — continuous
batching, shedding, degradation, and healing never cost fidelity.

Results land in benchmarks/results/BENCH_serve.json with an appended
commit-stamped `history` entry per run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, traffic
from repro.core.simulator import (clear_engine_caches, engine_stats,
                                  reset_engine_stats)
from repro.serve.engine import SessionServer, replay_standalone
from repro.serve.policies import PRIORITY_CLASSES, ServerPolicy
from repro.serve.resilience import ResiliencePolicy
from repro.serve.scheduler import SessionRequest
from benchmarks.common import fixed_gateway_config, save_json_history

CHUNK = 8
BAND = 0.10
PARITY_KEYS = ("mean_latency", "mean_power_mw", "mean_energy",
               "valid_intervals")


def _mk_trace(rng, t: int, scale: float = 1.0) -> dict:
    apps = ("dedup", "canneal", "streamcluster")
    tr = traffic.generate_trace(apps[int(rng.integers(len(apps)))], t,
                                jax.random.PRNGKey(int(rng.integers(1 << 30))))
    if scale != 1.0:
        for k in ("ext_load", "mem_load", "int_load"):
            tr[k] = jnp.asarray(tr[k]) * scale
    return tr


def _arrivals(rng, rate: float, burst_at: int = -1, burst_size: int = 0,
              t_lo: int = 8, t_hi: int = 24):
    def gen(now):
        n = int(rng.poisson(rate)) + (burst_size if now == burst_at else 0)
        return [SessionRequest(
            trace=_mk_trace(rng, int(rng.integers(t_lo, t_hi + 1))),
            priority=PRIORITY_CLASSES[int(rng.choice(
                3, p=[0.50, 0.35, 0.15]))])
            for _ in range(n)]
    return gen


def _parity_audit(sim, server, limit: int = 16) -> dict:
    """Bit-compare completed sessions against their standalone replay."""
    checked = ok = 0
    for sess in server.completed[:limit]:
        ref = replay_standalone(sim, sess)
        mine = sess.summary()
        checked += 1
        ok += all(float(ref[k]) == mine[k] for k in PARITY_KEYS)
    return {"parity_checked": checked, "parity_ok": ok,
            "parity_clean": checked == ok}


def _nominal(sim, seed: int) -> dict:
    """Steady mix below capacity: throughput + latency percentiles."""
    server = SessionServer(sim, ServerPolicy(
        lanes=4, chunk_intervals=CHUNK, queue_capacity=16))
    rng = np.random.default_rng(seed)
    reset_engine_stats()
    t0 = time.perf_counter()
    server.run(16, arrivals=_arrivals(rng, rate=1.0))
    server.drain()
    wall = time.perf_counter() - t0
    m = server.metrics()
    served_intervals = sum(s.served_intervals for s in server.completed)
    return {
        "ticks": m["ticks"],
        "submitted": m["submitted"],
        "completed": m["completed"],
        "shed_total": m["shed_queue_full"] + m["shed_memory"]
        + m["shed_priority"],
        "sessions_per_s": m["completed"] / wall,
        "intervals_per_s": served_intervals / wall,
        "p50_chunk_s": m["p50_chunk_s"],
        "p99_chunk_s": m["p99_chunk_s"],
        "scan_body_traces": engine_stats()["simulate_traces"],
        **_parity_audit(sim, server),
    }


def _overload(sim, seed: int) -> dict:
    """A burst 3x queue capacity: shed by policy, degrade, recover."""
    policy = ServerPolicy(lanes=4, chunk_intervals=CHUNK, queue_capacity=8,
                          degrade_hi=0.5, degrade_lo=0.25,
                          degrade_patience=2, degrade_coalesce=2)
    server = SessionServer(sim, policy)
    rng = np.random.default_rng(seed + 1)
    server.run(20, arrivals=_arrivals(rng, rate=1.5, burst_at=4,
                                      burst_size=3 * policy.queue_capacity))
    server.drain()
    server.run(2 * policy.degrade_patience)    # let the hysteresis unlatch
    m = server.metrics()
    depths = [e["queue_depth"] for e in server.events]
    return {
        "submitted": m["submitted"],
        "completed": m["completed"],
        "shed_queue_full": m["shed_queue_full"],
        "shed_priority": m["shed_priority"],
        "displaced": m["displaced"],
        "max_queue_depth": max(depths),
        "queue_bounded": max(depths) <= policy.queue_capacity,
        "degraded_ticks": m["degraded_ticks"],
        "coalesced_dispatches": m["coalesced_dispatches"],
        "recovered_from_degraded": not server.degraded,
        "accounted": m["completed"] + m["shed_queue_full"]
        + m["shed_memory"] + m["shed_priority"] + m["deadline_expired"]
        + m["retry_exhausted"] == m["submitted"],
        **_parity_audit(sim, server),
    }


def _storm(sim, seed: int) -> dict:
    """Fault storm mid-serve with the closed-loop healer: availability
    recovers, zero healthy sessions drop."""
    policy = ServerPolicy(lanes=2, chunk_intervals=CHUNK, queue_capacity=4)
    victims = SessionServer(sim, policy).placement[:2]
    t_total, storm_t0 = 96, 32
    env = faults.FaultInjector(
        [faults.GatewayFault(start=storm_t0, position=p) for p in victims],
        4 * t_total, seed=seed)
    server = SessionServer(
        sim, policy, fault_env=env,
        resilience=ResiliencePolicy(threshold_frac=BAND, hysteresis=2,
                                    cooldown=1, search_generations=8,
                                    search_population=8, search_seed=seed))
    # x2-load dedup streams: the calibrated storm workload (losing half
    # the pinned gateways is a real capacity loss; see
    # tests/test_resilience.py) without app-mix latency noise.
    for i in range(policy.lanes):
        tr = traffic.generate_trace("dedup", t_total, jax.random.PRNGKey(i))
        for k in ("ext_load", "mem_load", "int_load"):
            tr[k] = jnp.asarray(tr[k]) * 2.0
        server.submit(SessionRequest(trace=tr))
    t0 = time.perf_counter()
    server.drain()
    wall = time.perf_counter() - t0
    m = server.metrics()
    heal_tick = next((e["tick"] for e in server.events if e.get("healed")),
                     None)
    storm_tick = storm_t0 // CHUNK
    post_heal = [e for e in server.events
                 if heal_tick is not None and e["tick"] > heal_tick
                 and e["latency"] is not None]
    recovery_tick = next((e["tick"] for e in post_heal if not e["breach"]),
                         None)
    return {
        "storm_tick": storm_tick,
        "heal_tick": heal_tick,
        "detection_latency_ticks":
            None if heal_tick is None else heal_tick - storm_tick,
        "recovery_time_ticks":
            None if recovery_tick is None else recovery_tick - storm_tick,
        "heals": m["heals"],
        "availability": m["availability"],
        "recovered_within_band": recovery_tick is not None,
        "healed_off_victims": not (set(server.placement) & set(victims)),
        "total_pcm_nj": m["total_pcm_nj"],
        "total_stall_cycles": m["total_stall_cycles"],
        "healthy_dropped": m["admitted"] - m["completed"],
        "wall_s": wall,
        **_parity_audit(sim, server),
    }


def run(seed: int = 0) -> dict:
    sim = fixed_gateway_config(4)
    clear_engine_caches()
    result = {
        "nominal": _nominal(sim, seed),
        "overload": _overload(sim, seed),
        "storm": _storm(sim, seed),
        "chunk": CHUNK,
        "band_frac": BAND,
    }
    save_json_history("BENCH_serve.json", result)
    return result


if __name__ == "__main__":
    r = run()
    n, o, s = r["nominal"], r["overload"], r["storm"]
    print(f"nominal: {n['completed']}/{n['submitted']} sessions, "
          f"{n['sessions_per_s']:.1f} sessions/s "
          f"({n['intervals_per_s']:.0f} intervals/s), chunk wall "
          f"p50={n['p50_chunk_s'] * 1e3:.2f}ms "
          f"p99={n['p99_chunk_s'] * 1e3:.2f}ms, "
          f"{n['scan_body_traces']} scan-body trace(s), shed "
          f"{n['shed_total']}, parity {n['parity_ok']}/{n['parity_checked']}")
    print(f"overload: {o['submitted']} submitted -> {o['completed']} "
          f"completed, shed {o['shed_queue_full']}+{o['shed_priority']} "
          f"(displaced {o['displaced']}), max queue depth "
          f"{o['max_queue_depth']} (bounded={o['queue_bounded']}), "
          f"{o['degraded_ticks']} degraded ticks / "
          f"{o['coalesced_dispatches']} coalesced, "
          f"recovered={o['recovered_from_degraded']}, "
          f"accounted={o['accounted']}")
    print(f"storm: onset tick {s['storm_tick']}, healed at "
          f"{s['heal_tick']} ({s['heals']} heal(s)), availability "
          f"{s['availability']:.0%}, recovered_within_band="
          f"{s['recovered_within_band']}, off_victims="
          f"{s['healed_off_victims']}, dropped {s['healthy_dropped']} "
          f"healthy, bill {s['total_pcm_nj']:.0f} nJ, parity "
          f"{s['parity_ok']}/{s['parity_checked']}")
