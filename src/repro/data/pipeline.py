"""Synthetic token data pipeline (host-sharded, deterministic, restartable).

Produces LM batches with a compressible synthetic distribution (Zipf-ish
unigram mixture + local repetition) so a ~100M model shows a real, visibly
decreasing loss in the end-to-end example. Each host generates only its
addressable slice (`host_slice`), keyed by (seed, step) so restarts resume
the exact stream position — no data-state checkpointing needed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    repeat_p: float = 0.3       # local bigram repetition (learnable signal)
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        v = cfg.real_vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-dcfg.zipf_a)
        self.probs = (probs / probs.sum()).astype(np.float64)

    def host_slice(self, step: int, host: int = 0, host_count: int = 1
                   ) -> Dict[str, np.ndarray]:
        d = self.dcfg
        per_host = d.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, host]))
        v = self.cfg.real_vocab
        toks = rng.choice(v, size=(per_host, d.seq_len + 1), p=self.probs)
        # local repetition: with prob repeat_p, copy the previous token —
        # a first-order structure the model can learn (loss < unigram H).
        rep = rng.random((per_host, d.seq_len)) < d.repeat_p
        toks[:, 1:][rep] = toks[:, :-1][rep]
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.family == "vlm":
            batch["image_embeds"] = rng.standard_normal(
                (per_host, self.cfg.frontend_embeds, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        if self.cfg.family == "encdec":
            # frames correlated with labels so cross-attention is learnable
            emb = rng.standard_normal((v, self.cfg.d_model)) * 0.02
            batch["frames"] = emb[batch["labels"]].astype(np.float32)
        return batch

    def iter_batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.host_slice(step, jax.process_index(),
                                  jax.process_count())
            step += 1
