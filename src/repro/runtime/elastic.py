"""Elastic re-meshing: resume a run on a different device count.

Checkpoints are mesh-agnostic (full logical arrays per entry, written
shard-wise), so scaling from 2 pods to 1 (node loss) or 1 to 2 (scale-up)
is: build the new mesh -> rebuild shardings from the same Rules -> restore
with device_put onto the new shardings. The batch schedule is rescaled to
keep the global batch constant (synchronous data parallelism is preserved;
see DESIGN.md fault-tolerance notes).
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import Rules
from repro.models.params import partition_specs
from repro.checkpoint import ckpt


def replan_mesh(multi_pod: bool):
    """(mesh, rules) for the surviving topology."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    return mesh, Rules(mesh)


def restore_elastic(model, directory: str, multi_pod: bool,
                    step: Optional[int] = None) -> Any:
    """Restore train state onto the current topology's shardings."""
    from repro.train.train_step import (abstract_train_state, state_pspecs)
    from repro.launch.dryrun import to_shardings  # spec->NamedSharding
    mesh, rules = replan_mesh(multi_pod)
    like = abstract_train_state(model)
    shardings = to_shardings(state_pspecs(model, rules), mesh)
    return ckpt.restore_checkpoint(like, directory, step=step,
                                   shardings=shardings), mesh, rules


def rescale_batch(global_batch: int, old_dp: int, new_dp: int) -> dict:
    """Keep the global batch fixed across re-meshing: adjust per-replica
    microbatch and gradient-accumulation so optimization is bit-for-bit
    schedule-compatible after elastic restart."""
    assert global_batch % new_dp == 0, (global_batch, new_dp)
    per_replica_old = global_batch // old_dp
    per_replica_new = global_batch // new_dp
    accum = max(1, per_replica_new // max(per_replica_old, 1))
    return {"per_replica_batch": per_replica_new,
            "grad_accum": accum,
            "note": "global batch preserved; LR schedule unchanged"}
