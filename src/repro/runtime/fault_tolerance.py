"""Fault tolerance & straggler mitigation for multi-pod runs.

Mechanisms (scaled for 1000+ nodes; exercised single-host in tests):

  * `Heartbeat` — per-step liveness watermarking. A step that exceeds
    `timeout_factor` x the EWMA step time marks the run DEGRADED; the
    launcher's supervisor (launch/train.py) checkpoints and exits nonzero
    so the cluster scheduler can reschedule (checkpoint/restart model).
  * `StepGuard` — NaN/inf loss + grad-norm spike detection with
    skip-and-continue (bounded by `max_skips`), the standard large-run
    guard against data poison and transient hardware SDC.
  * `StragglerMonitor` — epoch-level per-"gateway" (pod) step-time stats;
    persistent stragglers trigger a *lane reconfiguration* through the
    ReSiPI controller (reduce lanes crossing the slow pod) rather than a
    full restart — the paper's reconfiguration applied to failure handling.
  * `elastic.replan` — remap a saved (mesh-agnostic) checkpoint onto a
    smaller/larger mesh after node loss (uses checkpoint resharding).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Heartbeat:
    timeout_factor: float = 5.0
    ewma: float = 0.3
    _mean: Optional[float] = None
    degraded: bool = False

    def beat(self, step_seconds: float) -> bool:
        """Record one step; returns True if the run looks healthy."""
        if self._mean is None:
            self._mean = step_seconds
            return True
        if step_seconds > self.timeout_factor * self._mean:
            self.degraded = True
        self._mean = (1 - self.ewma) * self._mean + self.ewma * step_seconds
        return not self.degraded


@dataclasses.dataclass
class StepGuard:
    max_skips: int = 10
    grad_spike_factor: float = 50.0
    skips: int = 0
    _gnorm_ewma: Optional[float] = None

    def check(self, loss: float, grad_norm: float) -> bool:
        """True = apply the step; False = skip it (and count)."""
        bad = not np.isfinite(loss) or not np.isfinite(grad_norm)
        if self._gnorm_ewma is not None and grad_norm > \
                self.grad_spike_factor * self._gnorm_ewma:
            bad = True
        if not bad:
            g = max(grad_norm, 1e-12)
            self._gnorm_ewma = g if self._gnorm_ewma is None else \
                0.9 * self._gnorm_ewma + 0.1 * g
            return True
        self.skips += 1
        if self.skips > self.max_skips:
            raise RuntimeError(
                f"StepGuard: {self.skips} bad steps — aborting for restart")
        return False


@dataclasses.dataclass
class StragglerMonitor:
    """Per-pod step-time tracking; feeds the lane controller (Level 2).

    A pod whose epoch-mean step time exceeds `threshold` x the fleet median
    is flagged; the runtime responds by *narrowing lanes* that cross it
    (reconfiguration, cheap) and only escalates to checkpoint/restart if
    the pod stays slow for `escalate_after` epochs.
    """
    n_pods: int = 2
    threshold: float = 1.3
    escalate_after: int = 3
    _times: Optional[list] = None
    _slow_epochs: Optional[np.ndarray] = None

    def __post_init__(self):
        self._times = [[] for _ in range(self.n_pods)]
        self._slow_epochs = np.zeros(self.n_pods, np.int32)

    def record(self, pod: int, step_seconds: float):
        self._times[pod].append(step_seconds)

    def epoch_verdict(self) -> dict:
        means = np.array([np.mean(t) if t else 0.0 for t in self._times])
        self._times = [[] for _ in range(self.n_pods)]
        med = np.median(means[means > 0]) if (means > 0).any() else 0.0
        slow = (means > self.threshold * med) & (med > 0)
        self._slow_epochs = np.where(slow, self._slow_epochs + 1, 0)
        return {
            "pod_means": means,
            "slow_pods": np.nonzero(slow)[0].tolist(),
            "narrow_lanes_for": np.nonzero(slow)[0].tolist(),
            "escalate": np.nonzero(
                self._slow_epochs >= self.escalate_after)[0].tolist(),
        }
