"""Cold-start elimination: persistent compilation cache + AOT entry points.

Every fresh process pays ~0.6-2.3 s of XLA compiles per engine entry point
before its first sweep returns. This module removes that wall twice over:

  * `enable_persistent_cache` wires jax's persistent compilation cache
    (`jax_compilation_cache_dir`) to a shared directory, with thresholds
    opened up so every engine executable is cached — a fleet worker whose
    sibling (or yesterday's run) compiled the same (config, shape) serves
    its first dispatch from disk instead of XLA;
  * `aot_compile` lowers a hot entry point ahead of time
    (`jax.jit(...).lower(...).compile()`) and memoizes the compiled
    executable keyed on (entry, config, input shapes/dtypes), so serving
    paths can pin an executable explicitly and tests can assert
    AOT-vs-jit parity. With the persistent cache enabled the compiled
    executable is ALSO serialized to disk
    (`jax.experimental.serialize_executable`), so a later process's
    `aot_compile` skips tracing entirely — the jit-level persistent cache
    removes XLA compile time but still re-traces; the serialized
    executable removes both;
  * `warmup` runs selected public entry points once on representative
    inputs (blocking), which both fills the in-process jit caches and
    populates the persistent cache for every process that follows.

All helpers are single-host no-risk: nothing here changes numerics (the
cache is keyed on the exact HLO) and everything degrades to plain jit.
"""
from __future__ import annotations

import hashlib
import logging
import os
import pathlib
import pickle
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

log = logging.getLogger("repro.runtime.cache")

ENV_CACHE_DIR = "REPRO_CACHE_DIR"
_DEFAULT_DIR = "~/.cache/repro-jax-cache"

_CACHE = {"dir": None}
_AOT: Dict[tuple, "AotEntry"] = {}

#: Entry points `aot_compile` / `warmup` know how to lower.
AOT_ENTRY_POINTS = ("simulate", "sweep", "sweep_topology", "session_tick",
                    "search")


# ---------------------------------------------------------------------------
# Persistent compilation cache
# ---------------------------------------------------------------------------

def enable_persistent_cache(cache_dir: Optional[str] = None) -> pathlib.Path:
    """Point jax's persistent compilation cache at `cache_dir` (created if
    missing; default $REPRO_CACHE_DIR or ~/.cache/repro-jax-cache).

    The min-compile-time and min-entry-size thresholds are opened up so
    every engine executable lands in the cache — the whole point is
    eliminating sub-second cold compiles, which the defaults skip.
    Idempotent; returns the resolved directory.
    """
    path = pathlib.Path(
        cache_dir or os.environ.get(ENV_CACHE_DIR, _DEFAULT_DIR)
    ).expanduser()
    path.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:  # newer jax: also gate on entry size; -1 = cache everything
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:  # pragma: no cover - jax version dependent
        pass
    _CACHE["dir"] = path
    log.info("persistent compilation cache at %s", path)
    return path


def cache_dir() -> Optional[pathlib.Path]:
    """The enabled cache directory (None before enable_persistent_cache)."""
    return _CACHE["dir"]


def persistent_cache_stats(path=None) -> dict:
    """Entry count + total bytes of the persistent cache directory
    (includes the serialized AOT executables under its aot/ subdir)."""
    path = pathlib.Path(path).expanduser() if path is not None \
        else _CACHE["dir"]
    if path is None or not pathlib.Path(path).is_dir():
        return {"enabled": _CACHE["dir"] is not None, "dir": None,
                "entries": 0, "bytes": 0}
    files = [f for f in pathlib.Path(path).rglob("*") if f.is_file()]
    return {"enabled": _CACHE["dir"] is not None, "dir": str(path),
            "entries": len(files),
            "bytes": int(sum(f.stat().st_size for f in files))}


# ---------------------------------------------------------------------------
# AOT lowering of hot entry points
# ---------------------------------------------------------------------------

class AotEntry:
    """One AOT-compiled engine entry point.

    Calling it rebuilds the device arrays exactly like the public entry
    point and launches the pre-compiled executable — same inputs, same
    results (parity pinned by tests/test_runtime_cache.py), zero compile
    on the call path.
    """

    def __init__(self, entry: str, key: tuple, compiled,
                 build: Callable[..., tuple]):
        self.entry = entry
        self.key = key
        self.compiled = compiled
        self._build = build

    def __call__(self, *args, **kw):
        return self.compiled(*self._build(*args, **kw))

    def __repr__(self):
        return f"AotEntry({self.entry}, shapes={self.key[-1]})"


def _shape_key(args) -> tuple:
    return tuple(
        (tuple(np.shape(leaf)), str(np.asarray(leaf).dtype))
        for leaf in jax.tree.leaves(args))


def _grid_key(grids: dict) -> tuple:
    out = []
    for name in sorted(grids):
        v = grids[name]
        if name == "gateway_positions":
            out.append((name, tuple(None if p is None else tuple(map(tuple, p))
                                    for p in v)))
        else:
            out.append((name, tuple(np.asarray(v).reshape(-1).tolist())))
    return tuple(out)


def _param_key(kw: dict) -> tuple:
    """Hashable memo key for the "search" entry's mixed kwargs (ints,
    floats, grid lists, the nested knob_grids dict)."""
    def leaf(v):
        if v is None:
            return None
        if isinstance(v, dict):
            return tuple((k, leaf(v[k])) for k in sorted(v))
        if np.ndim(v) > 0:
            return tuple(np.asarray(v).reshape(-1).tolist())
        return v
    return tuple((name, leaf(kw[name])) for name in sorted(kw))


def _builders():
    """entry name -> (args_builder, jit_fn). The builder reproduces the
    public entry point's preprocessing so the compiled call is fed
    identically-shaped operands."""
    from repro.core import simulator as S

    def b_simulate(trace, sim):
        ext, mem, intra, ext_frac, t_mask, dest = S._trace_arrays(trace)
        return (ext, mem, intra, ext_frac, t_mask,
                S.selection_tables_jax(sim.cfg), dest)

    def b_sweep(trace, sim, **fields):
        ext, mem, intra, ext_frac, t_mask, dest = S._trace_arrays(trace)
        import jax.numpy as jnp
        ov = {f: jnp.asarray(v) for f, v in fields.items()}
        return (ext, mem, intra, ext_frac, t_mask,
                S.selection_tables_jax(sim.cfg), ov, dest)

    def b_sweep_topology(trace, sim, **grids):
        sim_p, topo, ov, c_max = S._prepare_topology_sweep(sim, grids)
        ext, mem, intra, ext_frac, t_mask, dest = S._topo_trace_arrays(
            trace, c_max)
        return (ext, mem, intra, ext_frac, t_mask, topo, ov, dest), sim_p

    def b_session_tick(states, batch, tables, sim):
        import jax.numpy as jnp
        dest = batch.get("dest")
        return (states, jnp.asarray(batch["ext_load"]),
                jnp.asarray(batch["mem_load"]),
                jnp.asarray(batch["int_load"]),
                jnp.asarray(batch["ext_frac"]),
                jnp.asarray(batch["t_mask"], jnp.float32), tables,
                None if dest is None else jnp.asarray(dest, jnp.float32))

    def b_search(trace, sim, **kw):
        from repro.core import pareto
        built, statics, _info = pareto._codesign_operands(trace, sim, **kw)
        return built, statics

    from repro.core import pareto as _pareto
    return {"simulate": (b_simulate, S._simulate_jit),
            "sweep": (b_sweep, S._sweep_jit),
            "sweep_topology": (b_sweep_topology, S._sweep_topology_jit),
            "session_tick": (b_session_tick, S._session_tick_jit),
            "search": (b_search, _pareto._codesign_jit)}


def _persist_path(key: tuple) -> Optional[pathlib.Path]:
    """Disk slot for a serialized AOT executable (None when the persistent
    cache is off). Keyed on the same (entry, config, grids, shapes) tuple
    as the in-process memo — `repr` of frozen dataclasses is stable."""
    d = _CACHE["dir"]
    if d is None:
        return None
    digest = hashlib.sha256(repr(key).encode()).hexdigest()[:32]
    return pathlib.Path(d) / "aot" / f"{key[0]}-{digest}.bin"


def _load_persisted(path: pathlib.Path):
    from jax.experimental import serialize_executable

    blob, in_tree, out_tree = pickle.loads(path.read_bytes())
    return serialize_executable.deserialize_and_load(blob, in_tree, out_tree)


def _persist(path: pathlib.Path, compiled) -> None:
    from jax.experimental import serialize_executable

    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(pickle.dumps(serialize_executable.serialize(compiled)))


def aot_compile(entry: str, *args, **kw) -> AotEntry:
    """AOT-lower one engine entry point for these exact (config, shapes).

    ::

        exe = aot_compile("simulate", trace, sim)
        out = exe(trace, sim)            # no compile, jit-parity results

    Entries: "simulate" (trace, sim), "sweep" (trace, sim, **fields),
    "sweep_topology" (trace, sim, **grids), "session_tick" (states, batch,
    tables, sim), "search" (trace, sim, **search_codesign kwargs — the
    Pareto co-design dispatch, so a fleet worker's first `search_codesign`
    skips tracing + XLA). Compiled executables are memoized on (entry, sim config,
    grid values, input shapes/dtypes) — a second call with a same-shaped
    trace returns the cached handle. Compiles go through the persistent
    cache when `enable_persistent_cache` is on, so AOT warmup in one
    process is compile-free in the next.
    """
    builders = _builders()
    if entry not in builders:
        raise ValueError(f"unknown AOT entry point {entry!r} "
                         f"(choose from {AOT_ENTRY_POINTS})")
    build, jit_fn = builders[entry]

    if entry == "sweep_topology":
        trace, sim = args
        built, sim_static = build(trace, sim, **kw)
        lower_kw = {"sim": sim_static}
        key = (entry, sim, _grid_key(kw), _shape_key(built))
        rebuild = lambda tr, sm, **g: build(tr, sm, **g)[0]
    elif entry == "search":
        trace, sim = args
        built, lower_kw = build(trace, sim, **kw)
        key = (entry, sim, _param_key(kw), _shape_key(built))
        rebuild = lambda tr, sm, **k: build(tr, sm, **k)[0]
    elif entry == "session_tick":
        states, batch, tables, sim = args
        built = build(states, batch, tables, sim)
        lower_kw = {"sim": sim}
        key = (entry, sim, (), _shape_key(built))
        rebuild = build
    else:
        sim = args[1]
        built = build(*args, **kw)
        lower_kw = {"sim": sim}
        key = (entry, sim, _grid_key(kw), _shape_key(built))
        rebuild = build

    hit = _AOT.get(key)
    if hit is not None:
        return hit
    path = _persist_path(key)
    if path is not None and path.exists():
        try:  # serialized executable: no tracing, no XLA — the warm path
            t0 = time.perf_counter()
            compiled = _load_persisted(path)
            log.info("AOT-loaded %s from %s in %.3fs", entry, path.name,
                     time.perf_counter() - t0)
            exe = AotEntry(entry, key, compiled, rebuild)
            _AOT[key] = exe
            return exe
        except Exception as e:  # stale/foreign blob: recompile below
            log.warning("could not load persisted AOT %s (%r); recompiling",
                        path.name, e)
    t0 = time.perf_counter()
    compiled = jit_fn.lower(*built, **lower_kw).compile()
    log.info("AOT-compiled %s in %.3fs (key shapes: %d operands)",
             entry, time.perf_counter() - t0, len(jax.tree.leaves(built)))
    if path is not None:
        try:
            _persist(path, compiled)
        except Exception as e:  # pragma: no cover - serialization support
            log.warning("could not persist AOT %s (%r)", entry, e)
    exe = AotEntry(entry, key, compiled, rebuild)
    _AOT[key] = exe
    return exe


def aot_cache_stats() -> dict:
    """Per-entry count of memoized AOT executables."""
    out: Dict[str, int] = {}
    for key in _AOT:
        out[key[0]] = out.get(key[0], 0) + 1
    return {"entries": len(_AOT), "by_entry": out}


def clear_aot_cache() -> None:
    _AOT.clear()


# ---------------------------------------------------------------------------
# Warmup
# ---------------------------------------------------------------------------

def warmup(sim, *, trace: Optional[dict] = None, n_intervals: int = 16,
           entries: Tuple[str, ...] = ("simulate", "sweep_topology"),
           grids: Optional[dict] = None, seed: int = 0) -> dict:
    """Run public entry points once, blocking: fills this process's jit
    caches AND the persistent cache for every process that follows.

    Pass the `trace` (and `grids` for "sweep_topology"/"sweep") your real
    workload will use — compilation caches key on exact shapes, so warming
    with representative shapes is what makes the real first dispatch free.
    Returns {entry: seconds} wall times (compile-inclusive).
    """
    from repro.core import simulator as S
    from repro.core import traffic

    if trace is None:
        trace = traffic.generate(
            traffic.UniformSpec(n_intervals=n_intervals),
            jax.random.PRNGKey(seed), sim.cfg)
    walls = {}
    for entry in entries:
        t0 = time.perf_counter()
        if entry == "simulate":
            out = S.simulate(trace, sim)
        elif entry == "sweep":
            fields = grids or {"l_m": [0.01]}
            out = S.sweep(trace, sim, **fields)
        elif entry == "sweep_topology":
            g = grids or {"n_chiplets": [sim.cfg.n_chiplets]}
            out = S.sweep_topology(trace, sim, **g)
        elif entry == "session_tick":
            states = S.init_session_states(sim, 1)
            ext = np.asarray(trace["ext_load"], np.float32)[None]
            batch = {"ext_load": ext,
                     "mem_load": np.asarray(
                         trace["mem_load"], np.float32)[None],
                     "int_load": np.asarray(
                         trace["int_load"], np.float32)[None],
                     "ext_frac": np.asarray(
                         [trace["ext_frac"]], np.float32),
                     "t_mask": np.ones(ext.shape[:2], np.float32)}
            out = S.session_tick(states, batch,
                                 S.selection_tables_jax(sim.cfg), sim)
        elif entry == "search":
            from repro.core import pareto

            g = grids or {"n_chiplets": [sim.cfg.n_chiplets]}
            out = pareto.search_codesign(trace, sim, islands=2,
                                         generations=2, population=2, **g)
            out = out["island_scores"]
        else:
            raise ValueError(f"unknown warmup entry {entry!r} "
                             f"(choose from {AOT_ENTRY_POINTS})")
        jax.block_until_ready(out)
        walls[entry] = time.perf_counter() - t0
    log.info("warmup: %s", {k: f"{v:.3f}s" for k, v in walls.items()})
    return walls
