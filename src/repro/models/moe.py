"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort-based
dispatch, expert-parallel execution.

Dispatch is index-based (argsort + gather) rather than one-hot-einsum: a
[tokens, E, C] dispatch tensor is infeasible at 384 experts x 1M tokens,
while gather indices are O(E*C). Expert weights are sharded
experts->data (EP) and expert_ff->model (TP); the token redistribution from
batch-sharded to expert-sharded layout is the all-to-all that the ReSiPI
lane controller meters and manages at Level 2 (DESIGN.md §5).

The router also returns per-expert load statistics — the Eq. 5 'packets per
gateway' analogue — consumed by repro.core.reconfig_runtime.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.models.layers import cast
from repro.sharding.rules import shard


def moe_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    d, m = cfg.d_model, cfg.moe
    e, f = m.n_experts, m.expert_d_ff
    s = {
        "router": ParamSpec((d, e), ("model_d", None), scale=0.02),
        "wi": ParamSpec((e, d, f), ("experts", "model_d", "expert_ff"),
                        fan_in_dims=(1,)),
        "wo": ParamSpec((e, f, d), ("experts", "expert_ff", "model_d"),
                        fan_in_dims=(1,)),
    }
    if cfg.activation == "swiglu":
        s["wg"] = ParamSpec((e, d, f), ("experts", "model_d", "expert_ff"),
                            fan_in_dims=(1,))
    return s


def route_topk(logits: jax.Array, top_k: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Top-k gates per token. logits [T, E] -> (gates [T,k], experts [T,k])."""
    gates, experts = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    return gates, experts


def build_dispatch(experts: jax.Array, n_experts: int, capacity: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Capacity-bounded dispatch + combine indices.

    Args:
      experts: [T, k] int — chosen expert per (token, choice).
    Returns:
      gather_idx:  [E, C] int — token feeding each expert slot (T = empty,
        points at a zero pad row).
      choice_idx:  [E, C] int — which of the k choices that slot serves.
      combine_idx: [T, k] int — flat slot (e*C + rank) each choice landed
        in, or E*C for dropped choices (points at a zero pad row). The
        combine is therefore a pure GATHER — a scatter-add combine makes
        GSPMD all-reduce a full [T, D] buffer per layer (§Perf iter 4).
      kept: [T, k] bool — choices that fit under capacity.
    """
    t, k = experts.shape
    flat_expert = experts.reshape(-1)                          # [T*k]
    # Rank of each (token, choice) within its expert queue, in token order —
    # deterministic tie-break, same rule as the paper's per-packet FIFO.
    order = jnp.argsort(flat_expert, stable=True)              # [T*k]
    sorted_experts = flat_expert[order]
    seg_start = jnp.searchsorted(sorted_experts,
                                 jnp.arange(n_experts), side="left")
    rank_sorted = jnp.arange(t * k) - seg_start[sorted_experts]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    kept = rank < capacity

    # Dropped (over-capacity) choices are routed to an out-of-range slot so
    # the scatter discards them instead of clobbering kept entries.
    slot = flat_expert * capacity + jnp.minimum(rank, capacity - 1)
    slot = jnp.where(kept, slot, n_experts * capacity)
    token_of_flat = jnp.arange(t * k) // k
    choice_of_flat = jnp.arange(t * k) % k
    gather_idx = jnp.full((n_experts * capacity,), t, jnp.int32)
    choice_idx = jnp.zeros((n_experts * capacity,), jnp.int32)
    gather_idx = gather_idx.at[slot].set(
        token_of_flat.astype(jnp.int32), mode="drop")
    choice_idx = choice_idx.at[slot].set(
        choice_of_flat.astype(jnp.int32), mode="drop")
    combine_idx = slot.reshape(t, k).astype(jnp.int32)
    return (gather_idx.reshape(n_experts, capacity),
            choice_idx.reshape(n_experts, capacity),
            combine_idx,
            kept.reshape(t, k))


def moe_block(p, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """MoE FFN. x: [B, S, D] -> ([B, S, D], load-stats dict)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, cast(p["router"]))
    gates, experts = route_topk(logits, m.top_k)               # [T,k]

    capacity = int(m.capacity_factor * m.top_k * t / m.n_experts)
    capacity = max(capacity, m.top_k)
    gather_idx, choice_idx, combine_idx, kept = build_dispatch(
        experts, m.n_experts, capacity)

    # Gather tokens into expert-major layout: [E, C, D]. The implicit
    # batch->expert resharding here is the EP all-to-all.
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xt_pad[gather_idx]                                    # [E, C, D]
    xe = shard(xe, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", xe, cast(p["wi"]))
    if cfg.activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, cast(p["wg"]))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "experts", None, "expert_ff")
    ye = jnp.einsum("ecf,efd->ecd", h, cast(p["wo"]))          # [E, C, D]
    ye = shard(ye, "experts", None, None)

    # Combine: scatter-add expert outputs back to tokens, gate-weighted.
    # §Perf iteration 4 A/B-tested this against a pure-gather combine
    # (every token gathers its top-k slots): GSPMD lowered the gather
    # variant to MORE wire (25.3 vs 16.6 TiB/dev at kimi/train_4k) because
    # its backward is the same cross-shard scatter — the scatter-add form
    # keeps the cheaper direction in the forward pass. (The real fix is an
    # explicit shard_map all_to_all dispatch — see EXPERIMENTS.md §Perf.)
    flat_slots = gather_idx.reshape(-1)                        # [E*C] -> token
    gate_of_slot = gates[jnp.minimum(flat_slots, t - 1),
                         choice_idx.reshape(-1)]
    gate_of_slot = jnp.where(flat_slots < t, gate_of_slot, 0.0)
    yt = jnp.zeros((t + 1, d), jnp.float32).at[flat_slots].add(
        ye.reshape(-1, d).astype(jnp.float32)
        * gate_of_slot[:, None])
    y = yt[:t].reshape(b, s, d).astype(x.dtype)
    y = shard(y, "batch", "seq", None)

    # Load stats: tokens per expert (Eq. 5 numerator at Level 2) + aux loss.
    tokens_per_expert = jnp.sum(
        jax.nn.one_hot(experts, m.n_experts, dtype=jnp.float32)
        * kept[..., None], axis=(0, 1))
    me = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=0)
    ce = tokens_per_expert / jnp.maximum(jnp.sum(tokens_per_expert), 1.0)
    aux_loss = m.n_experts * jnp.sum(me * ce)
    dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
    stats = {"tokens_per_expert": tokens_per_expert,
             "aux_loss": aux_loss, "drop_frac": dropped}
    return y, stats
