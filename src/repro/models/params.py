"""Parameter specification machinery.

Model modules declare their weights as `ParamSpec` trees (shape + logical
sharding axes + init); from one spec tree we derive: random initialization,
abstract ShapeDtypeStructs (for `.lower()` without allocation), and
PartitionSpec trees (for pjit in_shardings). This keeps weight bookkeeping in
exactly one place per architecture.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import Rules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"              # normal | zeros | ones
    scale: Optional[float] = None     # stddev; None => 1/sqrt(fan_in)
    fan_in_dims: Tuple[int, ...] = (0,)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def stack_specs(tree: Any, n: int, axis_name: Optional[str] = "layers"
                ) -> Any:
    """Prepend a scanned-layer axis to every spec in the tree."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec(shape=(n,) + s.shape, axes=(axis_name,) + s.axes,
                         init=s.init, scale=s.scale,
                         fan_in_dims=tuple(d + 1 for d in s.fan_in_dims))
    return jax.tree.map(f, tree, is_leaf=is_spec)


def init_params(tree: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(s: ParamSpec, k) -> jax.Array:
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        fan_in = math.prod(s.shape[d] for d in s.fan_in_dims) or 1
        std = s.scale if s.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(k, s.shape) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(s, k)
                                        for s, k in zip(leaves, keys)])


def abstract_params(tree: Any, dtype=jnp.float32) -> Any:
    def one(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, dtype)
    return jax.tree.map(one, tree, is_leaf=is_spec)


def partition_specs(tree: Any, rules: Rules) -> Any:
    def one(s: ParamSpec):
        return rules.spec_for_shape(s.shape, *s.axes)
    return jax.tree.map(one, tree, is_leaf=is_spec)


def shardings(tree: Any, rules: Rules) -> Any:
    def one(s: ParamSpec):
        import jax.sharding as shd
        return shd.NamedSharding(rules.mesh,
                                 rules.spec_for_shape(s.shape, *s.axes))
    return jax.tree.map(one, tree, is_leaf=is_spec)


def count_params(tree: Any) -> int:
    return sum(math.prod(s.shape) for s in
               jax.tree.leaves(tree, is_leaf=is_spec))
