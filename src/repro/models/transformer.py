"""Decoder-only model assemblies: dense / MoE / VLM / SSM / hybrid.

All families share: scan-over-layers with stacked weights (bounds compile
time and enables uniform remat), chunked cross-entropy (never materializes
[B, S, V] logits), and a uniform Model API:

    spec()                          ParamSpec tree
    train_loss(params, batch)       (loss, stats)
    prefill(params, batch)          (caches, last_logits)
    decode_step(params, tokens, caches)  (logits, caches)

Caches are stacked per-layer pytrees so decode also scans over layers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import ParamSpec, stack_specs
from repro.sharding.rules import shard


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_cross_entropy(unembed_p, hidden: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array], chunk: int = 512,
                          real_vocab: Optional[int] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over vocab, scanning seq chunks.

    Avoids a [B, S, V] logits buffer: each step materializes only
    [B, chunk, V]. Logits at indices >= real_vocab (TP padding) are masked
    out of the partition function. Returns (sum_loss, sum_weight).
    """
    b, s, d = hidden.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, pad)))
    mask = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, pad)))

    hs = hidden.reshape(b, nchunk, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nchunk, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nchunk, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        sum_loss, sum_w = carry
        h_c, l_c, m_c = xs
        logits = L.unembed(unembed_p, h_c).astype(jnp.float32)
        if real_vocab is not None and real_vocab < logits.shape[-1]:
            pad_mask = jnp.arange(logits.shape[-1]) < real_vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_c
        return (sum_loss + jnp.sum(nll), sum_w + jnp.sum(m_c)), None

    (sum_loss, sum_w), _ = jax.lax.scan(step, (0.0, 0.0), (hs, ls, ms))
    return sum_loss, sum_w


# ---------------------------------------------------------------------------
# Transformer decoder block (dense / moe families)
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    s = {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
    }
    if cfg.moe is not None:
        s["moe"] = MOE.moe_spec(cfg)
    else:
        s["mlp"] = L.mlp_spec(cfg)
    return s


def block_apply(p, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array,
                cache: Optional[L.KVCache] = None,
                causal: bool = True):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention(p["attn"], h, cfg,
                                      positions=positions, causal=causal,
                                      cache=cache)
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    stats = None
    if cfg.moe is not None:
        ffn, stats = MOE.moe_block(p["moe"], h, cfg)
    else:
        ffn = L.mlp(p["mlp"], h, cfg)
    # residual stream: sequence-sharded between blocks under SP
    out = shard(x + ffn, "batch", "seq_outer", None)
    return out, new_cache, stats


def _zero_stats(cfg: ModelConfig):
    if cfg.moe is None:
        return None
    return {"tokens_per_expert": jnp.zeros((cfg.moe.n_experts,),
                                           jnp.float32),
            "aux_loss": jnp.float32(0.0), "drop_frac": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Decoder-only transformer (dense / moe / vlm)
# ---------------------------------------------------------------------------

class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- spec ----------------------------------------------------------------
    def spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": L.embed_spec(cfg),
            "layers": stack_specs(block_spec(cfg), cfg.n_layers),
            "ln_f": L.rmsnorm_spec(cfg.d_model),
            "unembed": L.unembed_spec(cfg),
        }

    # -- shared stack runner ---------------------------------------------------
    def _run_stack(self, params, x, positions, caches=None, causal=True):
        cfg = self.cfg

        def body(carry, layer_in):
            xc, stats_acc = carry
            p_layer, cache_layer = layer_in
            xc, new_cache, stats = block_apply(
                p_layer, xc, cfg, positions=positions, cache=cache_layer,
                causal=causal)
            if stats is not None:
                stats_acc = jax.tree.map(lambda a, b: a + b, stats_acc,
                                         stats)
            return (xc, stats_acc), new_cache

        body = jax.checkpoint(
            body, policy=getattr(jax.checkpoint_policies, cfg.remat_policy,
                                 jax.checkpoint_policies.nothing_saveable))
        (x, stats), new_caches = jax.lax.scan(
            body, (x, _zero_stats(cfg)), (params["layers"], caches))
        return x, stats, new_caches

    # -- embedding helper (vlm prefix) ---------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm" and "image_embeds" in batch:
            img = L.cast(batch["image_embeds"])
            img = shard(img, "batch", "seq", None)
            x = jnp.concatenate([img, x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions

    # -- train ----------------------------------------------------------------
    def train_loss(self, params, batch) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        x, stats, _ = self._run_stack(params, x, positions)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        labels = batch["labels"]
        if x.shape[1] != labels.shape[1]:       # vlm: loss on text tail only
            x = x[:, x.shape[1] - labels.shape[1]:]
        mask = batch.get("loss_mask")
        sum_loss, sum_w = chunked_cross_entropy(
            params["unembed"], x, labels, mask,
            real_vocab=cfg.real_vocab)
        loss = sum_loss / jnp.maximum(sum_w, 1.0)
        out_stats = {"loss": loss}
        if stats is not None:
            aux = stats["aux_loss"] / cfg.n_layers
            out_stats.update(
                aux_loss=aux, drop_frac=stats["drop_frac"] / cfg.n_layers,
                tokens_per_expert=stats["tokens_per_expert"])
            loss = loss + 0.01 * aux
        return loss, out_stats

    # -- serve ----------------------------------------------------------------
    def prefill(self, params, batch, max_len: int):
        cfg = self.cfg
        x, positions = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        caches = L.KVCache(
            k=jnp.zeros((cfg.n_layers, b, max_len, cfg.n_kv_heads,
                         cfg.resolved_head_dim), L.COMPUTE_DTYPE),
            v=jnp.zeros((cfg.n_layers, b, max_len, cfg.n_kv_heads,
                         cfg.resolved_head_dim), L.COMPUTE_DTYPE),
            length=jnp.int32(0))
        # Prefill runs the flash path (no cache materialization cost in
        # attention itself) then writes K/V per layer via the stack scan.
        x, _, new_caches = self._run_stack(params, x, positions,
                                           caches=self._split_cache(caches, s))
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        last = x[:, -1:]
        logits = L.unembed(params["unembed"], last)[:, 0]
        return self._merge_cache(new_caches, s), logits

    def _split_cache(self, caches: L.KVCache, s: int):
        # per-layer cache views for the scan (length broadcast per layer)
        return L.KVCache(k=caches.k, v=caches.v,
                         length=jnp.broadcast_to(caches.length,
                                                 (caches.k.shape[0],)))

    def _merge_cache(self, caches: L.KVCache, s: int):
        return L.KVCache(k=caches.k, v=caches.v, length=caches.length[0])

    def decode_step(self, params, tokens, caches: L.KVCache):
        """tokens [B, 1] -> (logits [B, V], new caches)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        b = x.shape[0]
        pos = jnp.broadcast_to(caches.length[None, None], (b, 1))
        pos = pos.astype(jnp.int32)
        x, _, new_caches = self._run_stack(
            params, x, pos, caches=self._split_cache(caches, 1))
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)[:, 0]
        return logits, self._merge_cache(new_caches, 1)


# ---------------------------------------------------------------------------
# Pure SSM stack (mamba2)
# ---------------------------------------------------------------------------

class SSMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        layer = {"ln": L.rmsnorm_spec(cfg.d_model),
                 "mamba": SSM.mamba_spec(cfg)}
        return {
            "embed": L.embed_spec(cfg),
            "layers": stack_specs(layer, cfg.n_layers),
            "ln_f": L.rmsnorm_spec(cfg.d_model),
            "unembed": L.unembed_spec(cfg),
        }

    def _run_stack(self, params, x, caches=None, decode=False):
        cfg = self.cfg

        def body(xc, layer_in):
            p_layer, cache_layer = layer_in
            sstate = cstate = None
            if cache_layer is not None:
                sstate, cstate = cache_layer
            h = L.rmsnorm(p_layer["ln"], xc, cfg.norm_eps)
            y, (new_s, new_c) = SSM.mamba_block(
                p_layer["mamba"], h, cfg, ssm_state=sstate,
                conv_state=cstate, decode=decode)
            return shard(xc + y, "batch", "seq_outer", None), (new_s, new_c)

        body = jax.checkpoint(
            body, policy=getattr(jax.checkpoint_policies, cfg.remat_policy,
                                 jax.checkpoint_policies.nothing_saveable))
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
        return x, new_caches

    def train_loss(self, params, batch):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        x, _ = self._run_stack(params, x)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        sum_loss, sum_w = chunked_cross_entropy(
            params["unembed"], x, batch["labels"], batch.get("loss_mask"),
            real_vocab=cfg.real_vocab)
        loss = sum_loss / jnp.maximum(sum_w, 1.0)
        return loss, {"loss": loss}

    def prefill(self, params, batch, max_len: int):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        b = x.shape[0]
        caches = SSM.make_ssm_cache(cfg, b, cfg.n_layers)
        x, new_caches = self._run_stack(params, x, caches=caches)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x[:, -1:])[:, 0]
        return new_caches, logits

    def decode_step(self, params, tokens, caches):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens)
        x, new_caches = self._run_stack(params, x, caches=caches,
                                        decode=True)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)[:, 0]
        return logits, new_caches


# ---------------------------------------------------------------------------
# Hybrid (zamba2): mamba backbone + weight-shared attention block
# ---------------------------------------------------------------------------

class HybridLM:
    """`attn_every` mamba layers per group; one *shared* attention+MLP block
    (single weight set, reused) applied after each group — the Zamba2
    architecture. Leftover layers run as a tail group without attention."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        k = cfg.attn_every or 6
        self.n_groups = cfg.n_layers // k
        self.group_len = k
        self.tail = cfg.n_layers - self.n_groups * k

    def spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        mamba_layer = {"ln": L.rmsnorm_spec(cfg.d_model),
                       "mamba": SSM.mamba_spec(cfg)}
        s = {
            "embed": L.embed_spec(cfg),
            "groups": stack_specs(
                stack_specs(mamba_layer, self.group_len, None),
                self.n_groups),
            "shared": block_spec(cfg),       # ONE weight set, reused
            "ln_f": L.rmsnorm_spec(cfg.d_model),
            "unembed": L.unembed_spec(cfg),
        }
        if self.tail:
            s["tail"] = stack_specs(mamba_layer, self.tail)
        return s

    def _mamba_scan(self, p_layers, x, caches, decode):
        cfg = self.cfg

        def body(xc, layer_in):
            p_layer, cache_layer = layer_in
            sstate = cstate = None
            if cache_layer is not None:
                sstate, cstate = cache_layer
            h = L.rmsnorm(p_layer["ln"], xc, cfg.norm_eps)
            y, new_cache = SSM.mamba_block(p_layer["mamba"], h, cfg,
                                           ssm_state=sstate,
                                           conv_state=cstate, decode=decode)
            return shard(xc + y, "batch", "seq_outer", None), new_cache

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, (p_layers, caches))

    def _run(self, params, x, positions, ssm_caches=None, kv_caches=None,
             decode=False):
        """ssm_caches: ([G, gl, ...], tail [...]) stacked states or None.
        kv_caches: KVCache with leading [n_groups] dim or None."""
        cfg = self.cfg

        def group_body(carry, group_in):
            xc = carry
            p_group, ssm_group, kv_group = group_in
            xc, new_ssm = self._mamba_scan(p_group, xc, ssm_group, decode)
            xc, new_kv, _ = block_apply(params["shared"], xc, cfg,
                                        positions=positions, cache=kv_group,
                                        causal=True)
            return xc, (new_ssm, new_kv)

        x, (new_ssm, new_kv) = jax.lax.scan(
            group_body, x,
            (params["groups"],
             None if ssm_caches is None else ssm_caches[0],
             kv_caches))
        new_tail = None
        if self.tail:
            x, new_tail = self._mamba_scan(
                params["tail"], x,
                None if ssm_caches is None else ssm_caches[1], decode)
        return x, (new_ssm, new_tail), new_kv

    def train_loss(self, params, batch):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _, _ = self._run(params, x, positions)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        sum_loss, sum_w = chunked_cross_entropy(
            params["unembed"], x, batch["labels"], batch.get("loss_mask"),
            real_vocab=cfg.real_vocab)
        loss = sum_loss / jnp.maximum(sum_w, 1.0)
        return loss, {"loss": loss}

    def _init_caches(self, b: int, max_len: int):
        cfg = self.cfg
        ssm_g = SSM.make_ssm_cache(cfg, b, self.n_groups * self.group_len)
        ssm_g = jax.tree.map(
            lambda a: a.reshape((self.n_groups, self.group_len)
                                + a.shape[1:]), ssm_g)
        ssm_t = SSM.make_ssm_cache(cfg, b, self.tail) if self.tail else None
        kv = L.KVCache(
            k=jnp.zeros((self.n_groups, b, max_len, cfg.n_kv_heads,
                         cfg.resolved_head_dim), L.COMPUTE_DTYPE),
            v=jnp.zeros((self.n_groups, b, max_len, cfg.n_kv_heads,
                         cfg.resolved_head_dim), L.COMPUTE_DTYPE),
            length=jnp.zeros((self.n_groups,), jnp.int32))
        return (ssm_g, ssm_t), kv

    def prefill(self, params, batch, max_len: int):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        ssm_caches, kv = self._init_caches(b, max_len)
        x, new_ssm, new_kv = self._run(params, x, positions,
                                       ssm_caches=ssm_caches, kv_caches=kv)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x[:, -1:])[:, 0]
        return (new_ssm, new_kv), logits

    def decode_step(self, params, tokens, caches):
        cfg = self.cfg
        ssm_caches, kv = caches
        x = L.embed(params["embed"], tokens)
        b = x.shape[0]
        pos = jnp.broadcast_to(kv.length[0][None, None], (b, 1)).astype(
            jnp.int32)
        x, new_ssm, new_kv = self._run(params, x, pos,
                                       ssm_caches=ssm_caches, kv_caches=kv,
                                       decode=True)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)[:, 0]
        return logits, (new_ssm, new_kv)
