"""Mamba2 blocks via the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060). Used by mamba2-130m and the zamba2-7b hybrid.

The chunked form splits the sequence into chunks of Q tokens; within a chunk
the recurrence is computed 'attention-like' (quadratic in Q), and a single
[H, P, N] state is passed between chunks with a lax.scan — O(L*Q) compute,
O(L) memory, and a constant-size state for decode. The intra-chunk einsums
are the compute hot-spot mirrored by the Pallas kernel (kernels/ssd_scan);
`ssd_chunked` doubles as that kernel's reference oracle.

Shapes: x [B, L, H, P] (H ssd-heads, P head_dim), dt [B, L, H], A [H] (<0),
B/C [B, L, G, N] (G groups broadcast over heads, N d_state).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.params import ParamSpec
from repro.models.layers import cast, rmsnorm, rmsnorm_spec
from repro.sharding.rules import shard


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array,
                b_in: jax.Array, c_in: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y [B,L,H,P], final_state [B,H,P,N])."""
    bsz, l_in, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    # Pad the sequence to a chunk multiple with dt=0 tokens: zero dt means
    # zero state contribution and unit decay, so padding is exact.
    pad = (-l_in) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l_in + pad
    nc = l // chunk
    rep = h // g

    xr = x.reshape(bsz, nc, chunk, h, p)
    dtr = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    br = jnp.repeat(b_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cr = jnp.repeat(c_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    da = dtr * a.astype(jnp.float32)                    # [B,nc,Q,H], <= 0
    da_cs = jnp.cumsum(da, axis=2)                      # inclusive cumsum

    # --- intra-chunk (quadratic within chunk) ------------------------------
    seg = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # clamp BEFORE exp: above-diagonal entries are masked anyway, but an
    # unclamped exp overflows and poisons the backward pass through where().
    # On the used (lower-tri) region seg <= 0 exactly, so the clamp is free.
    seg = jnp.minimum(seg, 0.0)
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", cr, br)
    w = scores * decay * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w.astype(x.dtype), xr)

    # --- chunk summary states ----------------------------------------------
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)        # [B,nc,Q,H]
    s_chunk = jnp.einsum("bcqh,bcqh,bcqhp,bcqhn->bchpn",
                         decay_to_end, dtr, xr.astype(jnp.float32),
                         br.astype(jnp.float32))               # [B,nc,H,P,N]

    # --- inter-chunk recurrence ---------------------------------------------
    total_decay = jnp.exp(da_cs[:, :, -1, :])                   # [B,nc,H]

    def step(state, inp):
        s_c, dec_c = inp                                        # [B,H,P,N]
        out_state = state                                       # entering state
        new_state = state * dec_c[..., None, None] + s_c
        return new_state, out_state

    init = (jnp.zeros((bsz, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final_state, states_in = jax.lax.scan(
        step, init,
        (s_chunk.transpose(1, 0, 2, 3, 4),
         total_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)              # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         cr.astype(jnp.float32), states_in,
                         jnp.exp(da_cs)).astype(x.dtype)

    y = (y_intra + y_inter).reshape(bsz, l, h, p)[:, :l_in]
    return y, final_state


def ssd_decode_step(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b_in: jax.Array, c_in: jax.Array,
                    state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update. x [B,1,H,P]; state [B,H,P,N]."""
    bsz, _, h, p = x.shape
    g = b_in.shape[2]
    rep = h // g
    br = jnp.repeat(b_in[:, 0], rep, axis=1).astype(jnp.float32)  # [B,H,N]
    cr = jnp.repeat(c_in[:, 0], rep, axis=1).astype(jnp.float32)
    dtf = dt[:, 0].astype(jnp.float32)                            # [B,H]
    da = jnp.exp(dtf * a.astype(jnp.float32))                     # [B,H]
    xf = x[:, 0].astype(jnp.float32)                              # [B,H,P]
    new_state = (state * da[..., None, None]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dtf, xf, br))
    y = jnp.einsum("bhn,bhpn->bhp", cr, new_state)
    return y[:, None].astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, conv_dim


def mamba_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return {
        "in_proj": ParamSpec((d, proj_out), ("model_d", "heads")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), (None, "heads"),
                            scale=0.5, fan_in_dims=(0,)),
        "conv_b": ParamSpec((conv_dim,), ("heads",), init="zeros"),
        "a_log": ParamSpec((n_heads,), ("heads",), init="ones"),
        "dt_bias": ParamSpec((n_heads,), ("heads",), init="zeros"),
        "d_skip": ParamSpec((n_heads,), ("heads",), init="ones"),
        "norm": rmsnorm_spec(d_inner)["scale"],
        "out_proj": ParamSpec((d_inner, d), ("heads", "model_d")),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. xbc [B,L,C]; w [W,C]; returns (y, new_state).

    new_state is the last W-1 inputs [B, W-1, C] (decode carry).
    """
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)              # [B, L+W-1, C]
    y = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None]
            for i in range(width))
    y = jax.nn.silu(y + b[None, None])
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return y, new_state


def mamba_block(p, x: jax.Array, cfg: ModelConfig, *,
                ssm_state: Optional[jax.Array] = None,
                conv_state: Optional[jax.Array] = None,
                decode: bool = False):
    """Mamba2 block. x [B,L,D] -> (y [B,L,D], (ssm_state, conv_state))."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    gn = s.n_groups * s.d_state

    proj = jnp.einsum("bld,dk->blk", x, cast(p["in_proj"]))
    z, xbc, dt_raw = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    xbc, new_conv = _causal_conv(xbc, cast(p["conv_w"]), cast(p["conv_b"]),
                                 conv_state)
    xs, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)

    bsz, l = x.shape[0], x.shape[1]
    xh = xs.reshape(bsz, l, n_heads, s.head_dim)
    xh = shard(xh, "batch", "seq", "heads", None)
    bh = b_in.reshape(bsz, l, s.n_groups, s.d_state)
    ch = c_in.reshape(bsz, l, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if decode:
        y, new_state = ssd_decode_step(xh, dt, a, bh, ch, ssm_state)
    else:
        y, new_state = ssd_chunked(xh, dt, a, bh, ch, s.chunk_len,
                                   initial_state=ssm_state)
    y = y + xh * cast(p["d_skip"])[None, None, :, None]
    y = y.reshape(bsz, l, d_inner)

    y = rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, cast(p["out_proj"]))
    return shard(out, "batch", "seq", None), (new_state, new_conv)


def make_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int,
                   abstract: bool = False):
    """Stacked per-layer (ssm_state, conv_state) decode caches."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    ssm_shape = (n_layers, batch, n_heads, s.head_dim, s.d_state)
    conv_shape = (n_layers, batch, s.conv_width - 1, conv_dim)
    if abstract:
        return (jax.ShapeDtypeStruct(ssm_shape, jnp.float32),
                jax.ShapeDtypeStruct(conv_shape, jnp.float32))
    return (jnp.zeros(ssm_shape, jnp.float32),
            jnp.zeros(conv_shape, jnp.float32))
