"""Core transformer layers: norms, RoPE, GQA attention (dense / blockwise
flash / decode-with-cache), and MLPs. Pure functions over ParamSpec trees.

Conventions: activations are bf16, accumulation f32, params f32 (cast at
use). Tensor names: B batch, S/Q/K sequence, D d_model, H q-heads, G kv
heads, d head_dim, F d_ff, V vocab.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding.rules import shard

COMPUTE_DTYPE = jnp.bfloat16


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("model_d",), init="ones")}


def rmsnorm(p, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return cast(y * p["scale"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, n, d]; positions: [..., S]."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, g = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    s = {
        "wq": ParamSpec((d, h, hd), ("model_d", "heads", None)),
        "wk": ParamSpec((d, g, hd), ("model_d", "kv", None)),
        "wv": ParamSpec((d, g, hd), ("model_d", "kv", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "model_d"),
                        fan_in_dims=(0, 1)),
    }
    if cfg.use_bias:
        s.update({
            "bq": ParamSpec((h, hd), ("heads", None), init="zeros"),
            "bk": ParamSpec((g, hd), ("kv", None), init="zeros"),
            "bv": ParamSpec((g, hd), ("kv", None), init="zeros"),
            "bo": ParamSpec((d,), ("model_d",), init="zeros"),
        })
    return s


def _project_qkv(p, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    k = jnp.einsum("bsd,dgk->bsgk", x, cast(p["wk"]))
    v = jnp.einsum("bsd,dgk->bsgk", x, cast(p["wv"]))
    if cfg.use_bias:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv", None)
    v = shard(v, "batch", "seq", "kv", None)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B,S,G,d] -> [B,S,H,d] by repeating each kv head H/G times."""
    g = k.shape[2]
    if g == n_heads:
        return k
    return jnp.repeat(k, n_heads // g, axis=2)


def _dense_attend(q, k, v, causal: bool, q_pos, k_pos) -> jax.Array:
    """Materialized-scores attention for short sequences. [B,S,H,d] io."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = q_pos[:, :, None] >= k_pos[:, None, :]         # [B,Q,K]
        scores = jnp.where(mask[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _flash_attend(q, k, v, causal: bool, q_pos, k_pos,
                  block_q: int, block_kv: int) -> jax.Array:
    """Blockwise (FlashAttention-style) softmax in pure jnp.

    Outer scan over query blocks, inner scan over KV blocks with running
    (max, denom, acc). Never materializes [S, S]; this is what lets the
    32k-token prefill lower within HBM. The Pallas kernel
    (kernels/flash_attention) implements the same schedule for TPU; this
    function is also its reference oracle.
    """
    b, s_q, h, hd = q.shape
    s_kv = k.shape[1]
    nq = -(-s_q // block_q)
    nkv = -(-s_kv // block_kv)
    pad_q = nq * block_q - s_q
    pad_kv = nkv * block_kv - s_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    kpos = jnp.pad(k_pos, ((0, 0), (0, pad_kv)), constant_values=2**30)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    qb = qp.reshape(b, nq, block_q, h, hd).transpose(1, 0, 2, 3, 4)
    qposb = qpos.reshape(b, nq, block_q).transpose(1, 0, 2)
    kb = kp.reshape(b, nkv, block_kv, h, hd)
    vb = vp.reshape(b, nkv, block_kv, h, hd)
    kposb = kpos.reshape(b, nkv, block_kv)

    def q_block_step(_, q_in):
        q_i, qpos_i = q_in                       # [B,bq,H,d], [B,bq]

        def kv_step(carry, kv_in):
            m, l, acc = carry
            k_j, v_j, kpos_j = kv_in
            s_ij = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j)
            s_ij = s_ij.astype(jnp.float32) * scale
            if causal:
                mask = qpos_i[:, :, None] >= kpos_j[:, None, :]
                s_ij = jnp.where(mask[:, None], s_ij, -1e30)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
            p_ij = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p_ij, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_ij.astype(q_i.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        acc0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kposb.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3).astype(q_i.dtype)

    _, ob = jax.lax.scan(q_block_step, None, (qb, qposb))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(b, nq * block_q, h, hd)
    return out[:, :s_q]


@dataclasses.dataclass
class KVCache:
    """Decode-time cache. k/v: [B, S_max, G, d]; length: filled positions."""
    k: jax.Array
    v: jax.Array
    length: jax.Array           # scalar int32


jax.tree_util.register_dataclass(KVCache)


def attention(p, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              causal: bool = True,
              cache: Optional[KVCache] = None,
              memory: Optional[Tuple[jax.Array, jax.Array]] = None,
              memory_positions: Optional[jax.Array] = None,
              use_flash: Optional[bool] = None) -> Tuple[jax.Array,
                                                         Optional[KVCache]]:
    """GQA attention with three execution paths.

      * cache is None, memory is None: self-attention (train/prefill);
        flash path when S > cfg.flash_block_q (or use_flash=True).
      * memory given: cross-attention over encoder output (no cache here).
      * cache given: single-token decode — append to cache, attend over it.

    Returns (output [B,S,D], updated cache or None).
    """
    b, s, _ = x.shape
    h = cfg.n_heads

    if memory is not None:
        mem_k, mem_v = memory
        q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
        if cfg.use_bias:
            q = q + cast(p["bq"])
        q = rope(q, positions, cfg.rope_theta)
        k = _repeat_kv(mem_k, h)
        v = _repeat_kv(mem_v, h)
        out = _dense_attend(q, k, v, False, positions,
                            memory_positions)
        new_cache = None
    elif cache is not None and s > 1:
        # Prefill-into-cache: attend over the new segment with the flash
        # path (cache is empty at prefill start), write K/V to the cache.
        q, k_new, v_new = _project_qkv(p, x, cfg, positions)
        kr = _repeat_kv(k_new, h)
        vr = _repeat_kv(v_new, h)
        out = _flash_attend(q, kr, vr, causal, positions, positions,
                            cfg.flash_block_q, cfg.flash_block_kv)
        idx = cache.length
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, idx, 1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, idx, 1)
        new_cache = KVCache(k=k_all, v=v_all, length=idx + s)
    elif cache is not None:
        # Single-token decode: attend over the filled cache. Grouped-query
        # einsum — kv heads are NOT repeated to q heads, so the cache stays
        # sequence-sharded end-to-end (repeat would force GSPMD into an
        # involuntary full rematerialization; §Perf iteration 2).
        q, k_new, v_new = _project_qkv(p, x, cfg, positions)
        idx = cache.length
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, idx, 1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, idx, 1)
        k_pos = jnp.arange(k_all.shape[1], dtype=jnp.int32)
        valid_to = idx + s
        g = cfg.n_kv_heads
        rep = h // g
        hd = q.shape[-1]
        qg = q.reshape(b, s, g, rep, hd)
        scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_all)
        scores = scores.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
        mask = (k_pos[None, :] <= positions[:, :1])       # [B, S]
        mask &= (k_pos < valid_to)[None, :]
        scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v_all)
        out = out.reshape(b, s, h, hd)
        new_cache = KVCache(k=k_all, v=v_all, length=idx + s)
    else:
        q, k, v = _project_qkv(p, x, cfg, positions)
        kr = _repeat_kv(k, h)
        vr = _repeat_kv(v, h)
        flash = use_flash if use_flash is not None \
            else s > cfg.flash_block_q
        if flash:
            out = _flash_attend(q, kr, vr, causal, positions, positions,
                                cfg.flash_block_q, cfg.flash_block_kv)
        else:
            out = _dense_attend(q, kr, vr, causal, positions, positions)
        new_cache = None

    out = shard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bqhd,hdD->bqD", out, cast(p["wo"]))
    if cfg.use_bias:
        y = y + cast(p["bo"])
    return shard(y, "batch", "seq", None), new_cache


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=COMPUTE_DTYPE, n_layers: Optional[int] = None,
               abstract: bool = False):
    """Per-layer stacked KV cache [L, B, S_max, G, d]."""
    L = n_layers if n_layers is not None else cfg.decoder_layers
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (L, batch, max_len, g, hd)
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, dtype)
        ln = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        arr = jnp.zeros(shape, dtype)
        ln = jnp.int32(0)
    return KVCache(k=arr, v=arr if abstract else jnp.zeros(shape, dtype),
                   length=ln)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None
             ) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.activation == "swiglu":
        s = {
            "wi": ParamSpec((d, f), ("model_d", "ff")),
            "wg": ParamSpec((d, f), ("model_d", "ff")),
            "wo": ParamSpec((f, d), ("ff", "model_d")),
        }
    else:
        s = {
            "wi": ParamSpec((d, f), ("model_d", "ff")),
            "wo": ParamSpec((f, d), ("ff", "model_d")),
        }
    if cfg.use_bias:
        s["bi"] = ParamSpec((f,), ("ff",), init="zeros")
        s["bo"] = ParamSpec((d,), ("model_d",), init="zeros")
    return s


def mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, cast(p["wi"]))
    if cfg.use_bias:
        h = h + cast(p["bi"])
    if cfg.activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, cast(p["wg"]))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "batch", "seq", "ff")
    y = jnp.einsum("bsf,fd->bsd", h, cast(p["wo"]))
    if cfg.use_bias:
        y = y + cast(p["bo"])
    return shard(y, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {"embedding": ParamSpec((cfg.vocab, cfg.d_model),
                                   ("vocab", "model_d"), scale=0.02,
                                   fan_in_dims=(1,))}


def embed(p, tokens: jax.Array) -> jax.Array:
    out = cast(p["embedding"])[tokens]
    return shard(out, "batch", "seq", None)


def unembed_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {"w": ParamSpec((cfg.d_model, cfg.vocab), ("model_d", "vocab"))}


def unembed(p, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", x, cast(p["w"]))
    return shard(logits, "batch", "seq", "vocab")
