"""Encoder-decoder model (seamless-m4t-large-v2).

Encoder consumes precomputed modality-frontend embeddings (speech frames —
the frontend itself is a stub per the assignment), decoder is a causal LM
with cross-attention over encoder output. Cross-attention K/V are projected
once per layer from the encoder memory and cached for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec, stack_specs
from repro.models.transformer import chunked_cross_entropy
from repro.sharding.rules import shard


def enc_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def dec_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "ln_x": L.rmsnorm_spec(cfg.d_model),
        "xattn": L.attention_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_enc = cfg.encoder_layers
        self.n_dec = cfg.decoder_layers

    def spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "frame_proj": {"w": ParamSpec((cfg.d_model, cfg.d_model),
                                          ("model_d", None))},
            "embed": L.embed_spec(cfg),
            "encoder": stack_specs(enc_block_spec(cfg), self.n_enc),
            "ln_enc": L.rmsnorm_spec(cfg.d_model),
            "decoder": stack_specs(dec_block_spec(cfg), self.n_dec),
            "ln_f": L.rmsnorm_spec(cfg.d_model),
            "unembed": L.unembed_spec(cfg),
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: [B, S_enc, D] precomputed frontend embeddings."""
        cfg = self.cfg
        x = jnp.einsum("bsd,dk->bsk", L.cast(frames),
                       L.cast(params["frame_proj"]["w"]))
        x = shard(x, "batch", "seq", None)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(xc, p_layer):
            h = L.rmsnorm(p_layer["ln1"], xc, cfg.norm_eps)
            attn, _ = L.attention(p_layer["attn"], h, cfg,
                                  positions=positions, causal=False)
            xc = xc + attn
            h = L.rmsnorm(p_layer["ln2"], xc, cfg.norm_eps)
            return shard(xc + L.mlp(p_layer["mlp"], h, cfg),
                         "batch", "seq_outer", None), None

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return L.rmsnorm(params["ln_enc"], x, cfg.norm_eps)

    # -- decoder ---------------------------------------------------------------
    def _project_memory(self, p_xattn, memory):
        cfg = self.cfg
        k = jnp.einsum("bsd,dgk->bsgk", memory, L.cast(p_xattn["wk"]))
        v = jnp.einsum("bsd,dgk->bsgk", memory, L.cast(p_xattn["wv"]))
        if cfg.use_bias:
            k = k + L.cast(p_xattn["bk"])
            v = v + L.cast(p_xattn["bv"])
        return k, v

    def _run_decoder(self, params, x, positions, memory=None,
                     mem_kv=None, caches=None):
        """memory: [B,S_enc,D] (training/prefill) or mem_kv: pre-projected
        stacked (k, v) [L, B, S_enc, G, d] (decode)."""
        cfg = self.cfg
        b, s_enc = None, None
        if memory is not None:
            b, s_enc, _ = memory.shape
            mem_pos = jnp.broadcast_to(
                jnp.arange(s_enc, dtype=jnp.int32), (b, s_enc))
        else:
            b = x.shape[0]
            s_enc = mem_kv[0].shape[2]
            mem_pos = jnp.broadcast_to(
                jnp.arange(s_enc, dtype=jnp.int32), (b, s_enc))

        def body(xc, layer_in):
            p_layer, cache_layer, mem_kv_layer = layer_in
            h = L.rmsnorm(p_layer["ln1"], xc, cfg.norm_eps)
            attn, new_cache = L.attention(
                p_layer["attn"], h, cfg, positions=positions, causal=True,
                cache=cache_layer)
            xc = xc + attn
            h = L.rmsnorm(p_layer["ln_x"], xc, cfg.norm_eps)
            if mem_kv_layer is not None:
                kv = mem_kv_layer
            else:
                kv = self._project_memory(p_layer["xattn"], memory)
            xattn, _ = L.attention(p_layer["xattn"], h, cfg,
                                   positions=positions, memory=kv,
                                   memory_positions=mem_pos)
            xc = xc + xattn
            h = L.rmsnorm(p_layer["ln2"], xc, cfg.norm_eps)
            return shard(xc + L.mlp(p_layer["mlp"], h, cfg),
                         "batch", "seq_outer", None), new_cache

        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_caches = jax.lax.scan(
            body, x, (params["decoder"], caches, mem_kv))
        return x, new_caches

    # -- api -------------------------------------------------------------------
    def train_loss(self, params, batch) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = L.embed(params["embed"], batch["tokens"])
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _ = self._run_decoder(params, x, positions, memory=memory)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        sum_loss, sum_w = chunked_cross_entropy(
            params["unembed"], x, batch["labels"], batch.get("loss_mask"),
            real_vocab=cfg.real_vocab)
        loss = sum_loss / jnp.maximum(sum_w, 1.0)
        return loss, {"loss": loss}

    def prefill(self, params, batch, max_len: int):
        """Encode + decoder prefill. Returns ((kv_caches, mem_kv), logits)."""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = L.embed(params["embed"], batch["tokens"])
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        caches = L.KVCache(
            k=jnp.zeros((self.n_dec, b, max_len, cfg.n_kv_heads,
                         cfg.resolved_head_dim), L.COMPUTE_DTYPE),
            v=jnp.zeros((self.n_dec, b, max_len, cfg.n_kv_heads,
                         cfg.resolved_head_dim), L.COMPUTE_DTYPE),
            length=jnp.zeros((self.n_dec,), jnp.int32))
        x, new_caches = self._run_decoder(params, x, positions,
                                          memory=memory, caches=caches)
        # Pre-project cross K/V once for decode (vmap over layers).
        mem_kv = jax.vmap(self._project_memory, in_axes=(0, None))(
            params["decoder"]["xattn"], memory)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x[:, -1:])[:, 0]
        return (new_caches, mem_kv), logits

    def decode_step(self, params, tokens, caches):
        cfg = self.cfg
        kv_caches, mem_kv = caches
        x = L.embed(params["embed"], tokens)
        b = x.shape[0]
        pos = jnp.broadcast_to(kv_caches.length[0][None, None],
                               (b, 1)).astype(jnp.int32)
        x, new_caches = self._run_decoder(params, x, pos, mem_kv=mem_kv,
                                          caches=kv_caches)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.unembed(params["unembed"], x)[:, 0]
        return logits, (new_caches, mem_kv)
