"""Model registry: ModelConfig -> Model instance (uniform API)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.transformer import DecoderLM, SSMLM, HybridLM
from repro.models.encdec import EncDecLM


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return SSMLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family: {cfg.family}")


__all__ = ["get_model", "DecoderLM", "SSMLM", "HybridLM", "EncDecLM"]
