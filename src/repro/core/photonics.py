"""Photonic device models for the ReSiPI interposer.

Implements the paper's §3.2: PCM-based reconfigurable directional couplers
(PCMCs, Eqs. 1-3), the equal-power-share coupling-ratio schedule (Eq. 4), and
microring-group (MRG) device-count / power accounting for the SWMR interposer
of Fig. 4. Everything is pure-JAX and jittable over dynamic gateway-activity
masks, so the controller (gateway_controller.py) can run under `lax.scan`.

Eq. 4 note: the paper writes kappa_i = 1/(sum_c g_c - i) with i the PCMC chain
index, under the convention that idle writers have kappa=0 and do not consume
an index. We implement the equal-share-correct reading: i counts *active*
writers upstream of PCMC i, which yields exactly P_laser/GT at every active
writer for any activity pattern (property-tested in tests/test_photonics.py).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import (PHOTONIC_POWER, NETWORK, NetworkConfig,
                                  PhotonicPower)


# ---------------------------------------------------------------------------
# PCMC device (Fig. 5, Eqs. 1-3)
# ---------------------------------------------------------------------------

def pcmc_coupling_ratio(cl_amorphous: jax.Array, cl_crystalline: jax.Array
                        ) -> jax.Array:
    """Eq. 1: kappa = CL_am / CL_cr, clipped to the physical [0, 1] range."""
    return jnp.clip(cl_amorphous / jnp.maximum(cl_crystalline, 1e-12), 0.0, 1.0)


def pcmc_split(p_in: jax.Array, kappa: jax.Array,
               insertion_loss_db: float = 0.0
               ) -> Tuple[jax.Array, jax.Array]:
    """Eqs. 2-3: split input power into (cross, bar) outputs.

    P_C = kappa * P_I ; P_B = (1 - kappa) * P_I, with optional insertion loss
    applied to both arms (the paper assumes lossless transmission for Eq. 2-3;
    loss_db=0 reproduces that).
    """
    loss = 10.0 ** (-insertion_loss_db / 10.0)
    p_cross = kappa * p_in * loss
    p_bar = (1.0 - kappa) * p_in * loss
    return p_cross, p_bar


def kappa_schedule(active: jax.Array) -> jax.Array:
    """Eq. 4: coupling ratios for the N-1 PCMC chain given activity mask.

    Args:
      active: bool/int array [N] — gateway i's writer is active. Chain order
        follows the MRG chain of Fig. 4 (gateway N has no PCMC: it receives
        the bar-through remainder).

    Returns:
      kappa: float array [N-1]. kappa[i] = 1/(GT - a_i) if gateway i is
      active (a_i = number of active gateways upstream of i), else 0.
    """
    active = active.astype(jnp.float32)
    gt = jnp.sum(active)
    # a_i = number of active writers strictly before chain position i.
    upstream = jnp.cumsum(active) - active
    denom = jnp.maximum(gt - upstream, 1.0)
    kappa = jnp.where(active[:-1] > 0, 1.0 / denom[:-1], 0.0)
    return kappa


def power_division(active: jax.Array, laser_power_mw: jax.Array
                   ) -> jax.Array:
    """Propagate laser power down the PCMC chain (Fig. 4 wiring).

    Returns per-gateway received optical power [N]. With kappa_schedule and a
    laser tuned to `laser_power_mw`, every active gateway receives
    laser_power_mw / GT and idle gateways receive 0 (the PCM power-gating
    mechanism of §3.2).
    """
    kappa = kappa_schedule(active)
    n = active.shape[0]

    def step(p_bar, k):
        p_cross, p_bar_next = pcmc_split(p_bar, k)
        return p_bar_next, p_cross

    p_remaining, taps = jax.lax.scan(step, laser_power_mw, kappa)
    # Last gateway in the chain taps the remaining bar output directly.
    received = jnp.concatenate([taps, p_remaining[None]])
    # An idle final gateway must see zero power: with Eq. 4 the upstream taps
    # exhaust the laser power exactly, so p_remaining==0 whenever the final
    # gateway is idle; guard numerically.
    received = jnp.where(active > 0, received, 0.0)
    return received


# ---------------------------------------------------------------------------
# Placement-dependent access-waveguide loss
# ---------------------------------------------------------------------------

def gateway_access_loss_db(gw_pos: np.ndarray,
                           cfg: NetworkConfig = NETWORK,
                           power: PhotonicPower = PHOTONIC_POWER
                           ) -> np.ndarray:
    """Per-gateway optical access loss implied by where the gateway sits.

    A gateway's access waveguide runs from its router tile to the nearest
    chiplet edge, where it couples down to the interposer SWMR waveguide
    (Fig. 4). Edge-placed gateways (the default scheme) pay ~0 dB; interior
    placements pay propagation loss proportional to their Manhattan distance
    to the closest edge — the physical term that makes gateway *placement* a
    real latency-vs-power trade-off instead of a free hop-count knob.

    Args:
      gw_pos: [G, 2] int router coordinates (activation order).

    Returns [G] float32 dB values (design-time numpy constant; consumed by
    the selection tables as per-activation-level means).
    """
    from repro.core import topology

    pos = np.asarray(gw_pos, np.int32).reshape(-1, 2)
    if cfg.coords is None:
        edge_hops = np.minimum.reduce([
            pos[:, 0], cfg.mesh_x - 1 - pos[:, 0],
            pos[:, 1], cfg.mesh_y - 1 - pos[:, 1]])
    else:
        # Explicit layout: hop distance to the nearest boundary router
        # (design-time BFS LUT — see topology.edge_distance).
        edge_hops = topology.edge_lut(cfg)[pos[:, 0], pos[:, 1]]
    return (edge_hops * cfg.router_pitch_mm
            * power.waveguide_db_per_mm).astype(np.float32)


def gateway_access_loss_db_jnp(gw_pos, cfg: NetworkConfig = NETWORK,
                               power: PhotonicPower = PHOTONIC_POWER
                               ) -> jax.Array:
    """Traceable twin of `gateway_access_loss_db` for traced placements.

    Identical distance-to-nearest-edge formula, expressed in jnp so the
    device-resident placement search (repro.core.search) can derive a
    candidate's optical access loss without leaving the device. Matches the
    numpy builder at 1e-6 (tests/test_search.py).
    """
    from repro.core import topology

    pos = jnp.asarray(gw_pos, jnp.int32).reshape(-1, 2)
    if cfg.coords is None:
        edge_hops = jnp.minimum(
            jnp.minimum(pos[:, 0], cfg.mesh_x - 1 - pos[:, 0]),
            jnp.minimum(pos[:, 1], cfg.mesh_y - 1 - pos[:, 1]))
    else:
        edge_hops = jnp.asarray(topology.edge_lut(cfg))[pos[:, 0],
                                                        pos[:, 1]]
    return (edge_hops.astype(jnp.float32)
            * jnp.float32(cfg.router_pitch_mm * power.waveguide_db_per_mm))


# ---------------------------------------------------------------------------
# MRG accounting (Fig. 4): N gateways, W wavelengths
#   each MRG: 1 modulator row (W MRs) + (N-1) filter rows (W MRs each)
#   waveguides per MRG: N ; PCMCs in system: N-1
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InterposerGeometry:
    n_gateways: int
    wavelengths: int

    @property
    def mrgs(self) -> int:
        return self.n_gateways

    @property
    def pcmcs(self) -> int:
        return self.n_gateways - 1

    @property
    def modulators_per_mrg(self) -> int:
        return self.wavelengths

    @property
    def filters_per_mrg(self) -> int:
        return (self.n_gateways - 1) * self.wavelengths

    @property
    def total_mrs(self) -> int:
        return self.mrgs * (self.modulators_per_mrg + self.filters_per_mrg)


def interposer_power_mw(active: jax.Array,
                        wavelengths: jax.Array,
                        *,
                        n_gateways: int,
                        power: PhotonicPower = PHOTONIC_POWER,
                        loss_db: float = 0.0,
                        mode: str = "pcm",
                        gateway_count=None,
                        n_chiplets=None) -> dict:
    """Total photonic interposer power for a given activity state.

    Thermal tuning is the power that pulls an MR onto resonance; a ring with
    no light routed to it (PCM-gated MRG input) can be left untuned. A reader
    gateway ejects one packet at a time, so it keeps exactly one filter row
    (W rings) on-resonance; modulator rows of active writers are always lit.

    Args:
      active: [N] bool — active gateways (writers+readers co-gated, §3.2).
      wavelengths: scalar or [N] — active wavelengths per gateway.
      n_gateways: static N (chain length).
      loss_db: optical path loss; laser power is scaled by 10^(loss/10) to
        keep receiver-side power constant (the AWGR 1.8 dB penalty).
      mode:
        "pcm"    — ReSiPI: laser + tuning + driver + TIA all follow the
                   PCMC-gated activity mask (non-volatile gating, §3.2).
        "wdm"    — PROWAVES: per-gateway wavelength counts are adaptive
                   (laser, driver, TIA, tuning scale with active lambdas)
                   but every provisioned gateway stays lit — no PCM gating,
                   so the single gateway per chiplet never powers down.
        "static" — AWGR: everything provisioned is always on (fixed lasers,
                   passive AWGR routing, per-port receiver rings tuned).
      gateway_count: optional (possibly traced) *actual* gateway count when
        the [N] axis is padded for topology batching — replaces the static
        `n_gateways` in the count-dependent "static" terms so padded slots
        contribute zero. Defaults to `n_gateways` (unpadded behavior).
      n_chiplets: optional (possibly traced) chiplet count for the Table 2
        controller term (172 uW per chiplet + interposer controller).
        Defaults to the Table 1 system (NETWORK.n_chiplets).

    Returns dict with laser/tuning/driver/tia/total mW (jnp scalars).
    """
    active_f = active.astype(jnp.float32)
    w = jnp.broadcast_to(jnp.asarray(wavelengths, jnp.float32), (n_gateways,))
    loss_scale = 10.0 ** (loss_db / 10.0)
    gw_n = (jnp.float32(n_gateways) if gateway_count is None
            else jnp.asarray(gateway_count, jnp.float32))

    if mode == "pcm":
        lit_w = jnp.sum(active_f * w)
        laser = lit_w * power.laser_mw_per_wavelength
        mods = lit_w                      # modulator rings of active writers
        filters = lit_w                   # one tuned filter row per reader
    elif mode == "wdm":
        lit_w = jnp.sum(w)                # all provisioned gateways stay lit
        laser = lit_w * power.laser_mw_per_wavelength
        mods = lit_w
        filters = lit_w
    elif mode == "static":
        lit_w = jnp.sum(w)
        laser = lit_w * power.laser_mw_per_wavelength
        mods = lit_w
        # AWGR outputs keep a full receiver ring bank on-resonance (any of
        # N wavelengths can arrive at any output port).
        filters = gw_n * gw_n
    else:
        raise ValueError(f"unknown power mode: {mode}")

    tia = filters if mode != "static" else gw_n
    tia = tia * power.tia_mw
    tuning = (mods + filters) * power.tuning_mw_per_mr
    driver = mods * power.driver_mw

    laser = laser * loss_scale
    chips = (NETWORK.n_chiplets if n_chiplets is None
             else jnp.asarray(n_chiplets, jnp.float32))
    controller = (power.controller_lgc_uw * chips
                  + power.controller_inc_uw) / 1000.0
    total = laser + tia + tuning + driver + controller
    return {"laser_mw": laser, "tia_mw": tia, "tuning_mw": tuning,
            "driver_mw": driver,
            "controller_mw": jnp.asarray(controller, jnp.float32),
            "total_mw": total}


def reconfig_energy_nj(prev_active: jax.Array, new_active: jax.Array,
                       power: PhotonicPower = PHOTONIC_POWER) -> jax.Array:
    """PCM reconfiguration energy for one epoch boundary.

    Every PCMC whose kappa changes pays one ~2 nJ PCM state transition.
    Non-volatility (the PCM retains state at zero power) is what makes the
    steady-state term zero — the defining property exploited by the paper.
    """
    k_prev = kappa_schedule(prev_active)
    k_new = kappa_schedule(new_active)
    switched = jnp.sum((jnp.abs(k_new - k_prev) > 1e-6).astype(jnp.float32))
    return switched * power.pcmc_reconfig_nj
