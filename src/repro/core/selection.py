"""Adaptive gateway selection (§3.4, Fig. 8).

Routing an inter-chiplet packet takes three steps: (1) source router ->
source gateway, (2) source gateway -> destination gateway over the photonic
interposer, (3) destination gateway -> destination router. The source router
only knows its *local* active-gateway count g_src; the source gateway knows
g_dst of the destination chiplet. Selection decisions are design-time tables
(one per activation level), exactly as §3.4 prescribes, rebuilt here
programmatically:

  * routers are partitioned into balanced groups of R_g = R / g per gateway,
    each group containing the routers nearest to its gateway (Fig. 8 a-d),
  * the destination table picks, for each (g_dst, dest_router), the active
    gateway minimizing gateway->router hop count subject to the same balance.

Tables are small numpy constants (computed once per topology); runtime
lookups are jnp gathers, so per-packet selection is vmappable inside the
simulator and differentiable-free.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.constants import NETWORK, NetworkConfig


def default_gateway_positions(cfg: NetworkConfig = NETWORK) -> np.ndarray:
    """Gateway-attached router coordinates on the chiplet mesh.

    Placement follows the edge-distributed scheme of [29]/Fig. 8d: gateways
    sit on distinct edges so that consecutive activation levels keep them
    maximally spread. Activation order is the row order of this array.
    """
    mx, my = cfg.mesh_x, cfg.mesh_y
    pos = np.array([
        [1, 0],                 # G1: south edge
        [mx - 2, my - 1],       # G2: north edge (opposite side for g=2)
        [0, my - 2],            # G3: west edge
        [mx - 1, 1],            # G4: east edge
    ], dtype=np.int32)
    return pos[: cfg.max_gateways_per_chiplet]


def _router_coords(cfg: NetworkConfig) -> np.ndarray:
    xs, ys = np.meshgrid(np.arange(cfg.mesh_x), np.arange(cfg.mesh_y),
                         indexing="ij")
    return np.stack([xs.ravel(), ys.ravel()], axis=-1).astype(np.int32)


def hop_count(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """XY (dimension-ordered) routing hop count on the mesh — the DeFT [22]
    intra-chiplet distance metric (deadlock-freedom does not change hops)."""
    return np.abs(a[..., 0] - b[..., 0]) + np.abs(a[..., 1] - b[..., 1])


def _balanced_assignment(routers: np.ndarray, gw_pos: np.ndarray,
                         capacity: int) -> np.ndarray:
    """Greedy balanced nearest-gateway partition.

    Sorts (router, gateway) pairs by hop distance and assigns greedily under a
    per-gateway capacity of ceil(R/g) — the R_g = R/g_c balance rule of §3.4.
    Deterministic; ties broken by (distance, router id, gateway id).
    """
    n_r, n_g = len(routers), len(gw_pos)
    dist = hop_count(routers[:, None, :], gw_pos[None, :, :])  # [R, G]
    order = sorted(((dist[r, g], r, g) for r in range(n_r) for g in range(n_g)))
    assign = np.full((n_r,), -1, dtype=np.int32)
    load = np.zeros((n_g,), dtype=np.int32)
    for d, r, g in order:
        if assign[r] == -1 and load[g] < capacity:
            assign[r] = g
            load[g] += 1
    # Any leftovers (capacity exhausted by ties) -> least-loaded gateway.
    for r in range(n_r):
        if assign[r] == -1:
            g = int(np.argmin(load))
            assign[r] = g
            load[g] += 1
    return assign


@dataclasses.dataclass(frozen=True)
class SelectionTables:
    """Design-time tables, one slice per activation level g in 1..G.

    src_map:  [G, R] int  — source gateway index for each router when g
                            gateways are active (entries < g).
    dst_map:  [G, R] int  — destination gateway for each destination router.
    src_hops: [G]  float  — mean router->gateway hops under src_map.
    dst_hops: [G]  float  — mean gateway->router hops under dst_map.
    gw_pos:   [Gmax, 2]   — gateway coordinates (activation order).
    """
    src_map: np.ndarray
    dst_map: np.ndarray
    src_hops: np.ndarray
    dst_hops: np.ndarray
    gw_pos: np.ndarray

    def as_jax(self) -> dict:
        return {"src_map": jnp.asarray(self.src_map),
                "dst_map": jnp.asarray(self.dst_map),
                "src_hops": jnp.asarray(self.src_hops),
                "dst_hops": jnp.asarray(self.dst_hops)}


def build_selection_tables(cfg: NetworkConfig = NETWORK) -> SelectionTables:
    """Build (and memoize) the design-time tables for one topology.

    `NetworkConfig` is a frozen dataclass, so equal configs hash equally and
    the greedy numpy construction runs at most once per distinct topology —
    table lookups inside jit-compiled sweeps are free after the first call.
    The default is normalized *before* the cache so `build_selection_tables()`
    and `build_selection_tables(NETWORK)` share one entry. The returned
    `SelectionTables` (and its arrays) must be treated as immutable by
    callers.
    """
    return _build_selection_tables_cached(cfg)


@functools.lru_cache(maxsize=None)
def _build_selection_tables_cached(cfg: NetworkConfig) -> SelectionTables:
    routers = _router_coords(cfg)
    gw_pos = default_gateway_positions(cfg)
    n_r = len(routers)
    g_max = cfg.max_gateways_per_chiplet

    src_map = np.zeros((g_max, n_r), dtype=np.int32)
    dst_map = np.zeros((g_max, n_r), dtype=np.int32)
    src_hops = np.zeros((g_max,), dtype=np.float32)
    dst_hops = np.zeros((g_max,), dtype=np.float32)

    for g in range(1, g_max + 1):
        cap = int(np.ceil(n_r / g))
        active_pos = gw_pos[:g]
        assign = _balanced_assignment(routers, active_pos, cap)
        src_map[g - 1] = assign
        dst_map[g - 1] = assign      # step-3 tables share the balance rule
        d = hop_count(routers, active_pos[assign])
        src_hops[g - 1] = float(d.mean())
        dst_hops[g - 1] = float(d.mean())

    return SelectionTables(src_map=src_map, dst_map=dst_map,
                           src_hops=src_hops, dst_hops=dst_hops,
                           gw_pos=gw_pos)


# Cache-management handles for instrumentation (simulator.engine_stats) and
# baselines (simulator.SelectionTables_rebuild): same surface lru_cache
# would have put on the public name.
build_selection_tables.cache_info = _build_selection_tables_cached.cache_info
build_selection_tables.cache_clear = \
    _build_selection_tables_cached.cache_clear
build_selection_tables.__wrapped__ = \
    _build_selection_tables_cached.__wrapped__


def selection_tables_jax(cfg: NetworkConfig = NETWORK) -> dict:
    """Memoized device-resident view of the tables for `cfg`.

    Returns the *same* dict (same jax arrays) for equal configs, so repeated
    `simulate` calls ship identical buffers to jit and never re-upload.
    """
    return _selection_tables_jax_cached(cfg)


@functools.lru_cache(maxsize=None)
def _selection_tables_jax_cached(cfg: NetworkConfig) -> dict:
    return build_selection_tables(cfg).as_jax()


def select_source_gateway(tables: dict, router: jnp.ndarray,
                          g_src: jnp.ndarray) -> jnp.ndarray:
    """Step-1 selection: local table lookup (router only knows g_src)."""
    return tables["src_map"][g_src - 1, router]


def select_dest_gateway(tables: dict, dest_router: jnp.ndarray,
                        g_dst: jnp.ndarray) -> jnp.ndarray:
    """Step-2 selection at the source gateway (knows g_dst, §3.4)."""
    return tables["dst_map"][g_dst - 1, dest_router]


def mean_access_hops(tables: dict, g: jnp.ndarray) -> jnp.ndarray:
    """Mean router<->gateway hop count at activation level g (vectorized)."""
    return tables["src_hops"][jnp.maximum(g, 1) - 1]
