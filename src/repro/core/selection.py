"""Adaptive gateway selection (§3.4, Fig. 8).

Routing an inter-chiplet packet takes three steps: (1) source router ->
source gateway, (2) source gateway -> destination gateway over the photonic
interposer, (3) destination gateway -> destination router. The source router
only knows its *local* active-gateway count g_src; the source gateway knows
g_dst of the destination chiplet. Selection decisions are design-time tables
(one per activation level), exactly as §3.4 prescribes, rebuilt here
programmatically:

  * routers are partitioned into balanced groups of R_g = R / g per gateway,
    each group containing the routers nearest to its gateway (Fig. 8 a-d),
  * the destination table picks, for each (g_dst, dest_router), the active
    gateway minimizing gateway->router hop count subject to the same balance.

Tables are small numpy constants (computed once per topology); runtime
lookups are jnp gathers, so per-packet selection is vmappable inside the
simulator and differentiable-free.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import photonics, topology
from repro.core.constants import NETWORK, NetworkConfig
from repro.core.gateway_controller import activation_order


def _validate_positions(pos: np.ndarray, cfg: NetworkConfig,
                        what: str) -> None:
    """Reject out-of-bounds or colliding gateway coordinates loudly.

    Small meshes used to make the default edge formulas (`mx - 2`, `my - 2`)
    underflow into negative or duplicate coordinates *silently*; every
    placement now funnels through this check before any table is built.
    Explicit-coords layouts additionally require each coordinate to name an
    actual router (the dense LUT bounding box has off-layout holes).
    """
    bx, by = topology.lut_shape(cfg)
    oob = ((pos[:, 0] < 0) | (pos[:, 0] >= bx)
           | (pos[:, 1] < 0) | (pos[:, 1] >= by))
    if oob.any():
        bad = [tuple(p) for p in pos[oob]]
        raise ValueError(
            f"{what}: gateway coordinates {bad} fall outside the "
            f"{bx}x{by} chiplet mesh")
    if cfg.coords is not None:
        idx = topology.router_index_lut(cfg)
        hole = idx[pos[:, 0], pos[:, 1]] < 0
        if hole.any():
            bad = [tuple(p) for p in pos[hole]]
            raise ValueError(
                f"{what}: gateway coordinates {bad} are not routers of the "
                f"{cfg.coord_model} layout in NetworkConfig.coords")
    uniq, counts = np.unique(pos, axis=0, return_counts=True)
    if (counts > 1).any():
        dup = [tuple(p) for p in uniq[counts > 1]]
        raise ValueError(
            f"{what}: gateway coordinates collide at {dup} — each gateway "
            f"needs its own router on the {cfg.mesh_x}x{cfg.mesh_y} mesh")


# Slot count of the default edge-distributed scheme below; placements with
# more gateways need explicit NetworkConfig.gateway_positions.
N_DEFAULT_EDGE_SLOTS = 4


def default_gateway_positions(cfg: NetworkConfig = NETWORK) -> np.ndarray:
    """Gateway-attached router coordinates on the chiplet mesh.

    Placement follows the edge-distributed scheme of [29]/Fig. 8d: gateways
    sit on distinct edges so that consecutive activation levels keep them
    maximally spread. Activation order is the row order of this array.
    Raises a clear ValueError on meshes too small to host the scheme
    (the edge formulas need every sliced slot in-bounds and distinct).
    Explicit-coords layouts (hex patches etc.) have no fixed edge slots;
    they use the deterministic boundary max-min-spread generalization in
    `topology.default_positions`.
    """
    if cfg.coords is not None:
        pos = np.array(topology.default_positions(cfg), dtype=np.int32)
        _validate_positions(
            pos, cfg, f"default_gateway_positions on a {cfg.coord_model} "
                      f"layout")
        return pos
    mx, my = cfg.mesh_x, cfg.mesh_y
    pos = np.array([
        [1, 0],                 # G1: south edge
        [mx - 2, my - 1],       # G2: north edge (opposite side for g=2)
        [0, my - 2],            # G3: west edge
        [mx - 1, 1],            # G4: east edge
    ], dtype=np.int32)
    assert len(pos) == N_DEFAULT_EDGE_SLOTS
    if cfg.max_gateways_per_chiplet > len(pos):
        raise ValueError(
            f"default edge scheme defines {len(pos)} gateway slots but "
            f"max_gateways_per_chiplet={cfg.max_gateways_per_chiplet}; pass "
            f"explicit NetworkConfig.gateway_positions for denser placements")
    pos = pos[: cfg.max_gateways_per_chiplet]
    _validate_positions(
        pos, cfg, f"default_gateway_positions on a {mx}x{my} mesh")
    return pos


def resolve_gateway_positions(cfg: NetworkConfig = NETWORK) -> np.ndarray:
    """The placement the config actually means: explicit or default.

    Explicit `cfg.gateway_positions` are validated (bounds, collisions,
    enough rows for `max_gateways_per_chiplet`) and sliced to the first
    `max_gateways_per_chiplet` rows (activation order); None falls back to
    the edge-distributed default scheme. Everything downstream — selection
    tables, flit-kernel topology building, access-waveguide loss — goes
    through this single resolution point.
    """
    if cfg.gateway_positions is None:
        return default_gateway_positions(cfg)
    pos = np.asarray(cfg.gateway_positions, np.int32).reshape(-1, 2)
    if len(pos) < cfg.max_gateways_per_chiplet:
        raise ValueError(
            f"gateway_positions places {len(pos)} gateways but "
            f"max_gateways_per_chiplet={cfg.max_gateways_per_chiplet}")
    _validate_positions(pos, cfg, "gateway_positions")
    return pos[: cfg.max_gateways_per_chiplet]


def normalize_placement(positions, cfg: NetworkConfig = NETWORK, *,
                        order: str = "given"):
    """Canonicalize a placement into the hashable tuple form configs carry.

    `order="spread"` re-rows the placement by the controller's activation
    order (gateway_controller.activation_order) so partial activation levels
    stay well-spread; `order="given"` keeps the caller's row order. Returns
    None unchanged (the default scheme marker).
    """
    if positions is None:
        return None
    pos = np.asarray(positions, np.int64).reshape(-1, 2)
    if order == "spread":
        pos = pos[activation_order(pos, cfg)]
    elif order != "given":
        raise ValueError(f"unknown placement order: {order!r}")
    return tuple((int(x), int(y)) for x, y in pos)


def _router_coords(cfg: NetworkConfig) -> np.ndarray:
    """[R, 2] router coordinates — mesh grid or explicit cfg.coords
    (repro.core.topology is the single source of truth since PR 10)."""
    return topology.router_coords(cfg)


def hop_count(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """XY (dimension-ordered) routing hop count on the mesh — the DeFT [22]
    intra-chiplet distance metric (deadlock-freedom does not change hops).
    Mesh-only Manhattan closed form; coordinate-model-aware callers use
    `topology.pair_hops(cfg, a, b)` instead."""
    return np.abs(a[..., 0] - b[..., 0]) + np.abs(a[..., 1] - b[..., 1])


def _balanced_assignment_from_dist(dist: np.ndarray,
                                   capacity: int) -> np.ndarray:
    """Greedy balanced nearest-gateway partition from a [R, G] hop matrix.

    Processes (router, gateway) pairs in (distance, router id, gateway id)
    order and assigns greedily under a per-gateway capacity of ceil(R/g) —
    the R_g = R/g_c balance rule of §3.4. The pair ordering is a single
    vectorized `np.lexsort` (the O(R*G log RG) part); only the inherently
    sequential capacity-constrained walk remains a Python loop, with an
    early exit once every router is assigned.
    """
    n_r, n_g = dist.shape
    rr, gg = np.divmod(np.arange(n_r * n_g), n_g)
    order = np.lexsort((gg, rr, dist.ravel()))     # primary: distance
    assign = np.full((n_r,), -1, dtype=np.int32)
    load = np.zeros((n_g,), dtype=np.int32)
    remaining = n_r
    for idx in order:
        r, g = rr[idx], gg[idx]
        if assign[r] == -1 and load[g] < capacity:
            assign[r] = g
            load[g] += 1
            remaining -= 1
            if remaining == 0:
                break
    # Any leftovers (capacity exhausted by ties) -> least-loaded gateway.
    left = np.flatnonzero(assign == -1)
    for r in left:
        g = int(np.argmin(load))
        assign[r] = g
        load[g] += 1
    return assign


def _balanced_assignment(routers: np.ndarray, gw_pos: np.ndarray,
                         capacity: int) -> np.ndarray:
    """Greedy balanced nearest-gateway partition (see `..._from_dist`)."""
    dist = hop_count(routers[:, None, :], gw_pos[None, :, :])  # [R, G]
    return _balanced_assignment_from_dist(dist, capacity)


@dataclasses.dataclass(frozen=True)
class SelectionTables:
    """Design-time tables, one slice per activation level g in 1..G.

    src_map:  [G, R] int  — source gateway index for each router when g
                            gateways are active (entries < g).
    dst_map:  [G, R] int  — destination gateway for each destination router.
    src_hops: [G]  float  — mean router->gateway hops under src_map.
    dst_hops: [G]  float  — mean gateway->router hops under dst_map.
    gw_loss_db: [G] float — mean access-waveguide loss (dB) over the active
                            gateways at each level (placement-derived:
                            photonics.gateway_access_loss_db).
    gw_pos:   [Gmax, 2]   — gateway coordinates (activation order).
    """
    src_map: np.ndarray
    dst_map: np.ndarray
    src_hops: np.ndarray
    dst_hops: np.ndarray
    gw_loss_db: np.ndarray
    gw_pos: np.ndarray

    def as_jax(self) -> dict:
        return {"src_map": jnp.asarray(self.src_map),
                "dst_map": jnp.asarray(self.dst_map),
                "src_hops": jnp.asarray(self.src_hops),
                "dst_hops": jnp.asarray(self.dst_hops),
                "gw_loss_db": jnp.asarray(self.gw_loss_db)}


def build_selection_tables(cfg: NetworkConfig = NETWORK) -> SelectionTables:
    """Build (and memoize) the design-time tables for one topology.

    `NetworkConfig` is a frozen dataclass, so equal configs hash equally and
    the greedy numpy construction runs at most once per distinct topology —
    table lookups inside jit-compiled sweeps are free after the first call.
    The default is normalized *before* the cache so `build_selection_tables()`
    and `build_selection_tables(NETWORK)` share one entry. The returned
    `SelectionTables` (and its arrays) must be treated as immutable by
    callers.
    """
    return _build_selection_tables_cached(cfg)


@functools.lru_cache(maxsize=None)
def _build_selection_tables_cached(cfg: NetworkConfig) -> SelectionTables:
    routers = _router_coords(cfg)
    gw_pos = resolve_gateway_positions(cfg)
    n_r = len(routers)
    g_max = cfg.max_gateways_per_chiplet

    # One vectorized [R, Gmax] hop matrix feeds every activation level; the
    # per-level work is the greedy capacity walk plus fancy-indexed means.
    # pair_hops is the Manhattan closed form on meshes (bit parity) and the
    # BFS hop matrix on explicit-coords layouts.
    dist = topology.pair_hops(cfg, routers[:, None, :],
                              gw_pos[None, :, :])               # [R, Gmax]
    levels = np.arange(1, g_max + 1)
    caps = -(-n_r // levels)                                    # ceil(R/g)

    src_map = np.stack([
        _balanced_assignment_from_dist(dist[:, :g], int(cap))
        for g, cap in zip(levels, caps)])                       # [Gmax, R]
    dst_map = src_map.copy()        # step-3 tables share the balance rule
    hops = np.take_along_axis(dist, src_map.T, axis=1)          # [R, Gmax]
    src_hops = hops.mean(axis=0).astype(np.float32)
    dst_hops = src_hops.copy()
    # Level-g mean access loss: running mean over the first g placed
    # gateways — the laser must overcome the average lit access waveguide.
    per_gw_db = photonics.gateway_access_loss_db(gw_pos, cfg)
    gw_loss_db = (np.cumsum(per_gw_db) / levels).astype(np.float32)

    return SelectionTables(src_map=src_map.astype(np.int32),
                           dst_map=dst_map.astype(np.int32),
                           src_hops=src_hops, dst_hops=dst_hops,
                           gw_loss_db=gw_loss_db, gw_pos=gw_pos)


# Cache-management handles for instrumentation (simulator.engine_stats) and
# baselines (simulator.SelectionTables_rebuild): same surface lru_cache
# would have put on the public name.
build_selection_tables.cache_info = _build_selection_tables_cached.cache_info
build_selection_tables.cache_clear = \
    _build_selection_tables_cached.cache_clear
build_selection_tables.__wrapped__ = \
    _build_selection_tables_cached.__wrapped__


# ---------------------------------------------------------------------------
# Traceable placement->tables path (device-resident search, PR 5)
# ---------------------------------------------------------------------------

def placement_tables_jnp(positions, cfg: NetworkConfig = NETWORK) -> dict:
    """Traceable twin of the `build_selection_tables` hot columns.

    From a (possibly traced) [G, 2] placement in activation order, builds
    exactly the two per-activation-level columns the epoch simulator
    consumes — `src_hops` (mean router->gateway hops under the §3.4 balanced
    partition) and `gw_loss_db` (running-mean access-waveguide loss) —
    entirely in jnp, so candidate placements never leave the device
    (repro.core.search scores thousands of candidates without a host
    round-trip). Matches the numpy builder at 1e-6 for arbitrary placements
    on any mesh (tests/test_search.py). The full src_map/dst_map router
    tables stay design-time numpy: nothing in the epoch-level scan reads
    them.

    The numpy builder walks (router, gateway) pairs one at a time in
    (distance, router, gateway) order — inherently sequential, and slow as
    compiled code (R*g scatter steps per level). This twin uses an exactly
    equivalent *class-column* schedule: for each distance value d
    (ascending), for each gateway column g (ascending), take the first
    `capacity - load_g` still-unassigned distance-d candidates of g in
    router order — one masked cumsum over the router axis per (d, g) step.
    Equivalence: within a distance class the pair walk assigns router r at
    its smallest in-class gateway with spare capacity at its turn, and by
    induction over g the winner set of each column is exactly "the first
    cap_left unassigned candidates in router order" — which is what the
    cumsum computes. That turns sum_g R*g scalar steps into
    (mesh_x + mesh_y - 1) * G fully vectorized ones, with all G activation
    levels riding as batched lanes (this is the search's hot inner loop,
    rebuilt per candidate per generation). Pinned bit-exact against the
    numpy walk across meshes in tests/test_search.py.
    """
    pos = jnp.asarray(positions, jnp.int32).reshape(-1, 2)
    g_max = int(pos.shape[0])
    routers = jnp.asarray(_router_coords(cfg))
    n_r = int(routers.shape[0])
    if cfg.coords is None:
        # Derived mesh: the Manhattan closed form, bit-identical to the
        # pre-coords code path (d values 0 .. mesh_x + mesh_y - 2).
        d_vals = cfg.mesh_x + cfg.mesh_y - 1   # distinct Manhattan values
        dist = jnp.sum(jnp.abs(routers[:, None, :] - pos[None, :, :]),
                       axis=-1).astype(jnp.int32)              # [R, G]
    else:
        # Explicit layout: hop distances are gathers from the design-time
        # BFS LUT — same integer values pair_hops gives the numpy builder.
        d_vals = topology.max_hops(cfg) + 1
        lut = jnp.asarray(topology.hop_lut(cfg))               # [R, X, Y]
        dist = lut[:, pos[:, 0], pos[:, 1]].astype(jnp.int32)  # [R, G]
    caps = jnp.asarray([-(-n_r // g) for g in range(1, g_max + 1)],
                       jnp.int32)                              # ceil(R/g)
    level_has = np.arange(1, g_max + 1)        # lane l uses gateways < l+1

    assigned = jnp.zeros((g_max, n_r), bool)   # [L, R]
    assign_d = jnp.zeros((g_max, n_r), jnp.float32)
    load = [jnp.zeros((g_max,), jnp.int32) for _ in range(g_max)]
    for d in range(d_vals):
        for g in range(g_max):
            lane_on = jnp.asarray(level_has > g)               # [L] static
            cand = ((~assigned) & (dist[None, :, g] == d)
                    & lane_on[:, None])                        # [L, R]
            k = jnp.cumsum(cand.astype(jnp.int32), axis=1)     # router order
            take = cand & (k <= (caps - load[g])[:, None])
            assigned = assigned | take
            assign_d = jnp.where(take, jnp.float32(d), assign_d)
            load[g] = load[g] + jnp.sum(take.astype(jnp.int32), axis=1)

    per_gw_db = photonics.gateway_access_loss_db_jnp(pos, cfg)
    levels = jnp.arange(1, g_max + 1, dtype=jnp.float32)
    return {"src_hops": jnp.mean(assign_d, axis=1),
            "gw_loss_db": jnp.cumsum(per_gw_db) / levels}


def placement_tables_from_lut_jnp(positions, hop_lut, edge_lut,
                                  router_mask, caps, *, d_pad: int,
                                  db_per_hop: float) -> dict:
    """`placement_tables_jnp` with the topology as TRACED data.

    The co-design engine (repro.core.pareto) scans over topology grid
    points inside ONE compiled executable, so the chiplet geometry cannot
    be a static `NetworkConfig`: everything shape-defining is padded and
    rides as scan inputs. This twin runs the identical class-column
    assignment schedule, with:

      positions   [g_pad, 2] int  — candidate placement (padded gateway
                  rows must hold any in-bounds coordinate; lanes beyond
                  the real gateway count are masked by the consumer).
      hop_lut     [r_pad, X, Y]   — router -> coordinate hops (padded
                  router rows arbitrary, they are masked out).
      edge_lut    [X, Y]          — boundary distance per coordinate.
      router_mask [r_pad]         — 1.0 where the router exists.
      caps        [g_pad] int     — per-level capacity ceil(R_real / g).
      d_pad       static int      — loop bound: max hop distance + 1 over
                  every topology sharing the executable.
      db_per_hop  static float    — access-waveguide dB per hop
                  (router_pitch_mm * waveguide_db_per_mm).

    On an unpadded mesh fed its own LUTs this reproduces
    `placement_tables_jnp` bit-for-bit (tests/test_pareto.py pins it).
    """
    pos = jnp.asarray(positions, jnp.int32).reshape(-1, 2)
    g_pad = int(pos.shape[0])
    lut = jnp.asarray(hop_lut)
    r_pad = int(lut.shape[0])
    router_on = jnp.asarray(router_mask, bool).reshape(r_pad)
    caps = jnp.asarray(caps, jnp.int32).reshape(g_pad)
    n_real = jnp.maximum(jnp.sum(router_on.astype(jnp.float32)), 1.0)
    dist = lut[:, pos[:, 0], pos[:, 1]].astype(jnp.int32)      # [R, G]
    level_has = np.arange(1, g_pad + 1)        # lane l uses gateways < l+1

    assigned = jnp.zeros((g_pad, r_pad), bool)   # [L, R]
    assign_d = jnp.zeros((g_pad, r_pad), jnp.float32)
    load = [jnp.zeros((g_pad,), jnp.int32) for _ in range(g_pad)]
    for d in range(d_pad):
        for g in range(g_pad):
            lane_on = jnp.asarray(level_has > g)               # [L] static
            cand = ((~assigned) & (dist[None, :, g] == d)
                    & lane_on[:, None] & router_on[None, :])   # [L, R]
            k = jnp.cumsum(cand.astype(jnp.int32), axis=1)     # router order
            take = cand & (k <= (caps - load[g])[:, None])
            assigned = assigned | take
            assign_d = jnp.where(take, jnp.float32(d), assign_d)
            load[g] = load[g] + jnp.sum(take.astype(jnp.int32), axis=1)

    per_gw_db = (edge_lut[pos[:, 0], pos[:, 1]].astype(jnp.float32)
                 * jnp.float32(db_per_hop))
    levels = jnp.arange(1, g_pad + 1, dtype=jnp.float32)
    return {"src_hops": jnp.sum(assign_d, axis=1) / n_real,
            "gw_loss_db": jnp.cumsum(per_gw_db) / levels}


@dataclasses.dataclass(frozen=True)
class PaddedSelectionTables:
    """Stacked, zero-padded tables for K topologies sharing ONE shape.

    All per-topology tables are padded to (g_pad activation levels, r_pad
    routers) so a topology sweep can vmap over the leading K axis inside a
    single compiled executable. Padded entries are zero and carry validity
    masks; the masking invariant is that a consumer which multiplies by the
    masks sees provably zero contribution from every padded slot.

    src_map/dst_map: [K, g_pad, r_pad] int   — padded with gateway 0.
    src_hops/dst_hops: [K, g_pad] float      — padded with 0.0 hops.
    gw_loss_db:  [K, g_pad] float — per-level mean access loss, 0-padded.
    gw_mask:     [K, g_pad] float — 1 where the activation level exists.
    router_mask: [K, r_pad] float — 1 where the router exists.
    n_gateways:  [K] int — real max gateways per chiplet per topology.
    n_routers:   [K] int — real router count per topology.
    """
    src_map: np.ndarray
    dst_map: np.ndarray
    src_hops: np.ndarray
    dst_hops: np.ndarray
    gw_loss_db: np.ndarray
    gw_mask: np.ndarray
    router_mask: np.ndarray
    n_gateways: np.ndarray
    n_routers: np.ndarray

    def as_jax(self) -> dict:
        return {k: jnp.asarray(getattr(self, k))
                for k in ("src_map", "dst_map", "src_hops", "dst_hops",
                          "gw_loss_db", "gw_mask", "router_mask",
                          "n_gateways", "n_routers")}


def build_selection_tables_padded(
        cfgs, pad_to: Tuple[int, int] | None = None) -> PaddedSelectionTables:
    """Build stacked zero-masked tables for a tuple of topologies.

    `pad_to = (g_pad, r_pad)` fixes the padded activation-level and router
    axes; None pads to the max over `cfgs`. Memoized per (cfgs, pad_to) —
    the per-topology builds themselves reuse the per-config lru_cache, and
    topologies that differ only in `n_chiplets` share one underlying build
    (selection tables are a per-chiplet-mesh structure).
    """
    cfgs = tuple(cfgs)
    if pad_to is None:
        pad_to = (max(c.max_gateways_per_chiplet for c in cfgs),
                  max(c.routers_per_chiplet for c in cfgs))
    return _build_selection_tables_padded_cached(cfgs, tuple(pad_to))


@functools.lru_cache(maxsize=None)
def _build_selection_tables_padded_cached(
        cfgs: Tuple[NetworkConfig, ...],
        pad_to: Tuple[int, int]) -> PaddedSelectionTables:
    g_pad, r_pad = pad_to
    k = len(cfgs)
    src_map = np.zeros((k, g_pad, r_pad), np.int32)
    dst_map = np.zeros((k, g_pad, r_pad), np.int32)
    src_hops = np.zeros((k, g_pad), np.float32)
    dst_hops = np.zeros((k, g_pad), np.float32)
    gw_loss_db = np.zeros((k, g_pad), np.float32)
    gw_mask = np.zeros((k, g_pad), np.float32)
    router_mask = np.zeros((k, r_pad), np.float32)
    n_gw = np.zeros((k,), np.int32)
    n_rt = np.zeros((k,), np.int32)

    for i, cfg in enumerate(cfgs):
        # n_chiplets does not enter the per-chiplet tables: canonicalize so
        # e.g. a 4..64-chiplet scan over one mesh builds tables exactly once.
        key_cfg = dataclasses.replace(cfg, n_chiplets=1)
        t = build_selection_tables(key_cfg)
        g, r = t.src_map.shape
        if g > g_pad or r > r_pad:
            raise ValueError(f"pad_to {pad_to} smaller than topology "
                             f"{i} tables {(g, r)}")
        src_map[i, :g, :r] = t.src_map
        dst_map[i, :g, :r] = t.dst_map
        src_hops[i, :g] = t.src_hops
        dst_hops[i, :g] = t.dst_hops
        gw_loss_db[i, :g] = t.gw_loss_db
        gw_mask[i, :g] = 1.0
        router_mask[i, :r] = 1.0
        n_gw[i], n_rt[i] = g, r

    return PaddedSelectionTables(
        src_map=src_map, dst_map=dst_map, src_hops=src_hops,
        dst_hops=dst_hops, gw_loss_db=gw_loss_db, gw_mask=gw_mask,
        router_mask=router_mask, n_gateways=n_gw, n_routers=n_rt)


@functools.lru_cache(maxsize=None)
def _padded_tables_jax_cached(cfgs, pad_to) -> dict:
    return _build_selection_tables_padded_cached(cfgs, pad_to).as_jax()


def padded_selection_tables_jax(
        cfgs, pad_to: Tuple[int, int] | None = None) -> dict:
    """Memoized device-resident view of the padded tables (see
    `selection_tables_jax` for the single-topology analogue)."""
    cfgs = tuple(cfgs)
    if pad_to is None:
        pad_to = (max(c.max_gateways_per_chiplet for c in cfgs),
                  max(c.routers_per_chiplet for c in cfgs))
    return _padded_tables_jax_cached(cfgs, tuple(pad_to))


def selection_tables_jax(cfg: NetworkConfig = NETWORK) -> dict:
    """Memoized device-resident view of the tables for `cfg`.

    Returns the *same* dict (same jax arrays) for equal configs, so repeated
    `simulate` calls ship identical buffers to jit and never re-upload.
    """
    return _selection_tables_jax_cached(cfg)


@functools.lru_cache(maxsize=None)
def _selection_tables_jax_cached(cfg: NetworkConfig) -> dict:
    return build_selection_tables(cfg).as_jax()


def select_source_gateway(tables: dict, router: jnp.ndarray,
                          g_src: jnp.ndarray) -> jnp.ndarray:
    """Step-1 selection: local table lookup (router only knows g_src)."""
    return tables["src_map"][g_src - 1, router]


def select_dest_gateway(tables: dict, dest_router: jnp.ndarray,
                        g_dst: jnp.ndarray) -> jnp.ndarray:
    """Step-2 selection at the source gateway (knows g_dst, §3.4)."""
    return tables["dst_map"][g_dst - 1, dest_router]


def mean_access_hops(tables: dict, g: jnp.ndarray) -> jnp.ndarray:
    """Mean router<->gateway hop count at activation level g (vectorized)."""
    return tables["src_hops"][jnp.maximum(g, 1) - 1]
