"""Intra-chiplet NoC latency model (epoch scale).

The Level-1 simulator models each reconfiguration interval with a queueing
abstraction instead of Noxim's cycle-accurate flit walk (DESIGN.md §9.2). The
model has three serial segments per inter-chiplet packet (§3.4):

  (1) source router -> source gateway:   mesh hops + convergence queueing
  (2) gateway -> gateway over photonics: serialization + M/D/1 gateway queue
  (3) destination gateway -> dest router: mesh hops + ejection queueing

plus plain mesh latency for intra-chiplet packets. Queueing terms use M/D/1
waiting time with a burstiness multiplier and a finite-buffer backpressure
amplification — the two effects that make small-buffer NoCs saturate well
below link capacity. Calibration constants are collected in `NocModel` and
documented; tests/test_noc.py pins their qualitative properties (monotone in
load, decreasing in gateways, knee location).

The flit-level Pallas kernel (kernels/noc_step) cross-validates this model on
short windows and produces the Fig. 13 residency maps.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.constants import NETWORK, NetworkConfig


@dataclasses.dataclass(frozen=True)
class NocModel:
    cfg: NetworkConfig = NETWORK
    router_pipeline_cycles: float = 2.0   # per-hop pipelined router traversal
    photonic_flight_cycles: float = 2.0   # time-of-flight + E/O + O/E
    burstiness: float = 3.0               # PARSEC batch-arrival factor
    # Finite-buffer backpressure: with 4-flit router / 8-flit gateway buffers
    # the network saturates at an effective utilization rho_sat < 1. The
    # queueing term diverges as rho -> buffer_sat instead of 1.0.
    buffer_sat: float = 0.55
    # Mesh links adjacent to a gateway router that traffic converges onto.
    feed_links: float = 2.0

    def serialization_cycles(self, wavelengths) -> jax.Array:
        """Cycles to push one packet through a gateway with W wavelengths."""
        w = jnp.asarray(wavelengths, jnp.float32)
        bits_per_cycle = w * (self.cfg.link_gbps_per_wavelength
                              / self.cfg.noc_freq_ghz)
        return self.cfg.packet_bits / bits_per_cycle

    @property
    def port_cycles(self) -> float:
        """Electronic gateway-port service time: the chiplet-side NoC ejects
        1 flit/cycle into the gateway (32 Gb/s at 1 GHz x 32-bit flits), so a
        packet needs packet_flits cycles *regardless of optical wavelengths*.
        This is the physical reason deep WDM on a single gateway saturates
        (Fig. 3 / Fig. 13): optical bandwidth beyond ~3 wavelengths outruns
        the electronic port. More gateways = more ports (ReSiPI's insight).
        """
        return float(self.cfg.packet_flits)

    # -- queueing primitives -------------------------------------------------

    def _md1_wait(self, rho: jax.Array, service: jax.Array) -> jax.Array:
        """M/D/1 waiting time with burst amplification and buffer saturation.

        W = b * rho_eff * s / (2 (1 - rho_eff)), rho_eff = rho / rho_sat.
        Clipped slightly below saturation so the epoch model stays finite;
        the simulator reports saturation separately via `saturated` flags.
        """
        rho_eff = jnp.clip(rho / self.buffer_sat, 0.0, 0.995)
        return self.burstiness * rho_eff * service / (2.0 * (1.0 - rho_eff))

    # -- per-segment latencies ----------------------------------------------

    def gateway_latency(self, load_pkts_per_cycle: jax.Array,
                        wavelengths) -> jax.Array:
        """Segment (2): M/D/1 queue at the gateway + serialization + flight.

        `load_pkts_per_cycle` is L from Eq. 5 — per-gateway packet rate.
        The queue's service time is the *slower* of optical serialization and
        the electronic port (see `port_cycles`); transit adds both stages
        pipelined (max) plus time of flight.
        """
        s_opt = self.serialization_cycles(wavelengths)
        s_eff = jnp.maximum(s_opt, self.port_cycles)
        rho = jnp.clip(load_pkts_per_cycle * s_eff, 0.0, 1.0)
        return (s_eff + self._md1_wait(rho, s_eff)
                + self.photonic_flight_cycles)

    def access_latency(self, hops: jax.Array,
                       load_pkts_per_cycle: jax.Array,
                       burst_scale=None) -> jax.Array:
        """Segments (1)/(3): mesh walk to/from the gateway.

        Convergence congestion: all of a gateway's traffic (L pkts/cycle *
        packet_flits flits) crosses ~feed_links mesh links of 1 flit/cycle
        next to the gateway router; local through-traffic is folded into
        buffer_sat.

        `burst_scale` (optional) rescales the queueing term's effective
        burstiness relative to the model default: the destination-aware path
        passes the fan-in concentration factor (a single-source fan-in is
        near-deterministic arrival, b_eff -> 1; a many-source fan-in keeps
        the full PARSEC batch factor). `None` leaves the term untouched —
        the uniform-destination path is bit-identical to the pre-dest model.
        """
        walk = hops * self.router_pipeline_cycles
        flits_per_cycle = load_pkts_per_cycle * self.cfg.packet_flits
        rho_link = jnp.clip(flits_per_cycle / self.feed_links, 0.0, 1.0)
        link_service = jnp.float32(self.cfg.packet_flits)  # 1 flit/cycle links
        wait = self._md1_wait(rho_link, link_service)
        if burst_scale is not None:
            wait = wait * burst_scale
        return walk + wait

    def mesh_latency(self, mean_hops: jax.Array,
                     link_load_flits: jax.Array) -> jax.Array:
        """Intra-chiplet (non-gateway) packets: uniform-mesh M/D/1 per link."""
        walk = mean_hops * self.router_pipeline_cycles
        rho = jnp.clip(link_load_flits, 0.0, 1.0)
        service = jnp.float32(self.cfg.packet_flits)
        return (walk + self.cfg.packet_flits
                + self._md1_wait(rho, service))

    # -- composite -----------------------------------------------------------

    def inter_chiplet_latency(self, gw_load: jax.Array, wavelengths,
                              src_hops: jax.Array, dst_hops: jax.Array
                              ) -> jax.Array:
        """End-to-end latency for an inter-chiplet packet (all segments)."""
        return (self.access_latency(src_hops, gw_load)
                + self.gateway_latency(gw_load, wavelengths)
                + self.access_latency(dst_hops, gw_load))

    def saturated(self, gw_load: jax.Array, wavelengths) -> jax.Array:
        """True when the gateway queue has crossed the buffer knee."""
        s = jnp.maximum(self.serialization_cycles(wavelengths),
                        self.port_cycles)
        return gw_load * s > self.buffer_sat


def uniform_mesh_mean_hops(cfg: NetworkConfig = NETWORK) -> float:
    """Mean hop count between uniformly random iid routers.

    Derived-mesh configs keep the exact closed form (E|x1-x2| for uniform
    iid on {0..n-1} is (n^2-1)/(3n) per axis); explicit-coords layouts
    average the BFS hop matrix (repro.core.topology.mean_hops — identical
    on full grids).
    """
    from repro.core import topology
    return topology.mean_hops(cfg)
