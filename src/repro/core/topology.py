"""Router coordinate / adjacency model (the arbitrary-layout refactor).

Until PR 10 every table builder in the stack — `selection` balanced
partitions, `gateway_controller` activation spread, `photonics` access
loss, the `noc_step` flit router — assumed an implicit mesh-radix layout:
router coordinates were a `mesh_x x mesh_y` grid, distances were Manhattan
closed forms, and "edge" meant the grid border. That blocked exactly the
layouts the co-design literature searches over (PlaceIT's placement-based
topologies, HexaMesh's hexagonal hundreds-of-chiplet arrangements).

This module is the single source of truth for router geometry:

  * `router_coords(cfg)`   — [R, 2] integer coordinates. The mesh grid is
    the DERIVED DEFAULT (`cfg.coords is None`); explicit
    `NetworkConfig.coords` (a hashable tuple) pins an arbitrary layout,
    with `hex_coords(rings)` as the first generator beyond the mesh.
  * `hop_matrix(cfg)`      — [R, R] shortest-path hops. Meshes keep the
    exact Manhattan closed form (bit parity with the pre-PR code paths);
    explicit layouts run BFS over the `coord_model` adjacency (mesh
    4-neighbor / hex 6-neighbor), so partial or holed layouts route
    *around* missing routers instead of through them.
  * gather LUTs (`hop_lut`, `router_index_lut`, `edge_lut`,
    `centrality_lut`) — dense [X, Y]-indexed numpy constants that let the
    TRACEABLE twins (`selection.placement_tables_jnp`,
    `gateway_controller.activation_order_jnp`, the device search) consume
    arbitrary layouts as pure gathers on traced (x, y) positions. On a
    mesh every gather reproduces the old closed form exactly — the 1e-6
    (mostly bit-exact) parity the existing placement/topology tests pin.

Everything here is design-time numpy, lru-memoized per frozen
`NetworkConfig` (the same compile-free discipline as the selection
tables); arrays are returned read-only and must not be mutated.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.constants import NETWORK, NetworkConfig

# Adjacency generators per coordinate model. Mesh: 4-neighbor grid steps.
# Hex: axial-coordinate neighbors — with hex layouts stored as shifted
# axial (q, r) pairs, the six unit moves are the four grid steps plus the
# two anti-diagonal ones.
NEIGHBOR_OFFSETS = {
    "mesh": ((1, 0), (-1, 0), (0, 1), (0, -1)),
    "hex": ((1, 0), (-1, 0), (0, 1), (0, -1), (1, -1), (-1, 1)),
}


def _ro(a: np.ndarray) -> np.ndarray:
    a.setflags(write=False)
    return a


def check_coord_model(model: str) -> None:
    if model not in NEIGHBOR_OFFSETS:
        raise ValueError(f"unknown coord_model {model!r} "
                         f"(known: {sorted(NEIGHBOR_OFFSETS)})")


def hex_coords(rings: int) -> tuple:
    """Hexagonal router layout: `rings` full rings around a center router.

    Axial coordinates (q, r) with max(|q|, |r|, |q+r|) <= rings, shifted by
    +rings so every coordinate is non-negative (3*rings*(rings+1)+1
    routers). Row order is lexicographic in the shifted (x, y) — the
    hex analogue of the mesh's x*mesh_y+y router ordering. Returns the
    hashable tuple form `NetworkConfig.coords` carries.
    """
    if rings < 1:
        raise ValueError(f"hex layout needs rings >= 1, got {rings}")
    out = []
    for q in range(-rings, rings + 1):
        for r in range(-rings, rings + 1):
            if abs(q + r) <= rings:
                out.append((q + rings, r + rings))
    return tuple(out)


def hex_config(rings: int, base: NetworkConfig = NETWORK,
               **replace) -> NetworkConfig:
    """A `base`-derived config whose chiplet network is a hexagonal patch.

    Sets `coords=hex_coords(rings)`, `coord_model="hex"`, and sizes
    `mesh_x`/`mesh_y` to the layout's bounding box (the dense LUT shape —
    nothing below reads them as a router count once `coords` is set).
    """
    import dataclasses

    coords = hex_coords(rings)
    side = 2 * rings + 1
    return dataclasses.replace(base, coords=coords, coord_model="hex",
                               mesh_x=side, mesh_y=side,
                               gateway_positions=None, **replace)


@functools.lru_cache(maxsize=None)
def router_coords(cfg: NetworkConfig) -> np.ndarray:
    """[R, 2] int32 router coordinates (mesh grid unless cfg.coords pins
    an explicit layout). Mesh row order is flat index x*mesh_y + y."""
    if cfg.coords is not None:
        pos = np.asarray(cfg.coords, np.int32).reshape(-1, 2)
        if pos.min() < 0:
            raise ValueError(f"negative router coordinates in "
                             f"NetworkConfig.coords: {cfg.coords}")
        if len(np.unique(pos, axis=0)) != len(pos):
            raise ValueError("NetworkConfig.coords contains duplicate "
                             "router coordinates")
        return _ro(pos)
    xs, ys = np.meshgrid(np.arange(cfg.mesh_x), np.arange(cfg.mesh_y),
                         indexing="ij")
    return _ro(np.stack([xs.ravel(), ys.ravel()], axis=-1).astype(np.int32))


def lut_shape(cfg: NetworkConfig) -> tuple:
    """(X, Y) dense lookup-table shape covering every router coordinate."""
    if cfg.coords is None:
        return (cfg.mesh_x, cfg.mesh_y)
    pos = router_coords(cfg)
    return (int(pos[:, 0].max()) + 1, int(pos[:, 1].max()) + 1)


@functools.lru_cache(maxsize=None)
def router_index_lut(cfg: NetworkConfig) -> np.ndarray:
    """[X, Y] int32: coordinate -> router row index, -1 off-layout.

    On a mesh this is exactly the flat index x*mesh_y + y the pre-PR
    occupancy tests used — the traceable search keeps its integer
    semantics through a gather instead of a multiply-add.
    """
    pos = router_coords(cfg)
    lut = np.full(lut_shape(cfg), -1, np.int32)
    lut[pos[:, 0], pos[:, 1]] = np.arange(len(pos), dtype=np.int32)
    return _ro(lut)


@functools.lru_cache(maxsize=None)
def hop_matrix(cfg: NetworkConfig) -> np.ndarray:
    """[R, R] int32 router-to-router hop counts.

    Mesh default: the Manhattan closed form (bit parity with the pre-PR
    `selection.hop_count` paths — XY routing hops). Explicit layouts:
    BFS shortest path over the `coord_model` adjacency, which equals the
    metric closed form on full patches and stays correct on partial ones.
    Raises on disconnected layouts (a router no packet can reach is a
    modelling error, not a soft case).
    """
    pos = router_coords(cfg).astype(np.int64)
    if cfg.coords is None:
        d = np.abs(pos[:, None, :] - pos[None, :, :]).sum(-1)
        return _ro(d.astype(np.int32))
    check_coord_model(cfg.coord_model)
    idx = router_index_lut(cfg)
    n = len(pos)
    xmax, ymax = idx.shape
    neigh = []
    for dx, dy in NEIGHBOR_OFFSETS[cfg.coord_model]:
        nx, ny = pos[:, 0] + dx, pos[:, 1] + dy
        ok = (0 <= nx) & (nx < xmax) & (0 <= ny) & (ny < ymax)
        j = np.where(ok, idx[np.clip(nx, 0, xmax - 1),
                            np.clip(ny, 0, ymax - 1)], -1)
        neigh.append(j)
    neigh = np.stack(neigh, axis=1)                       # [R, deg], -1 pad
    dist = np.full((n, n), -1, np.int32)
    for s in range(n):                                    # BFS per source
        dist[s, s] = 0
        frontier = [s]
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for v in neigh[u]:
                    if v >= 0 and dist[s, v] < 0:
                        dist[s, v] = d
                        nxt.append(int(v))
            frontier = nxt
    if (dist < 0).any():
        raise ValueError(
            f"NetworkConfig.coords describes a disconnected "
            f"{cfg.coord_model} layout ({int((dist[0] < 0).sum())} "
            f"unreachable routers from router 0)")
    return _ro(dist)


@functools.lru_cache(maxsize=None)
def hop_lut(cfg: NetworkConfig) -> np.ndarray:
    """[R, X, Y] int32: hops from router r to the router AT (x, y).

    Off-layout (x, y) slots hold max_hops + 1 (a finite, dominated
    sentinel — valid placements never gather them; masked consumers can
    rely on the value staying within int range).
    """
    pos = router_coords(cfg)
    hm = hop_matrix(cfg)
    lut = np.full((len(pos),) + lut_shape(cfg), int(hm.max()) + 1, np.int32)
    lut[:, pos[:, 0], pos[:, 1]] = hm
    return _ro(lut)


@functools.lru_cache(maxsize=None)
def max_hops(cfg: NetworkConfig) -> int:
    """Network diameter in hops (mesh: mesh_x + mesh_y - 2)."""
    return int(hop_matrix(cfg).max())


@functools.lru_cache(maxsize=None)
def mean_hops(cfg: NetworkConfig) -> float:
    """Mean hop count between uniformly random (iid) router pairs.

    Mesh default keeps the exact closed form the NoC model always used
    (E|x1-x2| = (n^2-1)/(3n) per axis); explicit layouts average the hop
    matrix — identical on full grids, correct on everything else.
    """
    if cfg.coords is None:
        mx, my = cfg.mesh_x, cfg.mesh_y
        ex = (mx * mx - 1) / (3.0 * mx)
        ey = (my * my - 1) / (3.0 * my)
        return float(ex + ey)
    return float(hop_matrix(cfg).mean())


def feed_width(cfg: NetworkConfig) -> float:
    """Mesh-feed width for the intra-chiplet link-load model.

    The scan body divides injected intra-chiplet flit load over
    2 * feed_width parallel mesh rows. Mesh: mesh_x (the pre-PR constant,
    bit parity). Explicit layouts: sqrt(R) — the equivalent-area square's
    row count, so hex patches see a comparable bisection.
    """
    if cfg.coords is None:
        return float(cfg.mesh_x)
    return float(np.sqrt(len(router_coords(cfg))))


@functools.lru_cache(maxsize=None)
def edge_distance(cfg: NetworkConfig) -> np.ndarray:
    """[R] int32 hops from each router to the layout boundary.

    Mesh default: the exact min(x, mx-1-x, y, my-1-y) closed form the
    access-loss model always used. Explicit layouts: hop distance to the
    nearest boundary router, where "boundary" means any router with fewer
    than the full `coord_model` neighbor count — the routers a chiplet's
    edge couplers sit next to.
    """
    pos = router_coords(cfg)
    if cfg.coords is None:
        d = np.minimum.reduce([pos[:, 0], cfg.mesh_x - 1 - pos[:, 0],
                               pos[:, 1], cfg.mesh_y - 1 - pos[:, 1]])
        return _ro(d.astype(np.int32))
    check_coord_model(cfg.coord_model)
    idx = router_index_lut(cfg)
    xmax, ymax = idx.shape
    deg = np.zeros((len(pos),), np.int32)
    for dx, dy in NEIGHBOR_OFFSETS[cfg.coord_model]:
        nx, ny = pos[:, 0] + dx, pos[:, 1] + dy
        ok = (0 <= nx) & (nx < xmax) & (0 <= ny) & (ny < ymax)
        j = np.where(ok, idx[np.clip(nx, 0, xmax - 1),
                            np.clip(ny, 0, ymax - 1)], -1)
        deg += (j >= 0).astype(np.int32)
    boundary = deg < len(NEIGHBOR_OFFSETS[cfg.coord_model])
    if not boundary.any():        # pragma: no cover - degenerate layouts
        boundary = np.ones_like(boundary)
    return _ro(hop_matrix(cfg)[:, boundary].min(axis=1).astype(np.int32))


@functools.lru_cache(maxsize=None)
def edge_lut(cfg: NetworkConfig) -> np.ndarray:
    """[X, Y] int32 boundary distance per coordinate (0 off-layout)."""
    pos = router_coords(cfg)
    lut = np.zeros(lut_shape(cfg), np.int32)
    lut[pos[:, 0], pos[:, 1]] = edge_distance(cfg)
    return _ro(lut)


@functools.lru_cache(maxsize=None)
def centrality_int(cfg: NetworkConfig) -> np.ndarray:
    """[R] int32 centrality key (smaller = more central, scale-free).

    Mesh default: 2x the Manhattan distance to the geometric mesh center —
    the exact integer key `activation_order_jnp` always used, so mesh
    activation orders stay bit-identical. Explicit layouts: total hops to
    every router (the medoid rule), which needs no geometric center.
    """
    pos = router_coords(cfg).astype(np.int64)
    if cfg.coords is None:
        c = (np.abs(2 * pos[:, 0] - (cfg.mesh_x - 1))
             + np.abs(2 * pos[:, 1] - (cfg.mesh_y - 1)))
        return _ro(c.astype(np.int32))
    return _ro(hop_matrix(cfg).sum(axis=1).astype(np.int32))


@functools.lru_cache(maxsize=None)
def centrality_lut(cfg: NetworkConfig) -> np.ndarray:
    """[X, Y] int32 centrality per coordinate (off-layout: big sentinel)."""
    pos = router_coords(cfg)
    cent = centrality_int(cfg)
    lut = np.full(lut_shape(cfg), int(cent.max()) + 1, np.int32)
    lut[pos[:, 0], pos[:, 1]] = cent
    return _ro(lut)


def centrality_bound(cfg: NetworkConfig) -> int:
    """Strict upper bound on `centrality_int` values (composite-key base).

    Mesh keeps the exact pre-PR constant 2*(mesh_x + mesh_y - 2) + 1 so
    the integer activation-order keys are bit-identical there.
    """
    if cfg.coords is None:
        return 2 * (cfg.mesh_x + cfg.mesh_y - 2) + 1
    return int(centrality_int(cfg).max()) + 1


def pair_hops(cfg: NetworkConfig, a, b) -> np.ndarray:
    """Hop count between coordinate arrays a, b (numpy, broadcastable).

    Mesh default: Manhattan (the pre-PR `selection.hop_count`). Explicit
    layouts: hop-matrix lookups — both arrays must hold actual router
    coordinates.
    """
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    if cfg.coords is None:
        return np.abs(a[..., 0] - b[..., 0]) + np.abs(a[..., 1] - b[..., 1])
    idx = router_index_lut(cfg)
    ia, ib = idx[a[..., 0], a[..., 1]], idx[b[..., 0], b[..., 1]]
    if (np.asarray(ia) < 0).any() or (np.asarray(ib) < 0).any():
        raise ValueError("pair_hops: coordinates fall outside the layout "
                         "described by NetworkConfig.coords")
    return hop_matrix(cfg)[ia, ib]


@functools.lru_cache(maxsize=None)
def default_positions(cfg: NetworkConfig) -> np.ndarray:
    """Default gateway placement for an explicit (non-mesh) layout.

    The mesh default is the hand-ordered 4-edge-slot scheme
    (`selection.default_gateway_positions`); layouts with explicit coords
    get its deterministic generalization: gateways sit on boundary routers
    (edge_distance == 0 — zero access-waveguide loss, like the mesh edge
    scheme), the first being the most central boundary router and each
    further one greedily maximizing its minimum hop distance to the chosen
    set (ties: centrality, then router index).
    """
    g = cfg.max_gateways_per_chiplet
    pos = router_coords(cfg)
    cent = centrality_int(cfg)
    hm = hop_matrix(cfg)
    cands = np.flatnonzero(edge_distance(cfg) == 0)
    if len(cands) < g:
        raise ValueError(
            f"layout has {len(cands)} boundary routers but "
            f"max_gateways_per_chiplet={g}; pass explicit "
            f"NetworkConfig.gateway_positions")
    chosen = [int(cands[np.lexsort((cands, cent[cands]))[0]])]
    rest = [int(c) for c in cands if c != chosen[0]]
    while len(chosen) < g:
        dmin = hm[np.asarray(rest)][:, np.asarray(chosen)].min(axis=1)
        best = np.lexsort((rest, cent[np.asarray(rest)], -dmin))[0]
        chosen.append(rest.pop(int(best)))
    return _ro(pos[np.asarray(chosen)].astype(np.int32))


def clear_topology_caches() -> None:
    """Drop every memoized geometry table (test isolation helper)."""
    for f in (router_coords, router_index_lut, hop_matrix, hop_lut,
              max_hops, mean_hops, edge_distance, edge_lut, centrality_int,
              centrality_lut, default_positions):
        f.cache_clear()
