"""Level-2 ReSiPI: reconfigurable communication lanes for the trainer.

The paper's mechanism — meter traffic per epoch, adjust the number of active
gateways with hysteresis (Eqs. 5-7), power-gate the idle ones, and re-divide
input power equally (Eq. 4) — maps onto a multi-pod TPU runtime as follows
(DESIGN.md §2):

  gateway            -> communication *lane*: one chunk-stream of a collective
                        (a gradient reduce-scatter split into `lanes` chunks
                        issues `lanes` smaller collectives that XLA can
                        overlap with compute; MoE all-to-all likewise)
  #active gateways   -> lane width per epoch
  packets/interval   -> collective bytes/step metered per epoch
  PCM reconfigure    -> swapping to the pre-compiled executable for the new
                        lane width (non-volatile: no cost while unchanged)
  laser power (Eq.4) -> equal per-lane bandwidth share; the photonic energy
                        model is reused verbatim to report lane energy

Lane width changes the *program*, so like the paper (design-time selection
tables, §3.4) we pre-compile one executable per lane width and the controller
switches between them at epoch boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import photonics
from repro.core.constants import PHOTONIC_POWER
from repro.core.gateway_controller import (ControllerConfig, ControllerState,
                                           update_gateways)

LANE_WIDTHS = (1, 2, 4)        # pre-compiled variants, like Fig. 8 a-d tables


@dataclasses.dataclass(frozen=True)
class LaneConfig:
    """Controller configuration for communication lanes.

    l_m is the maximum allowable per-lane load in *bytes per step per lane
    bandwidth-second* — i.e. the fraction of a lane's per-step byte budget
    that may be used before we widen (hysteresis mirrors Eqs. 6-7).
    """
    max_lanes: int = max(LANE_WIDTHS)
    min_lanes: int = 1
    l_m: float = 0.60                       # per-lane utilization knee
    lane_bytes_per_step: float = 50e9 * 1e-3  # ICI link bytes in ~1ms step

    def controller(self) -> ControllerConfig:
        return ControllerConfig(l_m=self.l_m, max_gateways=self.max_lanes,
                                min_gateways=self.min_lanes)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LaneState:
    lanes: jax.Array            # scalar int32 — current lane width
    bytes_seen: jax.Array      # scalar float32 — bytes accumulated this epoch
    steps_seen: jax.Array      # scalar int32
    epoch: jax.Array           # scalar int32

    @staticmethod
    def init(cfg: LaneConfig) -> "LaneState":
        return LaneState(lanes=jnp.int32(cfg.max_lanes),
                         bytes_seen=jnp.float32(0.0),
                         steps_seen=jnp.int32(0),
                         epoch=jnp.int32(0))


def meter_step(state: LaneState, bytes_this_step: jax.Array) -> LaneState:
    """Accumulate one step's collective traffic (Eq. 5 numerator)."""
    return LaneState(lanes=state.lanes,
                     bytes_seen=state.bytes_seen + bytes_this_step,
                     steps_seen=state.steps_seen + 1,
                     epoch=state.epoch)


def epoch_update(state: LaneState, cfg: LaneConfig
                 ) -> Tuple[LaneState, Dict[str, jax.Array]]:
    """Epoch-boundary lane decision — Eqs. 5-7 with lanes as gateways."""
    steps = jnp.maximum(state.steps_seen.astype(jnp.float32), 1.0)
    per_step = state.bytes_seen / steps
    load = per_step / (cfg.lane_bytes_per_step
                       * state.lanes.astype(jnp.float32))
    lanes_new = update_gateways(state.lanes[None], load[None],
                                cfg.controller())[0]
    rec = {"load": load, "lanes_before": state.lanes,
           "lanes_after": lanes_new,
           "reconfigured": (lanes_new != state.lanes)}
    return LaneState(lanes=lanes_new, bytes_seen=jnp.float32(0.0),
                     steps_seen=jnp.int32(0), epoch=state.epoch + 1), rec


def nearest_compiled_width(lanes: int,
                           widths: Sequence[int] = LANE_WIDTHS) -> int:
    """Snap a controller decision to the nearest pre-compiled lane width."""
    return min(widths, key=lambda w: (abs(w - lanes), w))


# ---------------------------------------------------------------------------
# Lane materialization: chunked gradient collectives
# ---------------------------------------------------------------------------

def chunk_pytree(tree: Any, lanes: int) -> list:
    """Split a gradient pytree into `lanes` balanced chunks (by byte size).

    Greedy largest-first binning — the per-packet balanced gateway selection
    of §3.4 applied to tensors. Returns a list of `lanes` sub-pytrees (dicts
    keyed by flattened path index).
    """
    if lanes < 1:
        raise ValueError(f"chunk_pytree needs lanes >= 1, got {lanes} — "
                         f"snap controller decisions through "
                         f"nearest_compiled_width first")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [(leaf.size * leaf.dtype.itemsize, i)
             for i, leaf in enumerate(leaves)]
    sizes.sort(reverse=True)
    bins: list = [dict() for _ in range(lanes)]
    loads = [0] * lanes
    for sz, i in sizes:
        b = loads.index(min(loads))
        bins[b][i] = leaves[i]
        loads[b] += sz
    return bins


def merge_chunks(bins: list, like: Any) -> Any:
    """Inverse of chunk_pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = [None] * len(leaves)
    for b in bins:
        for i, leaf in b.items():
            out[i] = leaf
    return jax.tree_util.tree_unflatten(treedef, out)


def laned_psum(tree: Any, axis_name: str, lanes: int) -> Any:
    """All-reduce a pytree as `lanes` independent chunk streams.

    Each chunk is a separate jax.lax.psum: XLA's latency-hiding scheduler can
    overlap chunk k+1's communication with whatever compute consumes chunk k
    — the TPU rendering of "more gateways, each narrower" (Fig. 3 design B).
    With lanes=1 this is the classical single fused all-reduce (design A).

    Lanes are chained through `optimization_barrier` so XLA's all-reduce
    combiner cannot re-fuse them into one deep collective: each lane stays
    a separate wire-level stream the scheduler can interleave with the
    consumer's compute (verified in tests/test_laned_sync.py by counting
    all-reduce ops in the compiled HLO per width).
    """
    if axis_name is None:        # outside shard_map (tests): identity
        return tree
    if lanes <= 1:
        return jax.lax.psum(tree, axis_name)
    bins = chunk_pytree(tree, lanes)
    reduced = []
    token = None
    for b in bins:
        if not b:
            reduced.append(b)
            continue
        if token is not None:
            b, _ = jax.lax.optimization_barrier((b, token))
        out = jax.lax.psum(b, axis_name)
        token = jax.tree.leaves(out)[0]
        reduced.append(out)
    return merge_chunks(reduced, tree)


def collective_bytes_of(tree: Any, axis_size: int) -> jax.Array:
    """Static per-step all-reduce traffic estimate: 2*(n-1)/n * bytes."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = sum(leaf.size * leaf.dtype.itemsize for leaf in leaves)
    return jnp.float32(2.0 * (axis_size - 1) / axis_size * total)


# ---------------------------------------------------------------------------
# Energy accounting: reuse the photonic interposer model for lanes
# ---------------------------------------------------------------------------

def lane_energy_report(lanes_history: jax.Array, cfg: LaneConfig) -> dict:
    """Report lane energy with the paper's power model (per-epoch).

    Lanes map to gateways with 4 'wavelengths' each; idle lanes are
    PCM-gated. Reconfigurations pay the 2 nJ PCM cost each. Units are model
    mW/nJ — used for *relative* schedule comparisons, as in Fig. 11.

    Besides the scalar aggregates, the report carries the cumulative audit
    trail that `epoch_update`'s `reconfigured` records feed: per-epoch
    running `cum_switches` / `cum_pcm_nj` ([T], epoch t includes the switch
    INTO epoch t), plus the `switch_count` total — so a lane schedule's
    reconfiguration history is auditable from the report alone.
    """
    max_l = cfg.max_lanes

    def power_of(l):
        active = jnp.arange(max_l) < l
        pw = photonics.interposer_power_mw(active, jnp.float32(4.0),
                                           n_gateways=max_l, mode="pcm")
        return pw["total_mw"]

    powers = jax.vmap(power_of)(lanes_history)
    changed = (jnp.diff(lanes_history) != 0).astype(jnp.float32)
    switches = jnp.sum(changed)
    # Epoch 0 inherits its width (no switch); epoch t>0 switched iff the
    # width differs from epoch t-1's.
    cum_switches = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                                    jnp.cumsum(changed)])
    return {"mean_power_mw": jnp.mean(powers),
            "reconfig_nj": switches * PHOTONIC_POWER.pcmc_reconfig_nj,
            "mean_lanes": jnp.mean(lanes_history.astype(jnp.float32)),
            "switch_count": switches,
            "cum_switches": cum_switches,
            "cum_pcm_nj": cum_switches * PHOTONIC_POWER.pcmc_reconfig_nj}
