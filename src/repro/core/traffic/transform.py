"""Trace transforms: validation, slicing, concatenation, time padding.

Every transform validates its inputs up front (`validate_trace`), so a
malformed trace fails with a clear message here instead of deep inside a jit
trace. The time-padding helpers (`pad_trace` / `trace_length`) implement the
ragged-T contract: a padded trace carries a `t_mask` [T] validity vector and
the engine guarantees masked intervals contribute exactly zero to every
latency/power/energy reduction (see simulator._simulate_impl).
"""
from __future__ import annotations

import jax.core as jax_core
import jax.numpy as jnp
import numpy as np

# The array keys every trace must carry (plus the "app" label, the ragged-T
# "t_mask", and the optional "dest" destination matrix). "dest" is [C, C] and
# time-free: it must never be sliced/padded along T (with C == T the shape
# check alone could not tell them apart), so it lives in the meta set and is
# carried whole by every transform; only `slice_trace` touches it (chiplet
# axis) and `concat_traces` mixes it load-weighted.
TRACE_KEYS = ("ext_load", "mem_load", "int_load", "ext_frac")
_META_KEYS = ("app", "t_mask", "dest")


def _renormalize_rows(dest):
    """Re-normalize a destination matrix's rows after masking/slicing.

    Rows whose mass was entirely masked away go to all-zero (their sources
    inject nothing in that view, so the row is never consulted).
    """
    dest = jnp.asarray(dest, jnp.float32)
    row = jnp.sum(dest, axis=-1, keepdims=True)
    return jnp.where(row > 0.0, dest / jnp.maximum(row, 1e-12), 0.0)


def validate_trace(trace, who: str = "trace") -> dict:
    """Check that `trace` is a well-formed trace dict; return it.

    Raises TypeError for non-dict inputs and ValueError naming any missing
    keys — the clear-error front door for every transform and engine entry
    point (a malformed trace used to fail deep inside the jit trace).
    """
    if not isinstance(trace, dict):
        raise TypeError(
            f"{who} must be a trace dict with keys {TRACE_KEYS} "
            f"(see repro.core.traffic.generate), got "
            f"{type(trace).__name__}: {trace!r:.80}")
    missing = [k for k in TRACE_KEYS if k not in trace]
    if missing:
        raise ValueError(
            f"{who} is missing {missing}; a trace dict needs {TRACE_KEYS} "
            f"(generate one with repro.core.traffic.generate / "
            f"generate_trace)")
    # Value sanity: NaN or negative injected loads only surface as garbage
    # summaries deep inside the compiled scan — reject them here, pre-jit.
    # Tracers (trace construction inside jit/vmap) have no values to check
    # and skip; concrete arrays (the common host-side path) are cheap to
    # scan once at the boundary.
    for k in TRACE_KEYS:
        v = trace[k]
        if isinstance(v, jax_core.Tracer):
            continue
        arr = np.asarray(v)
        if not np.issubdtype(arr.dtype, np.number):
            raise ValueError(
                f"{who}[{k!r}] must be numeric, got dtype {arr.dtype}")
        if np.isnan(arr).any():
            raise ValueError(
                f"{who}[{k!r}] contains NaN — injected loads must be "
                f"finite (the compiled scan would silently propagate "
                f"NaN into every summary)")
        if (arr < 0).any():
            raise ValueError(
                f"{who}[{k!r}] contains negative values (min "
                f"{float(arr.min()):g}) — loads are non-negative "
                f"flit rates")
    d = trace.get("dest")
    if d is not None and not isinstance(d, jax_core.Tracer):
        arr = np.asarray(d)
        c = int(np.shape(np.asarray(trace["ext_load"]))[-1]) \
            if not isinstance(trace["ext_load"], jax_core.Tracer) else None
        # Stacked batches (stack_traces) carry one leading [K] axis; the
        # trailing two dims must still be square and match the chiplet axis.
        if arr.ndim not in (2, 3) or arr.shape[-2] != arr.shape[-1] \
                or (c is not None and arr.shape[-1] != c):
            raise ValueError(
                f"{who}['dest'] must be a square [C, C] destination matrix "
                f"(optionally with one leading batch axis) matching the "
                f"trace's chiplet axis"
                f"{'' if c is None else f' (C={c})'}, got shape {arr.shape}")
        if not np.isfinite(arr).all() or (arr < 0).any():
            raise ValueError(
                f"{who}['dest'] must be finite and non-negative (a "
                f"row-stochastic destination distribution)")
    return trace


def trace_length(trace: dict) -> int:
    """Valid interval count: sum of `t_mask` if present, else the T axis."""
    validate_trace(trace)
    if "t_mask" in trace:
        return int(np.sum(np.asarray(trace["t_mask"]) > 0))
    return int(jnp.shape(trace["ext_load"])[0])


def slice_trace(trace: dict, n_chiplets: int) -> dict:
    """Restrict a trace to its first `n_chiplets` chiplet columns.

    The per-topology view used by topology sweeps: a trace generated at the
    grid's maximum chiplet count is narrowed per grid point. `mem_load` and
    `ext_frac` are chiplet-count-free and shared across grid points.
    """
    validate_trace(trace)
    c = trace["ext_load"].shape[-1]
    if n_chiplets > c:
        raise ValueError(f"trace has {c} chiplets, needs >= {n_chiplets}")
    out = dict(trace,
               ext_load=trace["ext_load"][..., :n_chiplets],
               int_load=trace["int_load"][..., :n_chiplets])
    if trace.get("dest") is not None:
        out["dest"] = _renormalize_rows(
            trace["dest"][..., :n_chiplets, :n_chiplets])
    return out


def pad_trace(trace: dict, n_intervals: int) -> dict:
    """Zero-pad a trace's time axis to `n_intervals`, adding a `t_mask`.

    Padded tail intervals inject zero traffic and are masked out of every
    engine reduction, so a padded trace simulates identically to the
    original (the ragged-batching invariant, pinned per-arch in tests).
    Already-padded traces extend their existing mask.
    """
    validate_trace(trace)
    t = int(jnp.shape(trace["ext_load"])[0])
    if n_intervals < t:
        raise ValueError(f"cannot pad a {t}-interval trace down to "
                         f"{n_intervals} (use slice on the time axis "
                         f"explicitly instead)")
    mask = jnp.asarray(trace.get("t_mask", jnp.ones((t,), jnp.float32)),
                       jnp.float32)
    pad = n_intervals - t
    if pad == 0:
        return dict(trace, t_mask=mask)

    def _pad_time(a):
        a = jnp.asarray(a)
        return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))

    out = dict(trace)
    for k in ("ext_load", "mem_load", "int_load"):
        out[k] = _pad_time(trace[k])
    out["t_mask"] = _pad_time(mask)
    # Carry any extra per-interval arrays along (leading axis == T).
    for k, v in trace.items():
        if k in TRACE_KEYS or k in _META_KEYS:
            continue
        if hasattr(v, "ndim") and getattr(v, "ndim", 0) >= 1 \
                and jnp.shape(v)[0] == t:
            out[k] = _pad_time(v)
    return out


def chunk_trace(trace: dict, size: int, *, pad: bool = False):
    """Yield consecutive `size`-interval chunks of a trace (last may be
    shorter — pass `pad=True` to zero-pad it to `size` with a `t_mask`,
    so every chunk reuses a streaming session's steady executable).

    Every per-interval key — the core loads, `t_mask`, and any extra array
    whose leading axis is T — is sliced; everything else is carried whole.
    The streaming companion to `SimSession.step_chunk` and the chunk feed
    of the continuous-batching `SessionServer` (fixed-shape lanes).
    """
    validate_trace(trace)
    if size < 1:
        raise ValueError(f"chunk size must be >= 1, got {size}")
    t = int(jnp.shape(trace["ext_load"])[0])
    per_t = [k for k, v in trace.items()
             if k in ("ext_load", "mem_load", "int_load", "t_mask")
             or (hasattr(v, "ndim") and getattr(v, "ndim", 0) >= 1
                 and k not in ("app", "dest") and jnp.shape(v)[0] == t)]
    for s in range(0, t, size):
        chunk = {k: (v[s:s + size] if k in per_t else v)
                 for k, v in trace.items()}
        yield pad_trace(chunk, size) if pad else chunk


def concat_traces(traces: list) -> dict:
    """Stitch traces back-to-back (Fig. 12 application-switch runs).

    `ext_frac` is the load-weighted mean of the segments' fractions (each
    segment weighted by its total ext load — an unweighted mean would let a
    near-idle segment drag the composite fraction). Keys outside the core
    trace schema are carried through: per-interval arrays (leading axis ==
    that segment's T) concatenate, segment-constant values must agree, and
    anything else raises instead of being silently dropped.
    """
    if not traces:
        raise ValueError("concat_traces() needs at least one trace")
    for i, tr in enumerate(traces):
        validate_trace(tr, who=f"traces[{i}]")
    lens = [int(jnp.shape(tr["ext_load"])[0]) for tr in traces]
    out = {k: jnp.concatenate([jnp.asarray(tr[k]) for tr in traces], axis=0)
           for k in ("ext_load", "mem_load", "int_load")}

    # Load-weighted ext_frac: sum_i f_i * L_i / sum_i L_i.
    weights = jnp.stack([jnp.sum(jnp.asarray(tr["ext_load"], jnp.float32))
                         for tr in traces])
    fracs = jnp.stack([jnp.asarray(tr["ext_frac"], jnp.float32)
                       for tr in traces])
    total = jnp.sum(weights)
    out["ext_frac"] = jnp.where(
        total > 0.0, jnp.sum(fracs * weights) / jnp.maximum(total, 1e-12),
        jnp.mean(fracs))
    out["app"] = "+".join(str(tr.get("app", "?")) for tr in traces)

    if any("t_mask" in tr for tr in traces):
        out["t_mask"] = jnp.concatenate(
            [jnp.asarray(tr.get("t_mask", jnp.ones((n,), jnp.float32)),
                         jnp.float32) for tr, n in zip(traces, lens)])

    if any(tr.get("dest") is not None for tr in traces):
        if not all(tr.get("dest") is not None for tr in traces):
            raise ValueError(
                "'dest' present in only some segments — concat_traces "
                "cannot stitch a partial destination matrix (attach one to "
                "every segment via generate(..., dest=True) or drop it)")
        # One composite matrix for the whole run: each segment's destination
        # rows weighted by its total ext load (mirrors the ext_frac mix),
        # then re-normalized to row-stochastic.
        dests = jnp.stack([jnp.asarray(tr["dest"], jnp.float32)
                           for tr in traces])
        w = jnp.where(total > 0.0, weights / jnp.maximum(total, 1e-12),
                      jnp.full_like(weights, 1.0 / len(traces)))
        out["dest"] = _renormalize_rows(
            jnp.sum(dests * w[:, None, None], axis=0))

    known = set(TRACE_KEYS) | set(_META_KEYS)
    extras = sorted(set().union(*(set(tr) for tr in traces)) - known)
    for k in extras:
        holders = [k in tr for tr in traces]
        if not all(holders):
            raise ValueError(
                f"key {k!r} present in only {sum(holders)}/{len(traces)} "
                f"segments — concat_traces cannot stitch a partial key "
                f"(drop it or add it to every segment)")
        vals = [tr[k] for tr in traces]
        if all(hasattr(v, "ndim") and getattr(v, "ndim", 0) >= 1
               and jnp.shape(v)[0] == n for v, n in zip(vals, lens)):
            out[k] = jnp.concatenate([jnp.asarray(v) for v in vals], axis=0)
        elif all(_values_equal(v, vals[0]) for v in vals[1:]):
            out[k] = vals[0]
        else:
            raise ValueError(
                f"key {k!r} differs across segments and is not a "
                f"per-interval array — concat_traces cannot merge it "
                f"(values: {[str(v)[:40] for v in vals]})")
    return out


def _values_equal(a, b) -> bool:
    if hasattr(a, "shape") or hasattr(b, "shape"):
        return np.array_equal(np.asarray(a), np.asarray(b))
    return a == b
