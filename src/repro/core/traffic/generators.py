"""Trace generation: spec + PRNG key -> trace dict, under jit.

A trace is a dict of arrays over reconfiguration intervals:
  ext_load   [T, C] — inter-chiplet packet injection per chiplet (pkts/cycle)
  mem_load   [T]    — traffic to the 2 memory-controller gateways (pkts/cycle)
  int_load   [T, C] — intra-chiplet-only traffic (pkts/cycle per chiplet)
  ext_frac   []     — fraction of packets that cross the interposer
  app        str    — workload label (the spec's `name`)

`generate(spec, key, cfg)` is the single entry point. The spec and cfg are
static jit arguments (both frozen/hashable), the PRNG key is traced: one tiny
compiled generator per (spec, cfg), re-keying is compile-free, and the whole
workload axis stays reproducible by seed. GEM5 full-system traces are
unavailable offline (DESIGN.md §9.1), so the PARSEC path generates per-interval
chiplet traffic calibrated to the paper's own characterization (§4.2, §4.5);
the synthetic paths implement the canonical NoC workloads (specs.py).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import NETWORK, NetworkConfig
from repro.core.traffic.specs import (APP_NAMES, BurstySpec, HotspotSpec,
                                      ParsecSpec, PermutationSpec,
                                      TrafficSpec, UniformSpec, as_spec,
                                      permutation_destinations)


def _lognormal_jitter(key: jax.Array, shape, cv: float) -> jax.Array:
    """Unit-mean lognormal multiplicative jitter with coefficient cv."""
    if cv <= 0.0:
        return jnp.ones(shape, jnp.float32)
    sigma = jnp.sqrt(jnp.log1p(cv ** 2))
    return jnp.exp(jax.random.normal(key, shape) * sigma - 0.5 * sigma ** 2)


def _package(ext: jax.Array, intra: jax.Array, ext_frac: float,
             mem_frac: float) -> dict:
    return {"ext_load": ext,
            "mem_load": mem_frac * jnp.sum(ext, axis=1),
            "int_load": intra,
            "ext_frac": jnp.float32(ext_frac)}


def _gen_parsec(spec: ParsecSpec, key: jax.Array,
                cfg: NetworkConfig) -> dict:
    """The calibrated PARSEC-like generator (op-for-op the pre-package
    `traffic.generate_trace`: same key splits, same math — seeded traces
    are unchanged up to jit fusion rounding, ~1e-7 relative)."""
    prof = spec.profile
    c = cfg.n_chiplets
    k_phase, k_jit, k_chip = jax.random.split(key, 3)

    t = jnp.arange(spec.n_intervals, dtype=jnp.float32)
    # Application phases: raised cosine keeps load non-negative and gives the
    # controller real transitions to track.
    phase = 1.0 + 0.5 * jnp.sin(2.0 * jnp.pi * t / prof.phase_period
                                + jax.random.uniform(k_phase) * 6.28)
    jitter = _lognormal_jitter(k_jit, (spec.n_intervals, c), prof.cv)
    # Mild static per-chiplet imbalance (placement effects).
    chip_w = 1.0 + 0.15 * jax.random.normal(k_chip, (c,))
    chip_w = jnp.clip(chip_w, 0.7, 1.3)

    ext = prof.mean_ext_load * phase[:, None] * jitter * chip_w[None, :]
    intra = ext * (1.0 - prof.ext_frac) / jnp.maximum(prof.ext_frac, 1e-6)
    return _package(ext, intra, prof.ext_frac, prof.mem_frac)


def _gen_uniform(spec: UniformSpec, key: jax.Array,
                 cfg: NetworkConfig) -> dict:
    ext = spec.mean_load * _lognormal_jitter(
        key, (spec.n_intervals, cfg.n_chiplets), spec.cv)
    intra = ext * (1.0 - spec.ext_frac) / spec.ext_frac
    return _package(ext, intra, spec.ext_frac, spec.mem_frac)


def _gen_hotspot(spec: HotspotSpec, key: jax.Array,
                 cfg: NetworkConfig) -> dict:
    c = cfg.n_chiplets
    n_hot = min(spec.n_hotspots, c)
    k_pick, k_jit = jax.random.split(key)
    jitter = _lognormal_jitter(k_jit, (spec.n_intervals, c), spec.cv)
    if n_hot >= c:                      # degenerate: everything is a hotspot
        w = jnp.ones((c,), jnp.float32)
    else:
        # Unit-mean spatial weights: the hotspot set carries hotspot_frac of
        # the total offered load, the rest share the remainder evenly.
        hot = jnp.zeros((c,), jnp.float32).at[
            jax.random.permutation(k_pick, c)[:n_hot]].set(1.0)
        w = (hot * (spec.hotspot_frac * c / n_hot)
             + (1.0 - hot) * ((1.0 - spec.hotspot_frac) * c / (c - n_hot)))
    ext = spec.mean_load * w[None, :] * jitter
    intra = ext * (1.0 - spec.ext_frac) / spec.ext_frac
    return _package(ext, intra, spec.ext_frac, spec.mem_frac)


def _gen_permutation(spec: PermutationSpec, key: jax.Array,
                     cfg: NetworkConfig) -> dict:
    c = cfg.n_chiplets
    dst = permutation_destinations(spec.pattern, c)
    self_paired = jnp.asarray(dst == np.arange(c), jnp.float32)
    jitter = _lognormal_jitter(key, (spec.n_intervals, c), spec.cv)
    offered = (spec.mean_load / spec.ext_frac) * jitter   # total load/chiplet
    # Self-paired chiplets keep their whole load on the local mesh; the rest
    # split ext_frac : 1-ext_frac between interposer and mesh.
    ext = spec.ext_frac * offered * (1.0 - self_paired)[None, :]
    intra = offered - ext
    return _package(ext, intra, spec.ext_frac, spec.mem_frac)


def _gen_bursty(spec: BurstySpec, key: jax.Array,
                cfg: NetworkConfig) -> dict:
    c = cfg.n_chiplets
    k0, k_chain, k_jit = jax.random.split(key, 3)
    duty = spec.duty
    on0 = jax.random.uniform(k0, (c,)) < duty     # stationary initial state
    u = jax.random.uniform(k_chain, (spec.n_intervals, c))

    def chain(on, u_t):
        on_next = jnp.where(on, u_t >= spec.p_off, u_t < spec.p_on)
        return on_next, on_next

    _, on = jax.lax.scan(chain, on0, u)           # [T, C] bool
    on_load = spec.mean_load / duty               # calibrated: E[ext]=mean
    jitter = _lognormal_jitter(k_jit, (spec.n_intervals, c), spec.cv)
    ext = on_load * on.astype(jnp.float32) * jitter
    intra = ext * (1.0 - spec.ext_frac) / spec.ext_frac
    return _package(ext, intra, spec.ext_frac, spec.mem_frac)


_GENERATORS = {ParsecSpec: _gen_parsec, UniformSpec: _gen_uniform,
               HotspotSpec: _gen_hotspot, PermutationSpec: _gen_permutation,
               BurstySpec: _gen_bursty}


def _generate(spec: TrafficSpec, key: jax.Array,
              cfg: NetworkConfig) -> dict:
    gen = _GENERATORS.get(type(spec))
    if gen is None:
        raise TypeError(f"no generator registered for "
                        f"{type(spec).__name__} (known: "
                        f"{sorted(c.__name__ for c in _GENERATORS)})")
    return gen(spec, key, cfg)


@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def _generate_jit(spec: TrafficSpec, key: jax.Array,
                  cfg: NetworkConfig) -> dict:
    return _generate(spec, key, cfg)


def generate(spec, key: jax.Array, cfg: NetworkConfig = NETWORK, *,
             jit: bool = True, dest: bool = False) -> dict:
    """Generate one trace from a spec (or PARSEC app name) and a PRNG key.

    `spec` and `cfg` are static jit arguments — the compiled generator is
    cached per (spec, cfg) and re-keying is compile-free. `jit=False` runs
    the eager path (the property tests pin jit/eager parity).

    `dest=True` attaches the spec's row-stochastic destination matrix
    (`dest` [C, C], see `traffic.dest`) so the simulator resolves actual
    source->destination gateway pressure. Opt-in: traces without `dest`
    ride the uniform-destination path, bit-matching pre-dest numbers. The
    matrix is memoized per (spec, cfg) and attached outside the compiled
    generator, so the jit cache and eager parity are unaffected.
    """
    spec = as_spec(spec)
    arrays = (_generate_jit if jit else _generate)(spec, key, cfg)
    out = dict(arrays, app=spec.name)
    if dest:
        from repro.core.traffic.dest import destination_matrix_jax
        out["dest"] = destination_matrix_jax(spec, cfg)
    return out


def generate_trace(app: str, n_intervals: int, key: jax.Array,
                   cfg: NetworkConfig = NETWORK) -> dict:
    """Generate one PARSEC application trace over `n_intervals` epochs.

    Pre-package API, kept verbatim: sugar for
    ``generate(ParsecSpec(app, n_intervals), key, cfg)``.
    """
    return generate(ParsecSpec(app=app, n_intervals=int(n_intervals)),
                    key, cfg)


def all_app_traces(n_intervals: int, seed: int = 0,
                   cfg: NetworkConfig = NETWORK) -> Dict[str, dict]:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(APP_NAMES))
    return {name: generate_trace(name, n_intervals, k, cfg)
            for name, k in zip(APP_NAMES, keys)}
