"""First-class traffic specifications: the workload axis of the DSE space.

ReSiPI's contribution is *run-time traffic-driven* reconfiguration (§4), so
workload must be a first-class, sweepable axis just like topology (PR 2) and
gateway placement (PR 3). Every spec here is a frozen — hence hashable —
dataclass: it can key an lru_cache, ride `jax.jit` as a static argument, and
zip into the padded sweep grids (`simulator.sweep_workload`).

Two spec families:

  * `ParsecSpec` — the calibrated PARSEC-like application traces the paper
    evaluates (§4.2/§4.5): slow phase oscillation + lognormal jitter, per-app
    parameters from `PARSEC` (blackscholes/facesim/dedup anchors).
  * canonical synthetic NoC workloads (the D3NOC / HexaMesh evaluation set):
    `UniformSpec` (uniform random), `HotspotSpec` (spatially concentrated),
    `PermutationSpec` (transpose / bit-complement / tornado / neighbor), and
    `BurstySpec` (Markov-modulated on/off sources).

All specs carry their own `n_intervals`, so a mixed-length workload set is
normal: the engine pads the time axis to the longest trace with a `t_mask`
(masked tail intervals provably contribute zero to every reduction).

Generation itself lives in `repro.core.traffic.generators`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import numpy as np

from repro.core.constants import NetworkConfig


@dataclasses.dataclass(frozen=True)
class AppProfile:
    name: str
    mean_ext_load: float    # per-chiplet inter-chiplet pkts/cycle
    cv: float               # coefficient of variation across intervals
    phase_period: float     # intervals per application phase
    ext_frac: float         # share of traffic that is inter-chiplet
    mem_frac: float         # share of ext traffic destined to memory


# Anchors per the paper; the other apps interpolated by their known
# communication intensity ordering in PARSEC characterization literature.
PARSEC: Dict[str, AppProfile] = {
    "blackscholes": AppProfile("blackscholes", 0.044, 0.25, 20.0, 0.40, 0.30),
    "swaptions":    AppProfile("swaptions",    0.018, 0.30, 16.0, 0.30, 0.25),
    "streamcluster":AppProfile("streamcluster",0.034, 0.35, 12.0, 0.45, 0.35),
    "facesim":      AppProfile("facesim",      0.006, 0.20, 24.0, 0.25, 0.30),
    "fluidanimate": AppProfile("fluidanimate", 0.028, 0.40, 10.0, 0.35, 0.25),
    "bodytrack":    AppProfile("bodytrack",    0.022, 0.35, 14.0, 0.30, 0.30),
    "canneal":      AppProfile("canneal",      0.038, 0.30, 18.0, 0.50, 0.40),
    "dedup":        AppProfile("dedup",        0.024, 0.45,  8.0, 0.35, 0.30),
}

APP_NAMES = list(PARSEC)

PERMUTATION_PATTERNS = ("transpose", "bit_complement", "tornado", "neighbor")


class TrafficSpec:
    """Marker base class; concrete specs are frozen dataclasses.

    Subclasses must provide `n_intervals: int`, a `name` property (the trace
    label) and pass `_check_common` from their `__post_init__`.
    """

    n_intervals: int

    @property
    def name(self) -> str:  # pragma: no cover - overridden everywhere
        return type(self).__name__

    def _check_common(self) -> None:
        if self.n_intervals < 1:
            raise ValueError(f"{type(self).__name__}: n_intervals must be "
                             f">= 1, got {self.n_intervals}")
        # (field, lower bound, bound is strict, upper bound)
        for field, lo, strict, hi in (("mean_load", 0.0, True, None),
                                      ("cv", 0.0, False, None),
                                      ("ext_frac", 0.0, True, 1.0),
                                      ("mem_frac", 0.0, False, 1.0)):
            if not hasattr(self, field):
                continue
            v = getattr(self, field)
            bad = v is None or v != v or (v <= lo if strict else v < lo)
            if bad:
                raise ValueError(f"{type(self).__name__}.{field} must be "
                                 f"{'>' if strict else '>='} {lo}, got {v}")
            if hi is not None and v > hi:
                raise ValueError(f"{type(self).__name__}.{field} must be "
                                 f"<= {hi}, got {v}")


@dataclasses.dataclass(frozen=True)
class ParsecSpec(TrafficSpec):
    """A calibrated PARSEC-like application trace (the paper's workloads)."""

    app: str = "dedup"
    n_intervals: int = 64

    def __post_init__(self):
        if self.app not in PARSEC:
            raise ValueError(f"unknown PARSEC app {self.app!r} "
                             f"(known: {APP_NAMES})")
        self._check_common()

    @property
    def profile(self) -> AppProfile:
        return PARSEC[self.app]

    @property
    def name(self) -> str:
        return self.app


@dataclasses.dataclass(frozen=True)
class UniformSpec(TrafficSpec):
    """Uniform-random traffic: every chiplet offers the same mean ext load,
    with lognormal per-interval jitter (stationary — no application phases)."""

    mean_load: float = 0.02
    cv: float = 0.3
    ext_frac: float = 0.4
    mem_frac: float = 0.3
    n_intervals: int = 64

    def __post_init__(self):
        self._check_common()

    @property
    def name(self) -> str:
        return "uniform"


@dataclasses.dataclass(frozen=True)
class HotspotSpec(TrafficSpec):
    """Hotspot traffic: `n_hotspots` randomly drawn chiplets concentrate
    `hotspot_frac` of the total offered ext load (HexaMesh-style stressor
    for the gateway controller's per-chiplet activation)."""

    mean_load: float = 0.02
    hotspot_frac: float = 0.6    # share of total load on the hotspot set
    n_hotspots: int = 1
    cv: float = 0.3
    ext_frac: float = 0.5
    mem_frac: float = 0.3
    n_intervals: int = 64

    def __post_init__(self):
        self._check_common()
        if self.n_hotspots < 1:
            raise ValueError(f"HotspotSpec.n_hotspots must be >= 1, "
                             f"got {self.n_hotspots}")
        if not 0.0 < self.hotspot_frac < 1.0:
            raise ValueError(f"HotspotSpec.hotspot_frac must be in (0, 1), "
                             f"got {self.hotspot_frac}")

    @property
    def name(self) -> str:
        return f"hotspot{self.n_hotspots}"


@dataclasses.dataclass(frozen=True)
class PermutationSpec(TrafficSpec):
    """Deterministic permutation traffic at chiplet granularity.

    Each chiplet sends to a fixed partner chiplet:

      * ``transpose``      — (i, j) -> (j, i) on the near-square chiplet
        grid; diagonal chiplets are self-paired, so their would-be inter-
        chiplet load stays *intra*-chiplet (zero ext injection there).
      * ``bit_complement`` — i -> C-1-i (index complement; self-paired
        middle chiplet when C is odd).
      * ``tornado``        — i -> (i + C//2) mod C.
      * ``neighbor``       — i -> (i + 1) mod C.

    At the epoch level the simulator consumes per-chiplet *injected* loads,
    so the pattern manifests through which chiplets inject inter-chiplet
    traffic at all (self-pairs divert to `int_load`); spatial injection is
    otherwise uniform, as in the canonical synthetic definitions.
    """

    pattern: str = "transpose"
    mean_load: float = 0.02
    cv: float = 0.25
    ext_frac: float = 0.5
    mem_frac: float = 0.25
    n_intervals: int = 64

    def __post_init__(self):
        if self.pattern not in PERMUTATION_PATTERNS:
            raise ValueError(f"unknown permutation pattern "
                             f"{self.pattern!r} (known: "
                             f"{PERMUTATION_PATTERNS})")
        self._check_common()

    @property
    def name(self) -> str:
        return self.pattern


@dataclasses.dataclass(frozen=True)
class BurstySpec(TrafficSpec):
    """Markov-modulated on/off sources (bursty traffic, D3NOC-style).

    Every chiplet runs an independent two-state Markov chain over intervals:
    OFF -> ON with probability `p_on`, ON -> OFF with `p_off`. ON-state load
    is calibrated to `mean_load / duty` (duty = p_on / (p_on + p_off)), so
    the long-run mean ext load equals `mean_load` regardless of burstiness.
    """

    mean_load: float = 0.02
    p_on: float = 0.2
    p_off: float = 0.3
    cv: float = 0.2
    ext_frac: float = 0.45
    mem_frac: float = 0.3
    n_intervals: int = 64

    def __post_init__(self):
        self._check_common()
        for f in ("p_on", "p_off"):
            v = getattr(self, f)
            if not 0.0 < v <= 1.0:
                raise ValueError(f"BurstySpec.{f} must be in (0, 1], got {v}")

    @property
    def duty(self) -> float:
        return self.p_on / (self.p_on + self.p_off)

    @property
    def name(self) -> str:
        return "bursty"


SpecLike = Union[TrafficSpec, str]


def as_spec(spec: SpecLike, n_intervals: int = 64) -> TrafficSpec:
    """Coerce a spec-like value: a `TrafficSpec` passes through, a string is
    a PARSEC app name (`ParsecSpec(app, n_intervals)`)."""
    if isinstance(spec, TrafficSpec):
        return spec
    if isinstance(spec, str):
        return ParsecSpec(app=spec, n_intervals=n_intervals)
    raise TypeError(f"expected a TrafficSpec or PARSEC app name, got "
                    f"{type(spec).__name__}: {spec!r}")


def expected_mean_ext_load(spec: TrafficSpec,
                           cfg: NetworkConfig) -> float:
    """Analytic mean of `ext_load` for a spec (the calibration target).

    Used by the property tests: every generator's sample mean must land
    within sampling tolerance of this value.
    """
    if isinstance(spec, ParsecSpec):
        return spec.profile.mean_ext_load
    if isinstance(spec, PermutationSpec):
        n_self = int((permutation_destinations(spec.pattern, cfg.n_chiplets)
                      == np.arange(cfg.n_chiplets)).sum())
        return spec.mean_load * (cfg.n_chiplets - n_self) / cfg.n_chiplets
    return spec.mean_load


def permutation_destinations(pattern: str, n_chiplets: int) -> np.ndarray:
    """Destination chiplet index per source chiplet for a pattern ([C])."""
    c = n_chiplets
    i = np.arange(c)
    if pattern == "tornado":
        return (i + c // 2) % c
    if pattern == "neighbor":
        return (i + 1) % c
    if pattern == "bit_complement":
        return c - 1 - i
    if pattern == "transpose":
        side = int(round(c ** 0.5))
        if side * side == c:
            r, q = i // side, i % side
            return q * side + r
        # Non-square chiplet counts: index reversal is the closest analogue
        # (same self-pair structure as bit_complement).
        return c - 1 - i
    raise ValueError(f"unknown permutation pattern {pattern!r} "
                     f"(known: {PERMUTATION_PATTERNS})")


ALL_SYNTHETIC_SPECS: Tuple[TrafficSpec, ...] = (
    UniformSpec(),
    HotspotSpec(),
    PermutationSpec(pattern="transpose"),
    PermutationSpec(pattern="bit_complement"),
    PermutationSpec(pattern="tornado"),
    PermutationSpec(pattern="neighbor"),
    BurstySpec(),
)
