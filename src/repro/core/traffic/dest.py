"""Spec-conditioned destination matrices: who sends to whom.

The epoch model consumed only per-chiplet *injected* load through PR 7, so
permutation workloads (transpose / tornado / bit-complement) were scenario-
diverse in load but invisible to routing — exactly the congestion structure
ReSiPI's traffic-driven gateway deployment is supposed to exploit. This
module builds the row-stochastic destination distribution ``dest`` [C, C]
for every spec family:

  * `UniformSpec` / `BurstySpec` — uniform over the C-1 other chiplets
    (the canonical uniform-random destination model).
  * `HotspotSpec` — uniform as well: the hotspot *set* is drawn from the
    PRNG key at generation time, so a spec-keyed (deterministic) matrix
    cannot name it; spatial concentration still enters through the load
    columns.
  * `PermutationSpec` — one-hot rows onto the fixed partner chiplet.
    Self-paired chiplets (transpose diagonal, bit-complement middle) keep
    their one-hot on the *diagonal*: the generator diverts their ext load
    to `int_load`, so the diagonal rows mark exactly the chiplets whose
    ext column is zero — the divert-parity invariant the property tests
    pin (`dest` diagonal == generator self-pair mask).
  * `ParsecSpec` — calibrated spread: ring-distance exponential decay with
    a per-app locality scale derived from the profile's `ext_frac` (more
    interposer-bound apps spread further), zero diagonal, row-normalized.

Matrices are memoized per ``(spec, cfg)`` exactly like the selection
tables — both spec and cfg are frozen/hashable — so repeated `generate`
calls and `sweep_workload` re-keys never rebuild them, and
`simulator.clear_engine_caches()` clears these caches too.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.constants import NETWORK, NetworkConfig
from repro.core.traffic.specs import (ParsecSpec, PermutationSpec,
                                      TrafficSpec, as_spec,
                                      permutation_destinations)


def _uniform_offdiag(c: int) -> np.ndarray:
    if c <= 1:
        return np.ones((c, c), np.float32)
    d = np.full((c, c), 1.0 / (c - 1), np.float32)
    np.fill_diagonal(d, 0.0)
    return d


def _permutation_dest(spec: PermutationSpec, c: int) -> np.ndarray:
    dst = permutation_destinations(spec.pattern, c)
    d = np.zeros((c, c), np.float32)
    d[np.arange(c), dst] = 1.0
    return d


def _parsec_dest(spec: ParsecSpec, c: int) -> np.ndarray:
    if c <= 1:
        return np.ones((c, c), np.float32)
    # Ring distance on the chiplet index: adjacent chiplets are cheap to
    # reach, so low-ext_frac (locality-heavy) apps concentrate there while
    # interposer-bound apps spread nearly uniformly.
    i = np.arange(c)
    hops = np.abs(i[:, None] - i[None, :])
    hops = np.minimum(hops, c - hops)
    tau = 1.0 + 4.0 * spec.profile.ext_frac
    d = np.exp(-hops / tau).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    return d / d.sum(axis=1, keepdims=True)


@functools.lru_cache(maxsize=None)
def _destination_matrix(spec: TrafficSpec, cfg: NetworkConfig) -> np.ndarray:
    c = cfg.n_chiplets
    if isinstance(spec, PermutationSpec):
        d = _permutation_dest(spec, c)
    elif isinstance(spec, ParsecSpec):
        d = _parsec_dest(spec, c)
    else:                       # Uniform / Hotspot / Bursty (see module doc)
        d = _uniform_offdiag(c)
    d.setflags(write=False)
    return d


def destination_matrix(spec, cfg: NetworkConfig = NETWORK) -> np.ndarray:
    """Row-stochastic destination distribution for a spec ([C, C], numpy).

    ``dest[i, j]`` is the fraction of chiplet i's inter-chiplet packets
    destined to chiplet j. Memoized per (spec, cfg); the returned array is
    read-only (shared across callers).
    """
    return _destination_matrix(as_spec(spec), cfg)


destination_matrix.cache_info = _destination_matrix.cache_info
destination_matrix.cache_clear = _destination_matrix.cache_clear
destination_matrix.__wrapped__ = _destination_matrix


@functools.lru_cache(maxsize=None)
def _destination_matrix_jax(spec: TrafficSpec, cfg: NetworkConfig):
    return jnp.asarray(_destination_matrix(spec, cfg))


def destination_matrix_jax(spec, cfg: NetworkConfig = NETWORK):
    """Device-resident view of `destination_matrix` (memoized separately so
    the device array is placed once per (spec, cfg), not per trace)."""
    return _destination_matrix_jax(as_spec(spec), cfg)


destination_matrix_jax.cache_info = _destination_matrix_jax.cache_info
destination_matrix_jax.cache_clear = _destination_matrix_jax.cache_clear
destination_matrix_jax.__wrapped__ = _destination_matrix_jax


def clear_destination_caches() -> None:
    """Drop both memoized views (wired into `clear_engine_caches`)."""
    _destination_matrix_jax.cache_clear()
    _destination_matrix.cache_clear()
