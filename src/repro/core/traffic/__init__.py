"""Workload-polymorphic traffic subsystem.

Traffic is a first-class DSE axis, like topology (PR 2) and gateway
placement (PR 3):

  * `specs` — frozen/hashable `TrafficSpec` hierarchy: calibrated
    PARSEC-like application profiles (`ParsecSpec` / `PARSEC`) plus the
    canonical synthetic NoC workloads (`UniformSpec`, `HotspotSpec`,
    `PermutationSpec` — transpose / bit-complement / tornado / neighbor —
    and `BurstySpec`, Markov-modulated on/off).
  * `generators` — `generate(spec, key, cfg)`: spec + PRNG key -> trace
    dict, under jit (spec/cfg static, key traced), so workload sweeps are
    seeded, reproducible and compile-free after the first key.
  * `transform` — `validate_trace` / `slice_trace` / `concat_traces` /
    `pad_trace` / `trace_length`: the ragged-T padding contract (`t_mask`)
    that lets mixed-length traces share one compiled executable
    (`simulator.sweep_workload`, `stack_traces(..., pad=True)`).

The flat pre-package API (`traffic.generate_trace`, `traffic.PARSEC`,
`traffic.slice_trace`, ...) is re-exported unchanged.
"""
from repro.core.traffic.specs import (ALL_SYNTHETIC_SPECS, APP_NAMES,
                                      AppProfile, BurstySpec, HotspotSpec,
                                      PARSEC, PERMUTATION_PATTERNS,
                                      ParsecSpec, PermutationSpec,
                                      TrafficSpec, UniformSpec, as_spec,
                                      expected_mean_ext_load,
                                      permutation_destinations)
from repro.core.traffic.dest import (clear_destination_caches,
                                     destination_matrix,
                                     destination_matrix_jax)
from repro.core.traffic.generators import (all_app_traces, generate,
                                           generate_trace)
from repro.core.traffic.transform import (TRACE_KEYS, chunk_trace,
                                          concat_traces, pad_trace,
                                          slice_trace, trace_length,
                                          validate_trace)

__all__ = [
    "ALL_SYNTHETIC_SPECS", "APP_NAMES", "AppProfile", "BurstySpec",
    "HotspotSpec", "PARSEC", "PERMUTATION_PATTERNS", "ParsecSpec",
    "PermutationSpec", "TRACE_KEYS", "TrafficSpec", "UniformSpec",
    "all_app_traces", "as_spec", "chunk_trace", "clear_destination_caches",
    "concat_traces", "destination_matrix", "destination_matrix_jax",
    "expected_mean_ext_load", "generate", "generate_trace", "pad_trace",
    "permutation_destinations", "slice_trace", "trace_length",
    "validate_trace",
]
