"""ReSiPI dynamic gateway management (§3.3, Fig. 6-7).

The epoch controller measures the mean per-gateway load of each chiplet over a
reconfiguration interval (Eq. 5) and applies hysteresis thresholds:

    activate   when L_c >  T_P_g = L_m                 (Eq. 6)
    deactivate when L_c <  T_N_g = L_m * (1 - 1/g)     (Eq. 7, from Eqs. 8-10)

This module is pure JAX so the exact same control law drives both the Level-1
network simulator (gateways on a photonic interposer) and the Level-2 training
runtime (communication lanes on a TPU mesh) — see reconfig_runtime.py.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import NETWORK, PAPER_L_M, NetworkConfig


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    l_m: float = PAPER_L_M        # maximum allowable per-gateway load (§4.2)
    max_gateways: int = 4         # G: per-chiplet maximum
    min_gateways: int = 1


def activation_order(positions, cfg: NetworkConfig = NETWORK) -> np.ndarray:
    """Controller activation order for an arbitrary gateway placement.

    The controller raises g one gateway at a time (Fig. 6), and the gateway
    that lights up at level k+1 is row k of the placement array — so the row
    *order* decides selection quality at every partial activation level. The
    default edge scheme hand-orders its 4 slots so consecutive levels stay
    maximally spread (Fig. 8 a-d); this generalizes that rule to arbitrary
    placements:

      * level 1 gets the position with the fewest mean hops to the mesh
        routers (closest to the mesh center — the best solo gateway),
      * each further level greedily maximizes its minimum Manhattan distance
        to the already-activated set (ties broken by mean-hop quality, then
        by original row index, so the order is deterministic).

    Returns a permutation of row indices (design-time numpy; applied by
    `selection.normalize_placement(..., order="spread")` and the placement
    search's candidate proposals).
    """
    from repro.core import topology

    pos = np.asarray(positions, np.int64).reshape(-1, 2)
    n = len(pos)
    if cfg.coords is None:
        # Derived mesh: geometric-center centrality + Manhattan spread (the
        # pre-coords rule, bit parity).
        center = np.array([(cfg.mesh_x - 1) / 2.0, (cfg.mesh_y - 1) / 2.0])
        centrality = np.abs(pos - center).sum(axis=1)
        pair = np.abs(pos[:, None, :] - pos[None, :, :]).sum(axis=-1)
    else:
        # Explicit layout: medoid centrality (total hops to every router)
        # and BFS hop distances — no geometric center exists.
        centrality = topology.centrality_lut(cfg)[pos[:, 0], pos[:, 1]]
        pair = topology.pair_hops(cfg, pos[:, None, :], pos[None, :, :])
    order = [int(np.lexsort((np.arange(n), centrality))[0])]
    remaining = [i for i in range(n) if i != order[0]]
    while remaining:
        dmin = [min(pair[i, j] for j in order) for i in remaining]
        best = np.lexsort((remaining, [centrality[i] for i in remaining],
                           [-d for d in dmin]))[0]
        order.append(remaining.pop(int(best)))
    return np.asarray(order, np.int64)


def activation_order_jnp(positions, cfg: NetworkConfig = NETWORK
                         ) -> jax.Array:
    """Traceable twin of `activation_order` (exact tie-break parity).

    Same greedy spread rule — most-central position first, then each level
    maximizes its minimum Manhattan distance to the already-activated set,
    ties broken by centrality then original row index — but expressed as an
    argmin over integer composite keys so it runs under jit/vmap on *traced*
    placements. This is what lets the device-resident placement search
    (repro.core.search) spread-order every proposal without a host
    round-trip. Matches the numpy `activation_order` exactly for any
    placement (integer comparisons only; pinned in tests/test_search.py).
    """
    from repro.core import topology

    pos = jnp.asarray(positions, jnp.int32).reshape(-1, 2)
    n = int(pos.shape[0])
    idx = jnp.arange(n, dtype=jnp.int32)
    if cfg.coords is None:
        # 2x the numpy rule's float centrality — integer, identical order.
        cent2 = (jnp.abs(2 * pos[:, 0] - (cfg.mesh_x - 1))
                 + jnp.abs(2 * pos[:, 1] - (cfg.mesh_y - 1)))
        pair = jnp.sum(jnp.abs(pos[:, None, :] - pos[None, :, :]), axis=-1)
        big = jnp.int32(4 * (cfg.mesh_x + cfg.mesh_y))
    else:
        # Explicit layout: the numpy branch's integer medoid centrality and
        # BFS pair hops become LUT gathers on the traced coordinates.
        cent2 = jnp.asarray(topology.centrality_lut(cfg))[pos[:, 0],
                                                          pos[:, 1]]
        rid = jnp.asarray(topology.router_index_lut(cfg))[pos[:, 0],
                                                          pos[:, 1]]
        pair = jnp.asarray(topology.hop_lut(cfg))[rid[:, None],
                                                  pos[None, :, 0],
                                                  pos[None, :, 1]]
        big = jnp.int32(topology.max_hops(cfg) + 1)
    # Composite lexicographic keys: b bounds the row-index tie-break, a
    # bounds (centrality, index). All terms stay far inside int32 for any
    # realistic mesh (dmin <= mesh perimeter).
    b = n
    a = topology.centrality_bound(cfg) * b
    taken = jnp.iinfo(jnp.int32).max

    first = jnp.argmin(cent2 * b + idx).astype(jnp.int32)
    order = jnp.zeros((n,), jnp.int32).at[0].set(first)
    selected = idx == first
    for k in range(1, n):
        dmin = jnp.min(jnp.where(selected[None, :], pair, big), axis=1)
        key = jnp.where(selected, taken, -dmin * a + cent2 * b + idx)
        nxt = jnp.argmin(key).astype(jnp.int32)
        order = order.at[k].set(nxt)
        selected = selected | (idx == nxt)
    return order


def t_p(cfg: ControllerConfig) -> jax.Array:
    """Eq. 6: activation threshold — constant L_m for every g."""
    return jnp.float32(cfg.l_m)


def t_n(g: jax.Array, cfg: ControllerConfig) -> jax.Array:
    """Eq. 7: deactivation threshold L_m * (1 - 1/g)."""
    g = jnp.maximum(g.astype(jnp.float32), 1.0)
    return cfg.l_m * (1.0 - 1.0 / g)


def average_gateway_load(packets: jax.Array, interval_cycles: jax.Array,
                         g: jax.Array) -> jax.Array:
    """Eq. 5: L_c^i = (1/g_c) * sum_j P_j / T_i.

    Args:
      packets: total packets transmitted by the chiplet's active gateways
        during the interval (scalar or [chiplets]).
      interval_cycles: T_i, interval duration in cycles.
      g: number of active gateways.
    """
    g = jnp.maximum(g.astype(jnp.float32), 1.0)
    return packets / (interval_cycles * g)


def update_gateways(g: jax.Array, load: jax.Array,
                    cfg: ControllerConfig) -> jax.Array:
    """One controller decision (Fig. 6): g -> g+1, g-1 or g.

    Vectorizes over chiplets. Hysteresis: since T_N_g < T_P for all g, the
    bands overlap nowhere and the controller cannot oscillate within one
    interval (property-tested).
    """
    g = g.astype(jnp.int32)
    inc = (load > t_p(cfg)) & (g < cfg.max_gateways)
    dec = (load < t_n(g, cfg)) & (g > cfg.min_gateways)
    return jnp.where(inc, g + 1, jnp.where(dec, g - 1, g))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ControllerState:
    """Carried across reconfiguration intervals (one per chiplet)."""
    g: jax.Array                  # [chiplets] int32 — active gateways
    packets_seen: jax.Array       # [chiplets] float32 — accumulator
    epoch: jax.Array              # scalar int32

    @staticmethod
    def init(n_chiplets: int, cfg: ControllerConfig) -> "ControllerState":
        # §3.3: "initially set to the maximum allowed".
        return ControllerState(
            g=jnp.full((n_chiplets,), cfg.max_gateways, jnp.int32),
            packets_seen=jnp.zeros((n_chiplets,), jnp.float32),
            epoch=jnp.int32(0))


def epoch_step(state: ControllerState, packets_this_interval: jax.Array,
               interval_cycles: float, cfg: ControllerConfig
               ) -> Tuple[ControllerState, dict]:
    """Run one reconfiguration-interval update (Fig. 7 flow).

    Returns the new state plus a record dict: per-chiplet g before/after, the
    measured loads, and the global gateway total GT used by Eq. 4 / the laser
    power manager. Gateway deactivation is modeled flush-then-deactivate
    (§3.3): the interval that *decides* to drop a gateway still pays its
    power; activation raises laser power first, so the new gateway is usable
    within the same interval boundary (100-cycle PCM + <1-cycle SOA delays,
    §4.3 — negligible vs the 1M-cycle interval, charged as energy).
    """
    load = average_gateway_load(packets_this_interval,
                                jnp.float32(interval_cycles), state.g)
    g_new = update_gateways(state.g, load, cfg)
    record = {
        "g_before": state.g,
        "g_after": g_new,
        "load": load,
        "gt": jnp.sum(g_new),
        "changed": jnp.sum(jnp.abs(g_new - state.g)),
    }
    new_state = ControllerState(g=g_new,
                                packets_seen=jnp.zeros_like(state.packets_seen),
                                epoch=state.epoch + 1)
    return new_state, record


def scan_controller(loads_per_interval: jax.Array, cfg: ControllerConfig,
                    interval_cycles: float) -> dict:
    """Replay the controller over a [T, chiplets] load trace with lax.scan.

    `loads_per_interval` is the would-be load *per single gateway* if exactly
    one gateway were active (i.e. total packets / interval); Eq. 5 rescales by
    the live g each epoch. Used for unit tests and the adaptivity benchmark.
    """
    n_chiplets = loads_per_interval.shape[1]
    state0 = ControllerState.init(n_chiplets, cfg)

    def step(state, total_load):
        packets = total_load * interval_cycles
        new_state, rec = epoch_step(state, packets, interval_cycles, cfg)
        return new_state, rec

    _, recs = jax.lax.scan(step, state0, loads_per_interval)
    return recs
