"""Epoch-level 2.5D network simulator (Level 1, DESIGN.md §3).

Simulates the four compared interposer architectures (§4.1) over a traffic
trace, one `lax.scan` step per reconfiguration interval:

  * RESIPI      — dynamic gateways (Eqs. 5-7), 4 wavelengths, PCM gating
  * RESIPI_ALL  — ReSiPI datapath with all gateways always active (Fig. 11)
  * PROWAVES    — 1 gateway/chiplet, dynamic wavelength count [16]
  * AWGR        — 4 gateways/chiplet static, 1 wavelength/port, 1.8 dB loss [8]

Each step: traffic -> per-gateway load (selection tables) -> latency
(noc.NocModel) -> power (photonics.interposer_power_mw) -> controller update.
Energy is reported as power x mean-packet-latency (per-packet service-energy
proxy; see EXPERIMENTS.md §Fig11 note) — consistent with the paper where the
-53% energy claim is the product of the -37% latency and -25% power claims.

Engine model (compile-once, batch-everywhere):

  * `SimConfig` (and the nested `NetworkConfig` / `ControllerConfig` /
    `NocModel`) are frozen dataclasses, hence hashable, and are passed to
    `jax.jit` as *static* arguments: equal configs hit the compile cache,
    distinct configs get their own executable.
  * `simulate`       — single trace, jit-cached on (trace shape, config).
  * `simulate_batch` — N stacked traces, one vmapped scan per config.
  * `sweep`          — vmap over *runtime* scalar overrides (`l_m`,
    `buffer_sat`, `wavelengths`, `prowaves_rho_hi/lo`) so a DSE over K
    parameter values is one compilation, not K.
  * `sweep_topology` / `sweep_topology_batch` — vmap over *shape-changing*
    topology axes (`n_chiplets`, `gateways_per_chiplet`, `mesh_radix`) via
    pad-to-max batching with validity masks: a hundreds-of-chiplets scan
    is ONE compiled executable, and padded slots provably contribute zero
    load/latency/power (see ROADMAP.md "Topology-sweep API").
  * `shard_sweep`    — the same padded grid with its topology axis sharded
    across devices (NamedSharding/GSPMD), single-device fallback.
  * `sweep_placement` / `sweep_placement_batch` — vmap K candidate gateway
    *placements* (NetworkConfig.gateway_positions) through the same ONE
    compiled masked scan; placements enter purely as traced hop/loss
    tables, so a placement DSE never recompiles per candidate.
  * `search_placement` — PlaceIT-style greedy/annealed placement search.
    The default engine is DEVICE-RESIDENT (repro.core.search): proposals,
    traceable placement tables, scoring, annealed acceptance and history
    run inside ONE compiled `lax.scan` — a whole search is a single
    dispatch. `engine="host"` keeps the PR-3 numpy-proposal loop (one
    `sweep_placement` call per generation) as the parity oracle.
  * `search_placement_islands` — K independent annealed chains vmapped
    over seeds in the same single executable; runtime `SWEEPABLE_FIELDS`
    grids of length K zip with the island axis (joint placement x
    runtime-knob search), sharded across devices when available.
  * `sweep_workload` — K `traffic.TrafficSpec` workloads (mixed lengths
    allowed) generated from seeds and run as ONE compiled executable;
    runtime/topology/placement grids of the same length zip in.
  * **Ragged time axis** — every batched entry point accepts mixed-length
    traces: `stack_traces(..., pad=True)` pads to the longest T with a
    `t_mask`, and masked tail intervals provably contribute zero to every
    latency/power/energy reduction (padded lane == unpadded `simulate`,
    pinned per-arch in tests — the time-axis analogue of the PR 2
    chiplet-masking invariant).
  * `SimSession.init(sim)` / `session.step_chunk(chunk)` — streaming
    simulation with a donated carry: controller/PROWAVES state persists
    across chunks, so an unbounded online trace runs at fixed memory and
    a chunked run bit-matches the one-shot `simulate` records.
  * `engine_stats()` — trace/compile counters used by tests and benches.

`simulate_eager` preserves the pre-engine per-call retrace path for
benchmark baselines (benchmarks/bench_engine.py).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import photonics, topology, traffic
from repro.core.faults import FAULT_KEYS, stack_fault_frames
from repro.core.constants import (NETWORK, PROWAVES_MAX_WAVELENGTHS,
                                  PROWAVES_MIN_WAVELENGTHS,
                                  RESIPI_WAVELENGTHS, NetworkConfig,
                                  PHOTONIC_POWER)
from repro.core.gateway_controller import (ControllerConfig, ControllerState,
                                           epoch_step)
from repro.core.noc import NocModel, uniform_mesh_mean_hops
from repro.core.selection import (N_DEFAULT_EDGE_SLOTS,
                                  build_selection_tables, mean_access_hops,
                                  normalize_placement,
                                  padded_selection_tables_jax,
                                  resolve_gateway_positions,
                                  selection_tables_jax)


class Arch(enum.Enum):
    RESIPI = "resipi"
    RESIPI_ALL = "resipi_all"
    PROWAVES = "prowaves"
    AWGR = "awgr"


@dataclasses.dataclass(frozen=True)
class SimConfig:
    arch: Arch = Arch.RESIPI
    cfg: NetworkConfig = NETWORK
    ctl: ControllerConfig = ControllerConfig()
    noc: NocModel = NocModel()
    wavelengths: int = RESIPI_WAVELENGTHS
    # PROWAVES wavelength controller: multiplicative increase/decrease with
    # utilization hysteresis (reactive approximation of [16]'s epoch policy).
    prowaves_rho_hi: float = 0.5
    prowaves_rho_lo: float = 0.30
    # Run the interval-scan body as the fused `kernels.epoch_step` Pallas
    # kernel (interpret on CPU, compiled on TPU) instead of the XLA lax.scan
    # body. Applies to the RESIPI/RESIPI_ALL unpadded-topology paths; other
    # configurations fall back to the scan body, which doubles as the
    # kernel's 1e-6 parity oracle (kernels/epoch_step/ref.py).
    epoch_kernel: bool = False

    def with_arch(self, arch: Arch) -> "SimConfig":
        w = {Arch.RESIPI: RESIPI_WAVELENGTHS,
             Arch.RESIPI_ALL: RESIPI_WAVELENGTHS,
             Arch.PROWAVES: PROWAVES_MAX_WAVELENGTHS,
             Arch.AWGR: 1}[arch]
        # PROWAVES ships 32-flit gateway buffers (4x ReSiPI, Table 1): deeper
        # buffers push the backpressure knee out.
        noc = dataclasses.replace(self.noc,
                                  buffer_sat=0.65 if arch == Arch.PROWAVES
                                  else self.noc.buffer_sat)
        return dataclasses.replace(self, arch=arch, wavelengths=w, noc=noc)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    ctl: ControllerState          # gateway controller (ReSiPI)
    wavelengths: jax.Array        # [C] PROWAVES per-chiplet active lambdas
    prev_active: jax.Array        # [N_total] previous gateway activity


def _activity_mask(g: jax.Array, sim: SimConfig) -> jax.Array:
    """Expand per-chiplet g into the global gateway-chain activity mask.

    Chain layout: C chiplets x G gateway slots (activation order), then the
    2 memory-controller gateways, which are always active (Table 1).
    """
    gmax = sim.cfg.max_gateways_per_chiplet
    slots = jnp.arange(gmax)[None, :] < g[:, None]          # [C, G]
    mem = jnp.ones((sim.cfg.memory_gateways,), bool)
    return jnp.concatenate([slots.reshape(-1), mem])


def _interval_metrics(g: jax.Array, wavelengths: jax.Array,
                      ext_load: jax.Array, mem_load: jax.Array,
                      int_load: jax.Array, ext_frac: jax.Array,
                      sim: SimConfig, tables: dict,
                      topo: Optional[dict] = None,
                      t_valid: jax.Array | float = 1.0,
                      extra_db: Optional[jax.Array] = None,
                      dest: Optional[jax.Array] = None) -> dict:
    """Latency/load metrics for one interval given activity (g, lambda).

    With `topo` (the padded topology-sweep path) the chiplet axis is padded
    to the grid maximum: every reduction is mask-weighted so padded chiplet
    lanes contribute exactly zero load/latency, and the per-topology hop
    tables/mesh scalars come from `topo` instead of the static config.

    `t_valid` is the interval's time-validity bit (ragged-T padding): a
    masked interval carries zero injected load already, but zero-load
    latency is NOT zero (the memory term alone yields a finite quotient),
    so every returned metric is multiplied by `t_valid` — a padded tail
    interval contributes exactly zero to every downstream reduction.

    `extra_db` (fault path) is the interval's optical loss-drift term,
    added to the placement's access loss so the laser power manager
    compensates for device aging; None (and the 0.0 a never-firing fault
    frame compiles to) leaves the fault-free math bit-identical.

    `dest` (destination-aware path) is the trace's row-stochastic [C, C]
    destination matrix: the destination leg of each inter-chiplet packet is
    then priced at the *actual* destination's gateway pressure (received
    load over its active gateways, with a fan-in concentration factor on
    the ejection queueing) instead of the uniform-destination mean-hop
    approximation. `dest=None` keeps the pre-dest math verbatim —
    bit-identical numbers for every existing trace.
    """
    noc = sim.noc
    # Per-gateway load after the Fig. 8 balanced selection. ext traffic of a
    # chiplet spreads over its g active gateways; memory traffic over the 2
    # memory gateways.
    gw_load = ext_load / jnp.maximum(g.astype(jnp.float32), 1.0)       # [C]
    mem_gw_load = mem_load / sim.cfg.memory_gateways

    if topo is None:
        chip_mask = None
        src_hops = mean_access_hops(tables, g)                         # [C]
        mean_src_hops = jnp.mean(src_hops)
        # Placement-derived optical access loss at each chiplet's current
        # activation level (0 dB for the default edge scheme).
        access_db = jnp.mean(tables["gw_loss_db"][jnp.maximum(g, 1) - 1])
        lam = wavelengths
        lam_mem = wavelengths if wavelengths.ndim == 0 \
            else jnp.mean(wavelengths)
        mesh_hops = jnp.float32(uniform_mesh_mean_hops(sim.cfg))
        # Rows feeding the gateway cut: mesh_x on a derived mesh (the
        # pre-coords constant, bit parity), sqrt(R) on explicit layouts.
        mesh_feed = 2.0 * topology.feed_width(sim.cfg)
    else:
        chip_mask = topo["chip_mask"]                                  # [C]
        src_hops = topo["src_hops"][jnp.maximum(g, 1) - 1]             # [C]
        nreal = jnp.maximum(jnp.sum(chip_mask), 1.0)
        mean_src_hops = jnp.sum(src_hops * chip_mask) / nreal
        gdb = topo["gw_loss_db"][jnp.maximum(g, 1) - 1]                # [C]
        access_db = jnp.sum(gdb * chip_mask) / nreal
        # Padded chiplet lanes carry lambda=0; clamp inside the latency math
        # only (their latencies are masked to zero below) so serialization
        # never divides by zero.
        lam = wavelengths if wavelengths.ndim == 0 \
            else jnp.where(chip_mask > 0, wavelengths, 1.0)
        lam_mem = wavelengths if wavelengths.ndim == 0 \
            else jnp.sum(wavelengths * chip_mask) / nreal
        mesh_hops = topo["mesh_hops"]
        mesh_feed = 2.0 * topo["mesh_x"]
    if extra_db is not None:
        access_db = access_db + extra_db

    if dest is None:
        # Destination side: packets land on a uniformly random other chiplet;
        # the destination hop count mixes the other chiplets' activation
        # levels.
        dst_hops = mean_src_hops * jnp.ones_like(src_hops)
        inter_lat = noc.inter_chiplet_latency(gw_load, lam,
                                              src_hops, dst_hops)      # [C]
    else:
        # Destination-aware: resolve the actual source->destination gateway
        # pressure. recv_j is the load *received* by chiplet j; phi_j is the
        # fan-in concentration (inverse participation ratio of the arrival
        # mix — 1 for a single-source permutation, ~1/(C-1) for uniform),
        # which scales the ejection queue's effective burstiness: one
        # dominant source is a near-deterministic arrival process, many
        # interleaved sources keep the full batch factor.
        w_ij = ext_load[:, None] * dest                            # [C, C]
        recv = jnp.sum(w_ij, axis=0)                               # [C]
        phi = jnp.sum(w_ij * w_ij, axis=0) / jnp.maximum(recv * recv, 1e-12)
        burst_scale = (1.0 + (noc.burstiness - 1.0) * phi) / noc.burstiness
        dst_gw_load = recv / jnp.maximum(g.astype(jnp.float32), 1.0)  # [C]
        dst_leg = noc.access_latency(src_hops, dst_gw_load, burst_scale)
        if chip_mask is not None:
            dst_leg = jnp.where(chip_mask > 0, dst_leg, 0.0)
        inter_lat = (noc.access_latency(src_hops, gw_load)
                     + noc.gateway_latency(gw_load, lam)
                     + dest @ dst_leg)                                 # [C]
    if chip_mask is not None:
        inter_lat = jnp.where(chip_mask > 0, inter_lat, 0.0)
    mem_lat = noc.inter_chiplet_latency(mem_gw_load, lam_mem,
                                        mean_src_hops, 1.0)
    link_load = int_load * sim.cfg.packet_flits / mesh_feed
    intra_lat = noc.mesh_latency(mesh_hops, link_load)                 # [C]

    # Traffic-weighted average packet latency across chiplets + memory.
    # (In the padded path ext/int loads of padded chiplets are zero, so
    # every weighted term below is mask-correct by construction.)
    w_ext = ext_load
    tot_ext = jnp.sum(w_ext) + 1e-9
    tot_int = jnp.sum(int_load) + 1e-9
    tot_mem = mem_load + 1e-9
    lat = (jnp.sum(inter_lat * w_ext) + jnp.sum(intra_lat * int_load)
           + mem_lat * tot_mem) / (tot_ext + tot_int + tot_mem)
    out = {"latency": lat * t_valid, "gw_load": gw_load * t_valid,
           "inter_latency": inter_lat * t_valid,
           "mean_inter_latency": jnp.sum(inter_lat * w_ext) / tot_ext
                                 * t_valid,
           "access_db": access_db,
           "saturated": jnp.any(noc.saturated(gw_load, lam))
                        & (t_valid > 0)}
    if dest is not None:
        # Raw (un-time-masked, like ext_load itself): the controller's
        # pressure term consumes it inside the same step.
        out["recv_load"] = recv
    return out


def _prowaves_update(lam: jax.Array, inter_latency: jax.Array,
                     gw_load: jax.Array, sim: SimConfig) -> jax.Array:
    """PROWAVES wavelength adaptation: latency-target driven [16].

    PROWAVES picks the wavelength count that keeps the experienced network
    delay under a target derived from the zero-load latency. When the single
    gateway's electronic port is the bottleneck, extra wavelengths cannot
    reduce delay, so the controller ratchets to the maximum and stays there
    (the Fig. 12.d behavior) — power burns while latency stays high.
    Multiplicative up / down with hysteresis reproduces the ~5-interval
    instability on load transitions reported in §4.5.
    """
    base = sim.noc.inter_chiplet_latency(
        jnp.float32(1e-4), jnp.float32(PROWAVES_MAX_WAVELENGTHS),
        jnp.float32(2.5), jnp.float32(2.5))
    s = sim.noc.serialization_cycles(lam)
    rho_opt = gw_load * s
    lam_up = jnp.minimum(lam * 2, PROWAVES_MAX_WAVELENGTHS)
    lam_dn = jnp.maximum(lam // 2, PROWAVES_MIN_WAVELENGTHS)
    hot = inter_latency > 1.5 * base
    cold = (inter_latency < 1.3 * base) & (rho_opt < sim.prowaves_rho_lo)
    return jnp.where(hot, lam_up, jnp.where(cold, lam_dn, lam))


def make_step(sim: SimConfig, tables: dict, topo: Optional[dict] = None,
              faulted: bool = False, dest: Optional[jax.Array] = None):
    """Build the per-interval scan body for the chosen architecture.

    `topo` switches on the padded topology-sweep path: the chiplet/gateway
    axes are padded to the grid maximum, `topo["chip_mask"]` marks the real
    chiplets, and the per-topology scalars (actual gateway totals, mesh
    geometry, hop tables) are traced values. Padded chiplet lanes hold g=0
    and lambda=0 throughout, so activity masks, power sums, and reconfig
    energy see them as permanently dark gateways.

    `faulted` appends the fault-frame xs (gw_ok [C, G], stuck_on [C, G],
    drift_db scalar — see repro.core.faults): a failed gateway slot is a
    dead lane exactly like a padded one — it carries no traffic (the
    chiplet's capacity drops to the surviving slots), draws no power and
    charges no reconfig energy — while a stuck-on cell burns power the
    controller cannot gate, and drift_db erodes the optical budget. An
    all-healthy frame reproduces the fault-free step bit-for-bit, so the
    fault executables share every masking invariant with the clean ones.

    `dest` is the trace's optional [C, C] destination matrix, a per-trace
    constant closed over the step (not a per-interval xs): it re-prices the
    destination leg in `_interval_metrics` and feeds the gateway controller
    a received-load pressure term, so congestion-aware deployment reacts to
    where packets actually *land*. `dest=None` is the pre-dest step,
    bit-for-bit.
    """
    cfg, ctl_cfg = sim.cfg, sim.ctl
    interval = float(cfg.reconfig_interval_cycles)
    n_total = cfg.total_gateways
    gmax = cfg.max_gateways_per_chiplet
    chip_mask = None if topo is None else topo["chip_mask"]
    # Actual (traced) counts for count-dependent power terms; None selects
    # the static-config behavior on the unpadded path.
    gw_count = None if topo is None else topo["total_gateways"]
    n_chips = cfg.n_chiplets if topo is None else topo["n_chiplets"]

    def _lit_mask(g_des: jax.Array, gw_ok: jax.Array,
                  stuck_on: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(usable [C, G], powered chain [N_total] bool) under faults.

        usable = slots the controller wants AND whose hardware works;
        powered = usable OR stuck-on-but-working (a lane the PCM cannot
        darken still burns laser/ring power); memory gateways are always
        on. A failed slot is in neither — provably dark and dead.
        """
        desired = (jnp.arange(gmax)[None, :]
                   < g_des[:, None]).astype(jnp.float32)        # [C, G]
        usable = desired * gw_ok
        lit = jnp.maximum(usable, stuck_on * gw_ok)
        mem_on = jnp.ones((cfg.memory_gateways,), jnp.float32)
        return usable, jnp.concatenate([lit.reshape(-1), mem_on]) > 0.5

    def step(state: SimState, tr) -> Tuple[SimState, dict]:
        ext, mem, intra, ext_frac, t_valid = tr[:5]
        if faulted:
            gw_ok, stuck_on, drift_db = tr[5:]
        else:
            gw_ok = stuck_on = drift_db = None
        if sim.arch in (Arch.RESIPI, Arch.RESIPI_ALL):
            g = state.ctl.g
            lam = jnp.float32(sim.wavelengths)
        elif sim.arch == Arch.PROWAVES:
            g = jnp.ones((cfg.n_chiplets,), jnp.int32) if topo is None \
                else (chip_mask > 0).astype(jnp.int32)
            lam = state.wavelengths.astype(jnp.float32)
        else:  # AWGR: all gateways, 1 lambda per port
            g = jnp.full((cfg.n_chiplets,), cfg.max_gateways_per_chiplet,
                         jnp.int32) if topo is None \
                else jnp.where(chip_mask > 0,
                               topo["g_max"].astype(jnp.int32), 0)
            lam = jnp.float32(1.0)

        # Fault-effective capacity: the chiplet only has its usable active
        # slots; g_eff == g whenever the frame never fires (exact parity).
        if faulted:
            usable, active_eff = _lit_mask(g, gw_ok, stuck_on)
            g_eff = jnp.sum(usable, axis=1).astype(jnp.int32)
        else:
            g_eff = g

        m = _interval_metrics(g_eff, lam, ext, mem, intra, ext_frac, sim,
                              tables, topo, t_valid=t_valid,
                              extra_db=drift_db, dest=dest)

        # --- power ---------------------------------------------------------
        active = active_eff if faulted else _activity_mask(g, sim)
        if sim.arch == Arch.PROWAVES:
            # 1 lit gateway per chiplet + memory gateways, per-chiplet
            # lambdas. Padded chiplet lanes carry lambda=0, so the "wdm"
            # power sums are mask-correct without further masking.
            n_pw = cfg.n_chiplets + cfg.memory_gateways
            w = state.wavelengths.astype(jnp.float32)
            if faulted:
                # A failed PROWAVES gateway (slot 0 is the chiplet's only
                # one) takes its lasers down with it: lambda * gw_ok is 0
                # for dead chiplets, identity for healthy ones.
                w = w * gw_ok[:, 0]
            if topo is None:
                lam_mem_val = jnp.mean(w)
            else:
                lam_mem_val = jnp.sum(w) / jnp.maximum(
                    jnp.sum(chip_mask), 1.0)
            lam_mem = jnp.full((cfg.memory_gateways,), lam_mem_val)
            per_gw_lam = jnp.concatenate([w, lam_mem])
            pw = photonics.interposer_power_mw(
                jnp.ones((n_pw,), bool), per_gw_lam,
                n_gateways=n_pw, mode="wdm", loss_db=m["access_db"],
                n_chiplets=n_chips)
        elif sim.arch == Arch.AWGR:
            # One wavelength per provisioned port (18 total in Table 1);
            # padded lanes are inactive, so summing the activity mask keeps
            # the laser/filter counts at the topology's real port count.
            pw = photonics.interposer_power_mw(
                active, active.astype(jnp.float32),
                n_gateways=n_total,
                loss_db=PHOTONIC_POWER.awgr_loss_db + m["access_db"],
                mode="static", gateway_count=gw_count, n_chiplets=n_chips)
        else:
            pw = photonics.interposer_power_mw(
                active, jnp.float32(sim.wavelengths),
                n_gateways=n_total, mode="pcm", loss_db=m["access_db"],
                n_chiplets=n_chips)

        # --- controller update ----------------------------------------------
        reconf_nj = jnp.float32(0.0)
        if sim.arch == Arch.RESIPI:
            if dest is None:
                pressure = ext
            else:
                # Destination-aware deployment pressure: a gateway group
                # serves both the chiplet's injected and received packets,
                # so the controller meters the hotter of the two — transpose
                # hot-destinations activate spares even though their own
                # injection is modest.
                pressure = jnp.maximum(ext, m["recv_load"])
            packets = pressure * interval
            if faulted:
                # The controller meters load per USABLE gateway: failures
                # concentrate the same packets on fewer lanes, so the
                # measured load rises and the hysteresis law activates
                # spares on its own (epoch_step divides by the desired g,
                # hence the g/g_eff rescale; exactly 1.0 when healthy).
                packets = packets * (g.astype(jnp.float32)
                                     / jnp.maximum(
                                         g_eff.astype(jnp.float32), 1.0))
            new_ctl, rec = epoch_step(state.ctl, packets, interval, ctl_cfg)
            if faulted:
                _, new_active = _lit_mask(new_ctl.g, gw_ok, stuck_on)
            else:
                new_active = _activity_mask(new_ctl.g, sim)
            reconf_nj = photonics.reconfig_energy_nj(active, new_active)
            new_state = SimState(ctl=new_ctl, wavelengths=state.wavelengths,
                                 prev_active=new_active)
        elif sim.arch == Arch.PROWAVES:
            lam_new = _prowaves_update(state.wavelengths,
                                       m["inter_latency"], m["gw_load"], sim)
            if chip_mask is not None:
                # Keep padded chiplet lanes at lambda=0 explicitly: the
                # controller's `cold` branch would otherwise ratchet a dead
                # lane up to the minimum wavelength floor, and the "wdm"
                # power sums are unmasked by design.
                lam_new = jnp.where(chip_mask > 0, lam_new, 0)
            new_state = SimState(ctl=state.ctl, wavelengths=lam_new,
                                 prev_active=active)
        else:
            new_state = SimState(ctl=state.ctl, wavelengths=state.wavelengths,
                                 prev_active=active)

        # energy proxy: mW * cycles-per-packet -> pJ-scale unit (model units)
        # (latency is already t_valid-masked, so energy is too.)
        energy = pw["total_mw"] * m["latency"]
        lam_rec = lam * jnp.ones((cfg.n_chiplets,)) if topo is None \
            else lam * chip_mask
        # Time-mask every record: a padded tail interval must read as zero
        # gateways / zero power / zero reconfig energy, never as an idle but
        # powered network — the t-axis analogue of the chiplet masking.
        rec = {"latency": m["latency"], "power_mw": pw["total_mw"] * t_valid,
               "laser_mw": pw["laser_mw"] * t_valid, "energy": energy,
               "reconfig_nj": reconf_nj * t_valid,
               # "g" reports the EFFECTIVE gateway count (usable active
               # slots): failed slots count zero in every reduction, like
               # padded ones. g_eff == g on every fault-free path.
               "g": g_eff * t_valid.astype(g_eff.dtype),
               "wavelengths": lam_rec * t_valid,
               "gw_load": m["gw_load"],
               "mean_inter_latency": m["mean_inter_latency"],
               "saturated": m["saturated"]}
        if faulted:
            # Fault telemetry (fault executables only — extra record keys
            # never feed _record_sums): the controller's desired g and the
            # count of desired-but-dead slots per interval.
            rec["g_desired"] = g * t_valid.astype(g.dtype)
            rec["failed_slots"] = (jnp.sum(
                (jnp.arange(gmax)[None, :] < g[:, None]) * (gw_ok < 0.5))
                .astype(jnp.float32) * t_valid)
        # Masked intervals FREEZE the carry (like the noc_step kernel's
        # frozen cycles): the controller must not react to the fake idle
        # epochs of a padded gap, so a mask-interior gap — a mid-stream
        # padded chunk, a concat of padded traces — resumes exactly where
        # the last valid interval left off.
        new_state = jax.tree.map(
            lambda new, old: jnp.where(t_valid > 0, new, old),
            new_state, state)
        return new_state, rec

    return step


# ---------------------------------------------------------------------------
# Engine core
# ---------------------------------------------------------------------------

# Trace-time counters: bumped every time jax actually traces a simulation
# body. A warm jit cache leaves these untouched — tests/benches assert on it.
# `search_dispatches` counts device-resident search executable launches
# (repro.core.search): one whole annealed search == one dispatch.
_STATS = {"traces": 0, "search_dispatches": 0}

# Config fields that `sweep` may override with runtime (traced) scalars.
# All are scalar knobs that feed jnp comparisons/arithmetic — nothing that
# changes array shapes (max_gateways/min_gateways clamp the controller; the
# gateway-slot axis is still sized by the static max_gateways_per_chiplet).
SWEEPABLE_FIELDS = ("l_m", "buffer_sat", "wavelengths",
                    "prowaves_rho_hi", "prowaves_rho_lo",
                    "max_gateways", "min_gateways")

# Shape-defining topology axes that `sweep_topology` batches via pad-to-max:
# every grid point is padded to the grid maxima (chiplets, gateway slots,
# routers) and carried through ONE compiled executable with validity masks.
# `gateway_positions` is the placement axis (PlaceIT-style DSE): each grid
# value is a placement — a tuple of (x, y) router coordinates in activation
# order, or None for the default edge scheme — and enters the executable
# purely through traced per-point tables (src_hops / gw_loss_db), so K
# placements never cost K compiles.
TOPOLOGY_SWEEPABLE_FIELDS = ("n_chiplets", "gateways_per_chiplet",
                             "mesh_radix", "gateway_positions")


def engine_stats() -> dict:
    """Engine instrumentation: scan-body trace count + table-cache stats."""
    info = build_selection_tables.cache_info()
    return {"simulate_traces": _STATS["traces"],
            "search_dispatches": _STATS["search_dispatches"],
            "selection_table_builds": info.misses,
            "selection_table_hits": info.hits}


def reset_engine_stats() -> None:
    _STATS["traces"] = 0
    _STATS["search_dispatches"] = 0


def clear_engine_caches() -> None:
    """Drop every jit executable the engine holds (cold-start measurement).

    The single place that knows all jitted entry points — benches must use
    this instead of reaching for the private wrappers, so adding an entry
    point can't silently leave a warm cache in a 'cold' measurement.
    """
    from repro.core.pareto import clear_codesign_caches
    from repro.core.search import clear_search_caches
    from repro.core.traffic.dest import clear_destination_caches

    for f in (_simulate_jit, _simulate_batch_jit, _sweep_jit,
              _sweep_batch_jit, _sweep_topology_jit,
              _sweep_topology_batch_jit, _sweep_workload_jit,
              _sweep_workload_topo_jit, _session_chunk_jit,
              _simulate_faults_jit, _simulate_batch_faults_jit,
              _sweep_faults_jit, _session_chunk_faults_jit,
              _session_tick_jit, _session_tick_faults_jit):
        f.clear_cache()
    clear_search_caches()
    clear_codesign_caches()
    clear_destination_caches()


def _grid_len(name: str, values) -> int:
    """Length of one swept grid, rejecting scalars with a clear message."""
    if name == "gateway_positions":
        if not isinstance(values, (list, tuple)):
            raise ValueError(
                f"swept field {name!r} must be a list of placements "
                f"(each a tuple of (x, y) pairs or None), got "
                f"{type(values).__name__}")
        return len(values)
    try:
        arr = jnp.asarray(values)
    except (TypeError, ValueError) as e:
        raise ValueError(
            f"swept field {name!r} must be a numeric grid "
            f"({e})") from None
    if arr.ndim != 1:
        raise ValueError(
            f"swept field {name!r} must be a 1-D grid of values, got "
            f"shape {arr.shape} — wrap a single value as [{name}_value]")
    return int(arr.shape[0])


def _apply_overrides(sim: SimConfig, ov: Optional[Dict[str, jax.Array]]
                     ) -> SimConfig:
    """Graft runtime override scalars into a (traced) config copy.

    The returned SimConfig holds tracers and must never be hashed / used as
    a static jit argument — it only flows through the scan body.
    """
    if not ov:
        return sim
    unknown = set(ov) - set(SWEEPABLE_FIELDS)
    if unknown:
        raise ValueError(f"non-sweepable fields: {sorted(unknown)} "
                         f"(sweepable: {SWEEPABLE_FIELDS})")
    ctl_over = {k: ov[k] for k in ("l_m", "max_gateways", "min_gateways")
                if k in ov}
    if ctl_over:
        sim = dataclasses.replace(sim, ctl=dataclasses.replace(
            sim.ctl, **ctl_over))
    if "buffer_sat" in ov:
        sim = dataclasses.replace(sim, noc=dataclasses.replace(
            sim.noc, buffer_sat=ov["buffer_sat"]))
    if "wavelengths" in ov:
        sim = dataclasses.replace(sim, wavelengths=ov["wavelengths"])
    if "prowaves_rho_hi" in ov:
        sim = dataclasses.replace(sim, prowaves_rho_hi=ov["prowaves_rho_hi"])
    if "prowaves_rho_lo" in ov:
        sim = dataclasses.replace(sim, prowaves_rho_lo=ov["prowaves_rho_lo"])
    return sim


def _initial_state(sim: SimConfig) -> SimState:
    """Fresh unpadded simulation state (shared by `simulate`/`SimSession`)."""
    cfg = sim.cfg
    return SimState(
        ctl=ControllerState.init(cfg.n_chiplets, sim.ctl),
        wavelengths=jnp.full((cfg.n_chiplets,), PROWAVES_MAX_WAVELENGTHS
                             if sim.arch == Arch.PROWAVES else
                             sim.wavelengths, jnp.int32),
        prev_active=_activity_mask(
            jnp.full((cfg.n_chiplets,), cfg.max_gateways_per_chiplet,
                     jnp.int32), sim))


def _scan_trace(state: SimState, xs, sim: SimConfig, tables: Optional[dict],
                topo: Optional[dict], faulted: bool = False,
                dest: Optional[jax.Array] = None) -> Tuple[SimState, dict]:
    """Run the per-interval scan; the ONE place the trace counter bumps.

    With `sim.epoch_kernel` set the whole interval scan runs as the fused
    `kernels.epoch_step` Pallas kernel (one kernel launch for T intervals)
    on the configurations it supports; everything else — and every parity
    oracle — takes the lax.scan body below. Both bodies share this counter:
    one trace per scan, whichever engine executes it.
    """
    _STATS["traces"] += 1
    if sim.epoch_kernel and topo is None \
            and sim.arch in (Arch.RESIPI, Arch.RESIPI_ALL):
        from repro.kernels.epoch_step.ops import epoch_run_pallas
        return epoch_run_pallas(state, xs, sim, tables,
                                dest=dest, faulted=faulted)
    step = make_step(sim, tables, topo, faulted=faulted, dest=dest)
    return jax.lax.scan(step, state, xs)


def _record_sums(recs: dict, t_mask: jax.Array) -> dict:
    """Mask-correct record totals: the sufficient statistics every summary
    (one-shot, padded lane, or streaming accumulation) is computed from.
    Records are already t_valid-masked in the scan body, so plain sums
    ignore padded tail intervals by construction."""
    return {
        "latency": jnp.sum(recs["latency"]),
        "power_mw": jnp.sum(recs["power_mw"]),
        "energy": jnp.sum(recs["energy"]),
        "gateways": jnp.sum(recs["g"]).astype(jnp.float32),
        "wavelengths": jnp.sum(recs["wavelengths"]),
        "saturated": jnp.sum(recs["saturated"].astype(jnp.float32)),
        "reconfig_nj": jnp.sum(recs["reconfig_nj"]),
        "valid_intervals": jnp.sum(t_mask),
    }


def _summary_from_sums(sums: dict, n_chiplets_for_lambda) -> dict:
    """Summary means from `_record_sums` totals.

    `n_chiplets_for_lambda` is the per-interval lambda-record width used to
    normalize mean_wavelengths (the real chiplet count on padded paths).
    """
    t = jnp.maximum(sums["valid_intervals"], 1.0)
    return {
        "mean_latency": sums["latency"] / t,
        "mean_power_mw": sums["power_mw"] / t,
        "mean_energy": sums["energy"] / t,
        "mean_gateways": sums["gateways"] / t,
        "mean_wavelengths": sums["wavelengths"]
                            / (t * n_chiplets_for_lambda),
        "saturated_frac": sums["saturated"] / t,
        "total_reconfig_nj": sums["reconfig_nj"],
        "valid_intervals": sums["valid_intervals"],
    }


# The summary schema `_summary_from_sums` emits, as a fixed-order tuple:
# the device-resident search (repro.core.search) packs best-candidate
# summaries as vectors in this order, and both search engines validate
# objectives against it — keep in sync with the dict above (pinned by
# tests/test_search.py).
SUMMARY_KEYS = ("mean_latency", "mean_power_mw", "mean_energy",
                "mean_gateways", "mean_wavelengths", "saturated_frac",
                "total_reconfig_nj", "valid_intervals")

# Short objective names accepted by the placement search engines.
PLACEMENT_OBJECTIVE_ALIASES = {"latency": "mean_latency",
                               "power": "mean_power_mw",
                               "energy": "mean_energy"}


def check_placement_objective(objective: str) -> None:
    """Shared search-objective validation (host and device engines)."""
    if objective == "inter_latency":
        return
    if PLACEMENT_OBJECTIVE_ALIASES.get(objective, objective) \
            not in SUMMARY_KEYS:
        raise ValueError(
            f"unknown placement objective {objective!r} (use "
            f"'inter_latency', 'latency', 'power', 'energy' or a summary "
            f"key: {sorted(SUMMARY_KEYS)})")


def _simulate_impl(ext: jax.Array, mem: jax.Array, intra: jax.Array,
                   ext_frac: jax.Array, t_mask: jax.Array, sim: SimConfig,
                   tables: dict, ov: Optional[Dict[str, jax.Array]] = None,
                   topo: Optional[dict] = None,
                   faults: Optional[Tuple[jax.Array, ...]] = None,
                   dest: Optional[jax.Array] = None) -> dict:
    """Scan body shared by every entry point (single / batch / sweep).

    With `topo` the trace/state is padded on the chiplet axis: `sim.cfg`
    describes the *padded* shape (grid maxima) and `topo` carries the
    per-topology actuals. Padded chiplets start with g=0 and lambda=0,
    inject zero traffic, and — because the controller thresholds can only
    raise g on positive load — stay dark for the whole scan.

    `t_mask` [T] is the time-axis validity vector (all-ones for full-length
    traces): masked intervals inject zero traffic, record zeros everywhere,
    and are excluded from every summary mean, so a tail-padded trace is
    bit-equivalent to its unpadded original.
    """
    sim = _apply_overrides(sim, ov)
    cfg = sim.cfg
    t_mask = t_mask.astype(jnp.float32)
    ext = ext * t_mask[:, None]
    mem = mem * t_mask
    intra = intra * t_mask[:, None]
    if topo is None:
        state0 = _initial_state(sim)
    else:
        valid = jnp.arange(cfg.n_chiplets) < topo["n_chiplets"]
        chip_mask = valid.astype(jnp.float32)
        topo = dict(topo, chip_mask=chip_mask)
        ext = ext * chip_mask
        intra = intra * chip_mask
        if dest is not None:
            # Padded chiplet columns receive nothing and padded rows send
            # nothing; surviving rows re-normalize to row-stochastic with
            # the same formula as traffic.slice_trace, so the padded view
            # prices destinations exactly like the sliced one.
            d = dest * chip_mask[None, :] * chip_mask[:, None]
            row = jnp.sum(d, axis=-1, keepdims=True)
            dest = jnp.where(row > 0.0, d / jnp.maximum(row, 1e-12), 0.0)
        g0 = jnp.where(valid,
                       jnp.asarray(sim.ctl.max_gateways).astype(jnp.int32),
                       0)
        w0 = PROWAVES_MAX_WAVELENGTHS if sim.arch == Arch.PROWAVES \
            else sim.wavelengths
        state0 = SimState(
            ctl=ControllerState(
                g=g0,
                packets_seen=jnp.zeros((cfg.n_chiplets,), jnp.float32),
                epoch=jnp.int32(0)),
            wavelengths=jnp.where(valid,
                                  jnp.asarray(w0).astype(jnp.int32), 0),
            prev_active=jnp.zeros((cfg.total_gateways,), bool))

    xs = (ext, mem, intra, jnp.broadcast_to(ext_frac, mem.shape), t_mask)
    if faults is not None:
        if topo is not None:
            raise ValueError("fault frames are not supported on the padded-"
                             "topology paths (run faults on an unpadded "
                             "config, or sweep them with sweep_faults)")
        xs = xs + tuple(faults)
    _, recs = _scan_trace(state0, xs, sim, tables, topo,
                          faulted=faults is not None, dest=dest)

    # Masked chiplet lanes record lambda=0 and must not dilute the
    # per-chiplet average on padded-topology paths.
    n_lam = cfg.n_chiplets if topo is None \
        else jnp.maximum(jnp.sum(topo["chip_mask"]), 1.0)
    summary = _summary_from_sums(_record_sums(recs, t_mask), n_lam)
    return {"records": recs, "summary": summary}


def _trace_arrays(trace: dict) -> Tuple[jax.Array, ...]:
    """(ext, mem, intra, ext_frac, t_mask, dest) — dest is None (an empty
    jit/vmap pytree, so destination-free traces keep their exact executable
    signatures) unless the trace carries a destination matrix."""
    traffic.validate_trace(trace)
    mem = trace["mem_load"]
    t_mask = trace.get("t_mask")
    t_mask = jnp.ones(jnp.shape(mem), jnp.float32) if t_mask is None \
        else jnp.asarray(t_mask, jnp.float32)
    dest = trace.get("dest")
    dest = None if dest is None else jnp.asarray(dest, jnp.float32)
    return (trace["ext_load"], mem, trace["int_load"],
            jnp.asarray(trace["ext_frac"]), t_mask, dest)


def _trace_faults(trace: dict) -> Optional[Tuple[jax.Array, ...]]:
    """The trace's fault frame as scan xs, or None when it carries none.

    Returns (gw_ok [..., T, C, G], stuck_on [..., T, C, G], drift_db
    [..., T]) in FAULT_KEYS order. A partial frame (some keys missing)
    raises instead of silently simulating fault-free.
    """
    present = [k for k in FAULT_KEYS if k in trace]
    if not present:
        return None
    missing = [k for k in FAULT_KEYS if k not in trace]
    if missing:
        raise ValueError(
            f"trace carries fault keys {present} but is missing {missing} "
            f"— attach a complete frame with faults.attach_faults")
    return tuple(jnp.asarray(trace[k], jnp.float32) for k in FAULT_KEYS)


@functools.partial(jax.jit, static_argnames=("sim",))
def _simulate_jit(ext, mem, intra, ext_frac, t_mask, tables, dest=None, *,
                  sim: SimConfig):
    return _simulate_impl(ext, mem, intra, ext_frac, t_mask, sim, tables,
                          dest=dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _simulate_faults_jit(ext, mem, intra, ext_frac, t_mask, tables, flt,
                         dest=None, *, sim: SimConfig):
    """Fault twin of `_simulate_jit` (its own executable: the no-fault
    entry points keep their exact shapes and caches)."""
    return _simulate_impl(ext, mem, intra, ext_frac, t_mask, sim, tables,
                          faults=flt, dest=dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _simulate_batch_faults_jit(ext, mem, intra, ext_frac, t_mask, tables,
                               flt, dest=None, *, sim: SimConfig):
    return jax.vmap(
        lambda e, m, i, f, t, fl, d: _simulate_impl(e, m, i, f, t, sim,
                                                    tables, faults=fl,
                                                    dest=d)
    )(ext, mem, intra, ext_frac, t_mask, flt, dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_faults_jit(ext, mem, intra, ext_frac, t_mask, tables, flt, ov,
                      dest=None, *, sim: SimConfig):
    """K fault frames (zipped with optional K runtime overrides) over one
    trace — the fault grid vmaps exactly like every other sweep axis."""
    return jax.vmap(
        lambda fl, o: _simulate_impl(ext, mem, intra, ext_frac, t_mask, sim,
                                     tables, o, faults=fl, dest=dest)
    )(flt, ov)


@functools.partial(jax.jit, static_argnames=("sim",))
def _simulate_batch_jit(ext, mem, intra, ext_frac, t_mask, tables, dest=None,
                        *, sim: SimConfig):
    return jax.vmap(
        lambda e, m, i, f, t, d: _simulate_impl(e, m, i, f, t, sim, tables,
                                                dest=d)
    )(ext, mem, intra, ext_frac, t_mask, dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_jit(ext, mem, intra, ext_frac, t_mask, tables, ov, dest=None, *,
               sim: SimConfig):
    return jax.vmap(
        lambda o: _simulate_impl(ext, mem, intra, ext_frac, t_mask, sim,
                                 tables, o, dest=dest)
    )(ov)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_batch_jit(ext, mem, intra, ext_frac, t_mask, tables, ov,
                     dest=None, *, sim: SimConfig):
    def one_trace(e, m, i, f, t, d):
        return jax.vmap(
            lambda o: _simulate_impl(e, m, i, f, t, sim, tables, o,
                                     dest=d))(ov)
    return jax.vmap(one_trace)(ext, mem, intra, ext_frac, t_mask, dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_topology_jit(ext, mem, intra, ext_frac, t_mask, topo, ov,
                        dest=None, *, sim: SimConfig):
    # `dest` is the one generated-at-c_max matrix, closed over the K-point
    # vmap: each point masks/re-normalizes it to its own chiplet count
    # inside `_simulate_impl` (traced chip_mask), so one matrix serves the
    # whole padded grid.
    return jax.vmap(
        lambda tp, o: _simulate_impl(ext, mem, intra, ext_frac, t_mask,
                                     sim, None, o, topo=tp,
                                     dest=dest))(topo, ov)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_topology_batch_jit(ext, mem, intra, ext_frac, t_mask, topo, ov,
                              dest=None, *, sim: SimConfig):
    def one_trace(e, m, i, f, t, d):
        return jax.vmap(
            lambda tp, o: _simulate_impl(e, m, i, f, t, sim, None,
                                         o, topo=tp, dest=d))(topo, ov)
    return jax.vmap(one_trace)(ext, mem, intra, ext_frac, t_mask, dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_workload_jit(ext, mem, intra, ext_frac, t_mask, tables, ov,
                        dest=None, *, sim: SimConfig):
    """K workload lanes zipped with K runtime-override lanes (one scan)."""
    return jax.vmap(
        lambda e, m, i, f, t, o, d: _simulate_impl(e, m, i, f, t, sim,
                                                   tables, o, dest=d)
    )(ext, mem, intra, ext_frac, t_mask, ov, dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _sweep_workload_topo_jit(ext, mem, intra, ext_frac, t_mask, topo, ov,
                             dest=None, *, sim: SimConfig):
    """K workload lanes zipped with K padded-topology/placement lanes."""
    return jax.vmap(
        lambda e, m, i, f, t, tp, o, d: _simulate_impl(e, m, i, f, t, sim,
                                                       None, o, topo=tp,
                                                       dest=d)
    )(ext, mem, intra, ext_frac, t_mask, topo, ov, dest)


@functools.partial(jax.jit, static_argnames=("sim",), donate_argnums=(0,))
def _session_chunk_jit(state, ext, mem, intra, ext_frac, t_mask, tables,
                       dest=None, *, sim: SimConfig):
    """One streaming chunk: scan from the carried state, return the new
    carry (donated — the old state's buffers are reused in place), the
    chunk's records, and mask-correct running totals."""
    t_mask = t_mask.astype(jnp.float32)
    xs = (ext * t_mask[:, None], mem * t_mask, intra * t_mask[:, None],
          jnp.broadcast_to(ext_frac, mem.shape), t_mask)
    new_state, recs = _scan_trace(state, xs, sim, tables, None, dest=dest)
    return new_state, recs, _record_sums(recs, t_mask)


@functools.partial(jax.jit, static_argnames=("sim",), donate_argnums=(0,))
def _session_chunk_faults_jit(state, ext, mem, intra, ext_frac, t_mask,
                              tables, flt, dest=None, *, sim: SimConfig):
    """Fault twin of `_session_chunk_jit`: the chunk's fault-frame slice
    (aligned by chunk_trace, which slices FAULT_KEYS with the loads) rides
    as extra scan xs; clean chunks keep their own executable."""
    t_mask = t_mask.astype(jnp.float32)
    xs = (ext * t_mask[:, None], mem * t_mask, intra * t_mask[:, None],
          jnp.broadcast_to(ext_frac, mem.shape), t_mask) + tuple(flt)
    new_state, recs = _scan_trace(state, xs, sim, tables, None, faulted=True,
                                  dest=dest)
    return new_state, recs, _record_sums(recs, t_mask)


@functools.partial(jax.jit, static_argnames=("sim",))
def _session_tick_jit(states, ext, mem, intra, ext_frac, t_mask, tables,
                      dest=None, *, sim: SimConfig):
    """One continuous-batching server tick: B session carries advance
    through B masked chunk scans as ONE vmapped executable.

    Lane semantics are exactly `_session_chunk_jit` per lane (the vmap is
    bit-transparent on CPU — pinned by tests/test_serve.py): a lane whose
    `t_mask` row is all zeros injects nothing, records zeros, and FREEZES
    its carry, so empty / backing-off / parked lanes ride along for free
    and the executable's [B, T] shape never changes across ticks.
    """
    def one(st, e, m, i, f, t, d):
        t = t.astype(jnp.float32)
        xs = (e * t[:, None], m * t, i * t[:, None],
              jnp.broadcast_to(f, m.shape), t)
        new_state, recs = _scan_trace(st, xs, sim, tables, None, dest=d)
        return new_state, recs, _record_sums(recs, t)
    return jax.vmap(one)(states, ext, mem, intra, ext_frac, t_mask, dest)


@functools.partial(jax.jit, static_argnames=("sim",))
def _session_tick_faults_jit(states, ext, mem, intra, ext_frac, t_mask,
                             tables, flt, dest=None, *, sim: SimConfig):
    """Fault twin of `_session_tick_jit`: the tick's fault frame lives on
    hardware time and is SHARED by every lane (closed over, not vmapped) —
    all sessions experience the same interposer this tick. Its own
    executable, so fault-free serving keeps the clean tick's cache."""
    def one(st, e, m, i, f, t, d):
        t = t.astype(jnp.float32)
        xs = (e * t[:, None], m * t, i * t[:, None],
              jnp.broadcast_to(f, m.shape), t) + tuple(flt)
        new_state, recs = _scan_trace(st, xs, sim, tables, None,
                                      faulted=True, dest=d)
        return new_state, recs, _record_sums(recs, t)
    return jax.vmap(one)(states, ext, mem, intra, ext_frac, t_mask, dest)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def simulate(trace: dict, sim: SimConfig) -> dict:
    """Run a full trace; returns per-interval records + summary scalars.

    Compile-once: `sim` is a static jit argument, so a second call with an
    equal config and trace shape re-traces nothing (engine_stats() shows the
    counter), and the selection tables are memoized per NetworkConfig.

    A trace carrying a fault frame (faults.attach_faults) routes to the
    fault twin of the scan automatically; traces without one never pay for
    the fault arithmetic and keep their own executables.
    """
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(trace)
    flt = _trace_faults(trace)
    if flt is not None:
        return _simulate_faults_jit(ext, mem, intra, ext_frac, t_mask,
                                    selection_tables_jax(sim.cfg), flt,
                                    dest, sim=sim)
    return _simulate_jit(ext, mem, intra, ext_frac, t_mask,
                         selection_tables_jax(sim.cfg), dest, sim=sim)


def simulate_eager(trace: dict, sim: SimConfig) -> dict:
    """Seed-parity path: rebuild tables and re-trace the scan every call.

    Kept as the benchmark baseline (bench_engine.py) — do not use in sweeps.
    """
    tables = rebuild_selection_tables(sim.cfg)
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(trace)
    return _simulate_impl(ext, mem, intra, ext_frac, t_mask, sim, tables,
                          dest=dest)


def rebuild_selection_tables(cfg: NetworkConfig) -> dict:
    """Uncached table build (bypasses both lru_caches) for baselines."""
    return build_selection_tables.__wrapped__(cfg).as_jax()


# Deprecated pre-PEP8 alias (PR 3 rename): kept so bench_engine.py baselines
# recorded against the old name keep importing/running unchanged.
SelectionTables_rebuild = rebuild_selection_tables


def stack_traces(traces: List[dict], *, pad: bool = False) -> dict:
    """Stack N traces along a new leading batch axis.

    Same-length traces stack directly (the pre-PR-4 behavior). Mixed-length
    (ragged-T) traces need `pad=True`: shorter traces zero-pad to the
    longest T and the stacked dict carries a `t_mask` [N, T] validity mask
    — masked tail intervals contribute exactly zero to every engine
    reduction, so padded lane k simulates identically to the unpadded
    trace k. Without `pad=True`, ragged inputs raise a ValueError naming
    the lengths (instead of the old cryptic jnp stacking error).
    """
    if not traces:
        raise ValueError("stack_traces() needs at least one trace")
    for i, tr in enumerate(traces):
        traffic.validate_trace(tr, who=f"traces[{i}]")
    chips = sorted({int(jnp.shape(tr["ext_load"])[-1]) for tr in traces})
    if len(chips) != 1:
        raise ValueError(
            f"traces cover different chiplet counts {chips}; narrow them "
            f"to one width first (traffic.slice_trace)")
    lengths = [int(jnp.shape(tr["ext_load"])[0]) for tr in traces]
    ragged = len(set(lengths)) > 1
    if ragged and not pad:
        raise ValueError(
            f"traces have mixed lengths T={lengths}; pass pad=True to "
            f"zero-pad them to T={max(lengths)} under a t_mask (the "
            f"ragged/padded batch path — simulate_batch/sweep_batch/"
            f"sweep_workload do this automatically for list inputs)")
    masked = pad or ragged or any("t_mask" in tr for tr in traces)
    if masked:
        traces = [traffic.pad_trace(tr, max(lengths)) for tr in traces]
    n_faulted = sum(_trace_faults(tr) is not None for tr in traces)
    if n_faulted not in (0, len(traces)):
        raise ValueError(
            f"{n_faulted}/{len(traces)} traces carry fault frames; a "
            f"batch must be uniformly faulted or uniformly clean (attach "
            f"faults.no_faults frames to the clean ones)")
    n_dest = sum(tr.get("dest") is not None for tr in traces)
    if n_dest not in (0, len(traces)):
        raise ValueError(
            f"{n_dest}/{len(traces)} traces carry destination matrices; a "
            f"batch must be uniformly destination-aware or uniformly "
            f"uniform-destination (generate every trace with dest=True, "
            f"or none)")
    keys = ("ext_load", "mem_load", "int_load", "ext_frac") \
        + (("t_mask",) if masked else ()) \
        + (("dest",) if n_dest else ()) \
        + (FAULT_KEYS if n_faulted else ())
    out = {k: jnp.stack([jnp.asarray(tr[k]) for tr in traces])
           for k in keys}
    out["app"] = [tr.get("app", "?") for tr in traces]
    return out


def simulate_batch(traces, sim: SimConfig) -> dict:
    """Batched simulate: one vmapped, jit-cached scan over N traces.

    `traces` is either a list of trace dicts (stacked here; mixed-length
    traces pad to the longest T under a `t_mask`) or an already-stacked
    dict with a leading batch axis (from `stack_traces`). Records and
    summary values gain that leading [N] axis; for ragged batches the
    records of shorter lanes are zero beyond their own T.
    """
    batch = stack_traces(traces, pad=True) \
        if isinstance(traces, (list, tuple)) else traces
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(batch)
    flt = _trace_faults(batch)
    if flt is not None:
        return _simulate_batch_faults_jit(ext, mem, intra, ext_frac, t_mask,
                                          selection_tables_jax(sim.cfg),
                                          flt, dest, sim=sim)
    return _simulate_batch_jit(ext, mem, intra, ext_frac, t_mask,
                               selection_tables_jax(sim.cfg), dest, sim=sim)


def sweep(trace: dict, sim: SimConfig, **fields) -> dict:
    """Vmapped DSE over scalar config fields, e.g.::

        sweep(tr, sim, l_m=jnp.linspace(0.005, 0.03, 64))

    Every swept field (see SWEEPABLE_FIELDS) gets a 1-D array of values; all
    arrays must share one length K. The K simulations run as a single
    compiled vmapped scan — results carry a leading [K] axis. Compilation is
    cached on (trace shape, config, set of swept fields, grid length K),
    not on the grid *values*, so re-sweeping a same-sized grid elsewhere in
    the space is compile-free.
    """
    ov = _check_sweep_fields(fields)
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(trace)
    return _sweep_jit(ext, mem, intra, ext_frac, t_mask,
                      selection_tables_jax(sim.cfg), ov, dest, sim=sim)


def _check_sweep_fields(fields) -> Dict[str, jax.Array]:
    if not fields:
        raise ValueError("sweep() needs at least one field=values pair")
    ov = {k: jnp.asarray(v) for k, v in fields.items()}
    lengths = {k: a.shape for k, a in ov.items()}
    if any(len(s) != 1 for s in lengths.values()) \
            or len({s[0] for s in lengths.values()}) != 1:
        raise ValueError(f"swept fields must be 1-D of equal length, "
                         f"got {lengths}")
    return ov


def sweep_batch(traces, sim: SimConfig, **fields) -> dict:
    """Full DSE grid in ONE compiled call: N traces x K parameter values.

    Combines `simulate_batch` and `sweep`: results carry leading [N, K]
    axes (trace-major). fig10's app x gateway-count exploration is a single
    call of this with `max_gateways`/`min_gateways` pinned per grid point.
    """
    batch = stack_traces(traces, pad=True) \
        if isinstance(traces, (list, tuple)) else traces
    ov = _check_sweep_fields(fields)
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(batch)
    return _sweep_batch_jit(ext, mem, intra, ext_frac, t_mask,
                            selection_tables_jax(sim.cfg), ov, dest, sim=sim)


def sweep_faults(trace: dict, sim: SimConfig, frames, **fields) -> dict:
    """K fault scenarios over one trace in a single compiled vmapped scan.

    `frames` is a list of fault frames (each from `faults.compile_faults`
    on the same horizon as the trace) or an already-stacked frame dict with
    a leading [K] axis (`faults.stack_fault_frames`). Optional `**fields`
    grids (SWEEPABLE_FIELDS, each length K) zip lane-for-lane with the
    fault axis, so fault scenarios compose with every runtime-override
    sweep axis. Results carry a leading [K] axis; compilation caches on
    (trace shape, config, K, swept-field set), not on which faults fire.
    """
    if _trace_faults(trace) is not None:
        raise ValueError(
            "sweep_faults() takes the fault grid via `frames`; pass a clean "
            "trace (faults.strip_faults) instead of an attached one")
    from repro.core.faults import stack_fault_frames as _stack
    stacked = _stack(frames) if isinstance(frames, (list, tuple)) else frames
    missing = [k for k in FAULT_KEYS if k not in stacked]
    if missing:
        raise ValueError(f"fault frames are missing keys {missing}")
    flt = tuple(jnp.asarray(stacked[k], jnp.float32) for k in FAULT_KEYS)
    k = int(flt[0].shape[0])
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(trace)
    t = int(jnp.shape(mem)[0])
    if int(flt[0].shape[1]) != t:
        raise ValueError(
            f"fault frames cover {int(flt[0].shape[1])} intervals but the "
            f"trace has {t} — compile them with n_intervals={t}")
    if fields:
        ov = _check_sweep_fields(fields)
        k_ov = next(iter(ov.values())).shape[0]
        if k_ov != k:
            raise ValueError(
                f"swept fields have length {k_ov} but there are {k} fault "
                f"frames — the axes zip lane-for-lane")
    else:
        # An empty override pytree has no mapped leaves; the vmap axis size
        # comes from the fault frame alone.
        ov = {}
    return _sweep_faults_jit(ext, mem, intra, ext_frac, t_mask,
                             selection_tables_jax(sim.cfg), flt, ov, dest,
                             sim=sim)


# ---------------------------------------------------------------------------
# Topology-polymorphic padded sweeps
# ---------------------------------------------------------------------------

def topology_point_config(sim: SimConfig, *, n_chiplets: int = None,
                          gateways_per_chiplet: int = None,
                          mesh_radix: int = None,
                          gateway_positions=None) -> SimConfig:
    """Unpadded SimConfig equivalent to one `sweep_topology` grid point.

    The controller's gateway bounds are clamped to the topology's per-chiplet
    gateway count, matching the padded engine's semantics. Used by parity
    tests and the compile-farm benchmark baseline. `gateway_positions` pins
    the point's placement (None keeps the base config's placement, which a
    `mesh_radix` change resets to the default edge scheme).
    """
    cfg = sim.cfg.with_topology(n_chiplets=n_chiplets,
                                gateways_per_chiplet=gateways_per_chiplet,
                                mesh_radix=mesh_radix)
    if gateway_positions is not None:
        cfg = cfg.with_placement(normalize_placement(gateway_positions))
    g = cfg.max_gateways_per_chiplet
    ctl = dataclasses.replace(
        sim.ctl, max_gateways=min(sim.ctl.max_gateways, g),
        min_gateways=min(sim.ctl.min_gateways, g))
    return dataclasses.replace(sim, cfg=cfg, ctl=ctl)


def _prepare_topology_sweep(sim: SimConfig, grids: dict):
    """Split grids into topology axes + runtime overrides; build the padded
    static config, per-topology traced arrays, and controller clamps.

    Returns (sim_padded, topo, ov, c_max) where `sim_padded.cfg` describes
    the PADDED shapes (grid maxima — the one compiled executable's shape)
    and `topo` holds the per-grid-point actual topology as traced arrays.
    """
    if not grids:
        raise ValueError("sweep_topology() needs at least one field=values "
                         f"pair from {TOPOLOGY_SWEEPABLE_FIELDS}")
    lengths = {k: _grid_len(k, v) for k, v in grids.items()}
    topo_grids = {k: list(v) for k, v in grids.items()
                  if k in TOPOLOGY_SWEEPABLE_FIELDS}
    other = {k: v for k, v in grids.items()
             if k not in TOPOLOGY_SWEEPABLE_FIELDS}
    unknown = set(other) - set(SWEEPABLE_FIELDS)
    if unknown:
        raise ValueError(
            f"non-sweepable fields: {sorted(unknown)} (topology: "
            f"{TOPOLOGY_SWEEPABLE_FIELDS}, runtime: {SWEEPABLE_FIELDS})")
    if not topo_grids:
        raise ValueError("no topology fields swept — use sweep() for "
                         "runtime-only grids")
    if len(set(lengths.values())) != 1:
        raise ValueError(f"swept fields must share one length, "
                         f"got {lengths}")
    k = next(iter(lengths.values()))

    cfg = sim.cfg
    cs = [int(x) for x in topo_grids.get("n_chiplets",
                                         [cfg.n_chiplets] * k)]
    gs = [int(x) for x in topo_grids.get(
        "gateways_per_chiplet", [cfg.max_gateways_per_chiplet] * k)]
    rs = [int(x) for x in topo_grids.get("mesh_radix", [cfg.mesh_x] * k)]
    if "gateway_positions" in topo_grids:
        ps = [normalize_placement(p)
              for p in topo_grids["gateway_positions"]]
    else:
        # with_topology's contract: a mesh_radix change invalidates the
        # base config's explicit placement (its coordinates belong to the
        # old mesh), so such grid points fall back to the default edge
        # scheme — matching topology_point_config and keeping the
        # padded==unpadded parity invariant.
        ps = [normalize_placement(cfg.gateway_positions)
              if r == cfg.mesh_x and r == cfg.mesh_y else None
              for r in rs]
    if min(cs) < 1 or min(gs) < 1 or min(rs) < 2:
        raise ValueError(f"invalid topology grid: n_chiplets {cs}, "
                         f"gateways {gs}, radix {rs}")
    for i, (g, p) in enumerate(zip(gs, ps)):
        avail = N_DEFAULT_EDGE_SLOTS if p is None else len(p)
        if g > avail:
            raise ValueError(
                f"grid point {i}: gateways_per_chiplet={g} exceeds the "
                f"{avail} placed gateway positions "
                f"({'default edge scheme' if p is None else p})")

    cfgs = tuple(dataclasses.replace(
        cfg.with_topology(n_chiplets=c, gateways_per_chiplet=g,
                          mesh_radix=r), gateway_positions=p)
                 for c, g, r, p in zip(cs, gs, rs, ps))
    c_max, g_max, r_max = max(cs), max(gs), max(rs)
    ptab = padded_selection_tables_jax(cfgs, (g_max, r_max * r_max))
    topo = {
        "n_chiplets": jnp.asarray(cs, jnp.int32),
        "g_max": jnp.asarray(gs, jnp.int32),
        "src_hops": ptab["src_hops"],                       # [K, g_max]
        "gw_loss_db": ptab["gw_loss_db"],                   # [K, g_max]
        "mesh_hops": jnp.asarray(
            [uniform_mesh_mean_hops(c) for c in cfgs], jnp.float32),
        "mesh_x": jnp.asarray(rs, jnp.float32),
        "total_gateways": jnp.asarray(
            [c.total_gateways for c in cfgs], jnp.float32),
    }

    # Controller gateway bounds ride the existing runtime-override path,
    # clamped per grid point to the topology's gateway count.
    ov = {f: jnp.asarray(v) for f, v in other.items()}
    user_max = ov.pop("max_gateways", jnp.int32(sim.ctl.max_gateways))
    user_min = ov.pop("min_gateways", jnp.int32(sim.ctl.min_gateways))
    maxg = jnp.minimum(jnp.broadcast_to(jnp.asarray(user_max, jnp.int32),
                                        (k,)), topo["g_max"])
    ming = jnp.minimum(jnp.broadcast_to(jnp.asarray(user_min, jnp.int32),
                                        (k,)), maxg)
    ov["max_gateways"] = maxg
    ov["min_gateways"] = ming

    sim_padded = dataclasses.replace(sim, cfg=dataclasses.replace(
        cfg, n_chiplets=c_max, max_gateways_per_chiplet=g_max,
        mesh_x=r_max, mesh_y=r_max))
    return sim_padded, topo, ov, c_max


def _topo_trace_arrays(trace_or_batch, c_max: int):
    if _trace_faults(trace_or_batch) is not None:
        raise ValueError(
            "fault frames are not supported on the padded-topology paths "
            "(sweep_topology / shard_sweep): fault frames are compiled "
            "against ONE topology's [C, G] slot grid and cannot be "
            "re-padded per grid point. strip_faults(trace) first, or use "
            "simulate / sweep_faults on a fixed topology.")
    ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(trace_or_batch)
    if ext.shape[-1] < c_max:
        raise ValueError(
            f"trace covers {ext.shape[-1]} chiplets but the grid needs "
            f"{c_max}; generate it with cfg.with_topology(n_chiplets="
            f"{c_max}) (see traffic.generate_trace)")
    if dest is not None:
        # Narrow to the padded chiplet axis; per-grid-point masking and row
        # re-normalization happen inside _simulate_impl against chip_mask.
        from repro.core.traffic.transform import _renormalize_rows
        dest = _renormalize_rows(dest[..., :c_max, :c_max])
    return ext[..., :c_max], mem, intra[..., :c_max], ext_frac, t_mask, dest


def sweep_topology(trace: dict, sim: SimConfig, **grids) -> dict:
    """Topology DSE over shape-changing axes in ONE compiled executable.

    ::

        sweep_topology(tr, sim, n_chiplets=[4, 16, 64],
                       gateways_per_chiplet=[4, 4, 2])

    Every topology field (TOPOLOGY_SWEEPABLE_FIELDS) gets a 1-D grid; all
    grids (topology + any runtime SWEEPABLE_FIELDS) share one length K and
    are zipped into K grid points. Instead of compiling one executable per
    topology shape, every per-topology array is padded to the grid maxima
    with a validity mask, and the K masked scans run as a single vmapped,
    jit-cached call (engine_stats() shows one scan-body trace per grid
    *shape*, not per topology).

    Masking invariant: padded chiplet/gateway slots hold zero load, g=0 and
    lambda=0 for the whole scan, so they contribute exactly zero to every
    latency/power/energy reduction — `sweep_topology` at pad==actual size
    matches unpadded `simulate` to float tolerance (tested).

    The trace must cover max(n_chiplets) chiplets; each grid point uses its
    first n_chiplets columns (traffic.slice_trace view). Results carry a
    leading [K] axis; per-chiplet records are padded to the grid maximum.
    Controller gateway bounds are clamped per point to the topology's
    gateway count (see `topology_point_config`).
    """
    sim_p, topo, ov, c_max = _prepare_topology_sweep(sim, grids)
    ext, mem, intra, ext_frac, t_mask, dest = _topo_trace_arrays(trace, c_max)
    return _sweep_topology_jit(ext, mem, intra, ext_frac, t_mask, topo, ov,
                               dest, sim=sim_p)


def sweep_topology_batch(traces, sim: SimConfig, *, devices=None,
                         **grids) -> dict:
    """N traces x K topologies in ONE compiled call ([N, K] results).

    The topology analogue of `sweep_batch`: `traces` is a list of same-shape
    trace dicts or an already-stacked dict from `stack_traces`. Pass
    `devices` (more than one — e.g. the fleet's global device list) to
    shard the K axis via `shard_sweep`.
    """
    if devices is not None and len(list(devices)) > 1:
        return shard_sweep(traces, sim, devices=devices, **grids)
    batch = stack_traces(traces, pad=True) \
        if isinstance(traces, (list, tuple)) else traces
    sim_p, topo, ov, c_max = _prepare_topology_sweep(sim, grids)
    ext, mem, intra, ext_frac, t_mask, dest = _topo_trace_arrays(batch, c_max)
    return _sweep_topology_batch_jit(ext, mem, intra, ext_frac, t_mask,
                                     topo, ov, dest, sim=sim_p)


def _sharding_note(out: dict, describe: dict) -> dict:
    """Attach sharding metadata to a sweep result (no silent pads): the
    pad-lane count lands in the returned summary and the full placement
    description under a top-level "sharding" key."""
    out = dict(out)
    if "summary" in out and isinstance(out["summary"], dict):
        out["summary"] = dict(out["summary"],
                              pad_lanes=int(describe["pad_lanes"]))
    out["sharding"] = dict(describe)
    return out


def shard_sweep(traces, sim: SimConfig, *, devices=None, **grids) -> dict:
    """Multi-device / multi-host topology sweep: the [N x K] grid sharded.

    The K (topology) axis of the padded grid is placed with a 1-D
    `NamedSharding` over the fleet's "grid" mesh axis, so the SAME compiled
    executable partitions the vmapped scans across all available devices
    (GSPMD); N-trace batches replicate the trace and shard the topology
    axis. After `repro.core.distributed.init_distributed` the default
    device list spans every fleet process and the same placement shards
    across hosts (trace arrays are then replicated fleet-wide and results
    all-gathered, so every process returns the full grid). K is padded to
    a multiple of the device count by repeating the last grid point —
    logged, sliced off the results, and reported as `summary["pad_lanes"]`
    plus a top-level `"sharding"` dict (no silent caps). Degrades
    gracefully to the single-device `sweep_topology` path when one device
    is present or sharding fails.

    Accepts a single trace dict or a list/stacked batch (leading [N] axis
    in the results, as `sweep_topology_batch`).
    """
    from repro.core.distributed import GridSharding

    batched = not (isinstance(traces, dict)
                   and jnp.ndim(traces["ext_load"]) == 2)
    single_call = sweep_topology_batch if batched else sweep_topology
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) <= 1:
        out = single_call(traces, sim, **grids)
        return _sharding_note(out, {
            "grid_points": int(np.asarray(
                out["summary"]["mean_latency"]).shape[-1]),
            "pad_lanes": 0, "devices": 1, "processes": 1})

    try:
        sim_p, topo, ov, c_max = _prepare_topology_sweep(sim, grids)
        batch = stack_traces(traces, pad=True) \
            if isinstance(traces, (list, tuple)) else traces
        ext, mem, intra, ext_frac, t_mask, dest = _topo_trace_arrays(
            batch, c_max)

        k = int(topo["n_chiplets"].shape[0])
        gs = GridSharding(k, devices=devices)
        topo = gs.shard(topo)
        ov = gs.shard(ov)
        ext, mem, intra, ext_frac, t_mask, dest = gs.replicate(
            (ext, mem, intra, ext_frac, t_mask, dest))
        fn = _sweep_topology_batch_jit if batched else _sweep_topology_jit
        out = fn(ext, mem, intra, ext_frac, t_mask, topo, ov, dest,
                 sim=sim_p)
        out = gs.gather(out, axis=1 if batched else 0)
        return _sharding_note(out, gs.describe())
    except Exception as e:  # pragma: no cover - depends on device layout
        import warnings
        warnings.warn(f"sharded sweep failed ({e!r}); falling back to "
                      f"single-device path")
        out = single_call(traces, sim, **grids)
        return _sharding_note(out, {
            "grid_points": int(np.asarray(
                out["summary"]["mean_latency"]).shape[-1]),
            "pad_lanes": 0, "devices": 1, "processes": 1})


# ---------------------------------------------------------------------------
# Workload-polymorphic sweeps + streaming sessions
# ---------------------------------------------------------------------------

def sweep_workload(specs, sim: SimConfig, *, seed: int = 0, keys=None,
                   dest: bool = False, devices=None, gen_chiplets=None,
                   **grids) -> dict:
    """Workload DSE: K traffic specs, ONE compiled executable.

    ::

        sweep_workload([traffic.ParsecSpec("dedup", n_intervals=64),
                        traffic.UniformSpec(n_intervals=32),
                        traffic.BurstySpec(n_intervals=48)], sim)

    Each spec (`traffic.TrafficSpec`, or a PARSEC app name) is generated
    under jit from `seed` (or an explicit [K]-row `keys` array) and the K
    traces — mixed lengths welcome — are padded to the longest T under a
    `t_mask` and run as a single vmapped scan. Results carry a leading [K]
    axis; lane k matches unpadded ``simulate(traffic.generate(specs[k],
    ...), sim)`` (tested per-arch at 1e-6).

    Workload zips with the other sweep axes: any TOPOLOGY_SWEEPABLE_FIELDS
    (n_chiplets / mesh_radix / gateway_positions / ...) or SWEEPABLE_FIELDS
    grids of length K pair element-wise with the specs, so "workload i on
    topology i with runtime knobs i" is still one compiled call.

    `dest=True` attaches each spec's destination matrix to its generated
    trace (`traffic.generate(..., dest=True)`), so every lane resolves
    actual source->destination gateway pressure — this is what separates
    transpose/tornado from uniform at the same mean load.

    `devices` (more than one — e.g. the fleet's global device list after
    `distributed.init_distributed`) shards the K workload axis with a 1-D
    NamedSharding: every lane-leading array (generated traces, topology
    grids, overrides, destination matrices) partitions over the "grid"
    mesh axis, K padded to a device multiple by repeating the last lane
    (logged; reported as `summary["pad_lanes"]` + a `"sharding"` dict and
    sliced off the results). Falls back to the unsharded call on failure.

    `gen_chiplets` pins the chiplet count traces are generated at (default:
    the largest `n_chiplets` in the grid). An emulated-host worker running
    a slice of a bigger grid passes the FULL grid's maximum here (plus the
    full run's sliced `keys`), so its lanes reproduce the full run's rows
    bit-for-bit even when its slice misses the global maximum.
    """
    specs = [traffic.as_spec(s) for s in specs]
    if not specs:
        raise ValueError("sweep_workload() needs at least one traffic spec")
    k = len(specs)
    if keys is None:
        keys = jax.random.split(jax.random.PRNGKey(seed), k)
    elif len(keys) != k:
        raise ValueError(f"{len(keys)} keys for {k} specs")
    for name, v in grids.items():
        n = _grid_len(name, v)
        if n != k:
            raise ValueError(
                f"grid {name!r} has length {n} but {k} workload specs "
                f"were given — workload zips element-wise with every grid")

    devices = list(devices) if devices is not None else None
    topo_grids = {g: v for g, v in grids.items()
                  if g in TOPOLOGY_SWEEPABLE_FIELDS}
    if topo_grids:
        c_gen = max(int(c) for c in topo_grids.get(
            "n_chiplets", [sim.cfg.n_chiplets]))
        if gen_chiplets is not None:
            if int(gen_chiplets) < c_gen:
                raise ValueError(
                    f"gen_chiplets={gen_chiplets} is smaller than the "
                    f"grid's largest n_chiplets ({c_gen})")
            c_gen = int(gen_chiplets)
        gen_cfg = sim.cfg.with_topology(n_chiplets=c_gen)
        traces = [traffic.generate(s, ky, gen_cfg, dest=dest)
                  for s, ky in zip(specs, keys)]
        batch = stack_traces(traces, pad=True)
        sim_p, topo, ov, c_max = _prepare_topology_sweep(sim, grids)
        ext, mem, intra, ext_frac, t_mask, dmat = _topo_trace_arrays(
            batch, c_max)
        if devices is not None and len(devices) > 1:
            out = _shard_workload(
                (ext, mem, intra, ext_frac, t_mask, topo, ov, dmat),
                devices, lambda a, _: _sweep_workload_topo_jit(*a, sim=sim_p))
            if out is not None:
                return out
        return _sweep_workload_topo_jit(ext, mem, intra, ext_frac, t_mask,
                                        topo, ov, dmat, sim=sim_p)

    unknown = set(grids) - set(SWEEPABLE_FIELDS)
    if unknown:
        raise ValueError(
            f"non-sweepable fields: {sorted(unknown)} (topology: "
            f"{TOPOLOGY_SWEEPABLE_FIELDS}, runtime: {SWEEPABLE_FIELDS})")
    ov = {g: jnp.asarray(v) for g, v in grids.items()}
    traces = [traffic.generate(s, ky, sim.cfg, dest=dest)
              for s, ky in zip(specs, keys)]
    batch = stack_traces(traces, pad=True)
    ext, mem, intra, ext_frac, t_mask, dmat = _trace_arrays(batch)
    tables = selection_tables_jax(sim.cfg)
    if devices is not None and len(devices) > 1:
        out = _shard_workload(
            (ext, mem, intra, ext_frac, t_mask, ov, dmat), devices,
            lambda a, rep: _sweep_workload_jit(
                a[0], a[1], a[2], a[3], a[4], rep[0], a[5], a[6], sim=sim),
            replicated=(tables,))
        if out is not None:
            return out
    return _sweep_workload_jit(ext, mem, intra, ext_frac, t_mask,
                               tables, ov, dmat, sim=sim)


def _shard_workload(args, devices, call, replicated=()):
    """Shard every lane-leading array of a workload sweep over `devices`.

    `args` is a tuple of leading-K pytrees (None leaves welcome);
    `replicated` holds fleet-global extras (e.g. selection tables).
    `call(sharded_args, replicated_extras)` launches the jitted entry
    point. Returns the gathered result dict with sharding metadata, or
    None to signal fallback to the unsharded path.
    """
    from repro.core.distributed import GridSharding

    try:
        k = int(args[0].shape[0])
        gs = GridSharding(k, devices=devices)
        out = call(gs.shard(args), gs.replicate(replicated))
        out = gs.gather(out)
        return _sharding_note(out, gs.describe())
    except Exception as e:  # pragma: no cover - depends on device layout
        import warnings
        warnings.warn(f"sharded workload sweep failed ({e!r}); falling "
                      f"back to the unsharded path")
        return None


class SimSession:
    """Streaming simulation session: unbounded traces at fixed memory.

    ::

        session = SimSession.init(sim)
        for chunk in online_trace_chunks:        # each a trace dict
            out = session.step_chunk(chunk)      # records + chunk summary
        total = session.summary()                # whole-stream summary

    The controller / PROWAVES / activity state persists across chunks (the
    carry is donated to the chunked executable, so steady-state streaming
    reuses its buffers in place), which makes a chunked run equivalent to
    one-shot `simulate` on the concatenated trace: per-interval records
    bit-match, and the running summary matches up to float re-association
    of the partial sums. Chunks of equal length share one compiled
    executable; `engine_stats()` shows one scan-body trace per chunk
    shape.
    """

    def __init__(self, sim: SimConfig, state: SimState, tables: dict):
        self.sim = sim
        self._state = state
        self._tables = tables
        self._sums = None
        self.placement = normalize_placement(
            resolve_gateway_positions(sim.cfg), sim.cfg)

    @classmethod
    def init(cls, sim: SimConfig) -> "SimSession":
        """Open a session with a fresh simulation state for `sim`."""
        return cls(sim, _initial_state(sim), selection_tables_jax(sim.cfg))

    def swap_placement(self, positions) -> None:
        """Live gateway re-placement between chunks (zero recompile).

        Placement reaches the executable only through the traced selection
        tables, so swapping in tables for a new placement reuses every
        cached chunk executable — this is what makes closed-loop recovery
        (serve.resilience.ResilienceRuntime) cheap at run time. The caller
        is responsible for charging the physical cost
        (faults.placement_reconfig_cost); the carried controller/NoC state
        streams on uninterrupted, modeling an in-flight reconfiguration.
        """
        p = normalize_placement(positions, self.sim.cfg)
        self._tables = selection_tables_jax(
            self.sim.cfg.with_placement(p))
        self.placement = p

    @property
    def intervals_seen(self) -> int:
        """Valid (unmasked) intervals consumed so far."""
        return 0 if self._sums is None \
            else int(self._sums["valid_intervals"])

    def step_chunk(self, chunk: dict) -> dict:
        """Consume one trace chunk; returns its records + chunk summary.

        `chunk` is an ordinary (unbatched) trace dict — `traffic.pad_trace`
        output with a `t_mask` is fine, e.g. a partial chunk padded to the
        session's steady chunk length so it reuses the same executable.
        Masked intervals freeze the carry (the controller never reacts to
        padded idle epochs), so padding mid-stream is exact too.
        """
        ext, mem, intra, ext_frac, t_mask, dest = _trace_arrays(chunk)
        if ext.ndim != 2:
            raise ValueError(
                f"step_chunk takes one unbatched trace chunk "
                f"(ext_load [T, C]), got ext_load {ext.shape}")
        flt = _trace_faults(chunk)
        if flt is not None:
            self._state, recs, sums = _session_chunk_faults_jit(
                self._state, ext, mem, intra, ext_frac, t_mask,
                self._tables, flt, dest, sim=self.sim)
        else:
            self._state, recs, sums = _session_chunk_jit(
                self._state, ext, mem, intra, ext_frac, t_mask,
                self._tables, dest, sim=self.sim)
        self._sums = sums if self._sums is None else jax.tree.map(
            lambda a, b: a + b, self._sums, sums)
        return {"records": recs,
                "summary": _summary_from_sums(sums, self.sim.cfg.n_chiplets)}

    def summary(self) -> dict:
        """Running summary over every interval streamed so far."""
        if self._sums is None:
            raise ValueError("summary() before any step_chunk() — the "
                             "session has consumed no intervals yet")
        return _summary_from_sums(self._sums, self.sim.cfg.n_chiplets)


def simulate_stream(chunks, sim: SimConfig) -> dict:
    """Drive a fresh `SimSession` over an iterable of trace chunks.

    Convenience wrapper for offline chunked runs: returns the final
    whole-stream summary plus the session (for further streaming).
    """
    session = SimSession.init(sim)
    n = 0
    for chunk in chunks:
        session.step_chunk(chunk)
        n += 1
    if n == 0:
        raise ValueError("simulate_stream() got an empty chunk iterable")
    return {"summary": session.summary(), "chunks": n, "session": session}


# ---------------------------------------------------------------------------
# Continuous-batching session packing (repro.serve.engine.SessionServer)
# ---------------------------------------------------------------------------

def init_session_states(sim: SimConfig, lanes: int) -> SimState:
    """Batched fresh session carries: a SimState pytree with leading [lanes].

    Every lane starts from the same `_initial_state` a standalone
    `SimSession.init` would hold, so lane k of the batched tick replays a
    standalone session exactly (the server resets a lane to row k of a
    fresh batch on every admission).
    """
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    one = _initial_state(sim)
    return jax.tree.map(lambda a: jnp.stack([a] * lanes), one)


def session_tick(states: SimState, batch: dict, tables: dict,
                 sim: SimConfig, frame=None):
    """Advance B packed session lanes one chunk: ONE vmapped executable.

    `batch` is a lane-stacked chunk dict: ext_load [B, T, C], mem_load
    [B, T], int_load [B, T, C], ext_frac [B], t_mask [B, T]. Lane k steps
    exactly like `SimSession.step_chunk` on the same chunk (bit-parity
    pinned by tests/test_serve.py); an all-masked lane freezes its carry
    and contributes zero to every sum, so the server can park empty,
    retrying, or draining lanes without changing the executable's shape.

    `frame` (optional) is ONE fault frame (gw_ok [T, C, G] / stuck_on
    [T, C, G] / drift_db [T]) shared by every lane — faults live on
    hardware time, not session time — routed to the fault twin so clean
    ticks keep their own executable and exact numerics.

    An optional `batch["dest"]` [B, C, C] (per-lane destination matrices,
    e.g. from `stack_traces` over `generate(..., dest=True)` chunks) routes
    every lane through the destination-aware latency path; absent, the
    tick bit-matches the pre-dest executable.

    Returns (new_states, records, sums), each with a leading [B] axis.
    The carry is NOT donated: the caller may keep the previous states
    pytree to roll back lanes whose step failed (retry path).
    """
    ext = jnp.asarray(batch["ext_load"])
    mem = jnp.asarray(batch["mem_load"])
    intra = jnp.asarray(batch["int_load"])
    ext_frac = jnp.asarray(batch["ext_frac"])
    t_mask = jnp.asarray(batch["t_mask"], jnp.float32)
    dest = batch.get("dest")
    dest = None if dest is None else jnp.asarray(dest, jnp.float32)
    if ext.ndim != 3 or mem.ndim != 2 or t_mask.ndim != 2:
        raise ValueError(
            f"session_tick takes lane-stacked chunks (ext_load [B, T, C], "
            f"mem_load [B, T], t_mask [B, T]); got ext_load {ext.shape}, "
            f"mem_load {mem.shape}, t_mask {t_mask.shape}")
    if frame is None:
        return _session_tick_jit(states, ext, mem, intra, ext_frac, t_mask,
                                 tables, dest, sim=sim)
    missing = [k for k in FAULT_KEYS if k not in frame]
    if missing:
        raise ValueError(f"fault frame is missing {missing} "
                         f"(build it with faults.compile_faults/no_faults)")
    flt = tuple(jnp.asarray(frame[k], jnp.float32) for k in FAULT_KEYS)
    if int(flt[0].shape[0]) != int(mem.shape[1]):
        raise ValueError(
            f"fault frame covers {int(flt[0].shape[0])} intervals but the "
            f"tick chunk has {int(mem.shape[1])} — compile the frame at "
            f"the server's chunk length")
    return _session_tick_faults_jit(states, ext, mem, intra, ext_frac,
                                    t_mask, tables, flt, dest, sim=sim)


def session_sums_zero() -> dict:
    """The additive identity of `_record_sums` totals (a session that has
    served nothing yet): partial summaries of never-served sessions come
    out well-formed instead of raising."""
    return {k: jnp.float32(0.0)
            for k in ("latency", "power_mw", "energy", "gateways",
                      "wavelengths", "saturated", "reconfig_nj",
                      "valid_intervals")}


def summary_from_sums(sums: dict, n_chiplets: int) -> dict:
    """Public summary reduction over accumulated `_record_sums` totals —
    the valid-intervals-only means every session summary (complete OR
    partial) is computed from."""
    return _summary_from_sums(sums, n_chiplets)


# ---------------------------------------------------------------------------
# Placement-polymorphic sweeps + compiled placement search (PlaceIT-style)
# ---------------------------------------------------------------------------

def sweep_placement(trace: dict, sim: SimConfig, placements, **grids) -> dict:
    """Gateway-placement DSE: K candidate placements, ONE compiled scan.

    ::

        sweep_placement(tr, sim, [None,                      # default edges
                                  ((1, 1), (2, 2), (1, 2), (2, 1)),
                                  ((0, 0), (3, 3), (0, 3), (3, 0))])

    Each placement is a tuple of (x, y) router coordinates in activation
    order (None = the default edge scheme). Placement data reaches the
    executable purely through traced per-point tables (hop means + access-
    waveguide loss), so the K placements share one jit cache entry per
    (shape, config, K) — re-sweeping different candidates of the same
    population size re-traces nothing, which is what makes the generation
    loop of `search_placement` compile-free after round one.

    Composes with the other sweep axes: any TOPOLOGY_SWEEPABLE_FIELDS /
    SWEEPABLE_FIELDS grids of the same length K zip in (`sweep_placement`
    is sugar for ``sweep_topology(..., gateway_positions=placements)``).
    Lane k matches unpadded `simulate` with
    ``NetworkConfig(gateway_positions=placements[k])`` (tested per-arch).
    """
    return sweep_topology(trace, sim, gateway_positions=list(placements),
                          **grids)


def sweep_placement_batch(traces, sim: SimConfig, placements,
                          **grids) -> dict:
    """N traces x K placements in ONE compiled call ([N, K] results)."""
    return sweep_topology_batch(traces, sim,
                                gateway_positions=list(placements), **grids)


def _placement_scores(summary: dict, inter_latency: np.ndarray,
                      objective: str) -> np.ndarray:
    """Per-lane scalar objective from device_get'd sweep results ([K])."""
    check_placement_objective(objective)
    if objective == "inter_latency":
        # Per-interval traffic-weighted inter-chiplet latency, [K, T] -> [K].
        return np.mean(inter_latency, axis=-1)
    return np.asarray(
        summary[PLACEMENT_OBJECTIVE_ALIASES.get(objective, objective)])


def search_placement(trace: dict, sim: SimConfig, *,
                     objective: str = "inter_latency",
                     generations: int = 10, population: int = 12,
                     seed: int = 0, init=None, temperature: float = 0.05,
                     cooling: float = 0.7, restart_frac: float = 0.25,
                     engine: str = "device",
                     blocked_positions=None) -> dict:
    """PlaceIT-style annealed gateway-placement search.

    Greedy/simulated-annealing hybrid: candidate placements (single-gateway
    moves around the incumbent, spread-reordered by the controller
    activation rule, plus random restarts) are scored per generation at
    fixed population size, with annealed acceptance of the incumbent and an
    elitist best over everything ever scored. The default edge scheme is
    always scored in generation 0, so `best_score <= default_score` when
    `init` is None.

    Two engines share these semantics:

      * `engine="device"` (default) — the whole search is ONE compiled
        `lax.scan` (repro.core.search.search_placement_device): proposals,
        traceable placement tables, scoring, acceptance and history all
        stay on device; a search is a single dispatch with zero host
        round-trips between generations (`engine_stats()` shows one
        scan-body trace and one `search_dispatches`). For parallel chains
        see `search_placement_islands`.
      * `engine="host"` — the PR-3 loop, retained as the parity oracle:
        numpy proposals, one `sweep_placement` call per generation (still
        one compiled executable across the search), and ONE
        `jax.device_get` of the summary pytree per generation.

    Both engines are deterministic per seed; their PRNG streams differ
    (jax.random vs numpy RandomState), so they explore different — equally
    valid — trajectories from the same seed.

    Returns {best_placement, best_score, best_summary, default_placement,
    default_score, improvement_frac, history} with one history entry per
    generation (the latency/power/energy trajectory of the search).

    `blocked_positions` excludes router coordinates (e.g. failed hardware
    reported by faults.FaultInjector) from the whole proposal space —
    restarts, mutations and the scored default all avoid them. An `init`
    that occupies a blocked router raises: repair it first
    (search.repair_placement).
    """
    if engine == "device":
        from repro.core.search import search_placement_device

        return search_placement_device(
            trace, sim, objective=objective, generations=generations,
            population=population, seed=seed, init=init,
            temperature=temperature, cooling=cooling,
            restart_frac=restart_frac, blocked_positions=blocked_positions)
    if engine != "host":
        raise ValueError(f"unknown engine {engine!r} (use 'device' or "
                         f"'host')")
    if population < 2:
        raise ValueError("population must be >= 2 (incumbent + candidates)")
    if generations < 1:
        raise ValueError("generations must be >= 1")
    cfg = sim.cfg
    gmax = cfg.max_gateways_per_chiplet
    blocked = {(int(x), int(y)) for (x, y) in (blocked_positions or ())}
    from repro.core import topology as _topology
    coords = [(int(x), int(y)) for x, y in _topology.router_coords(cfg)
              if (int(x), int(y)) not in blocked]
    if len(coords) < gmax:
        raise ValueError(
            f"{len(blocked)} blocked routers leave only {len(coords)} "
            f"allowed positions for {gmax} gateways")
    rng = np.random.RandomState(seed)

    default_p = normalize_placement(resolve_gateway_positions(cfg), cfg)
    if set(default_p) & blocked:
        # Can't score a default that sits on dead hardware; fall back to a
        # repaired variant of it as the reference lane.
        from repro.core.search import repair_placement
        default_p = repair_placement(default_p, blocked, cfg)
    parent = default_p if init is None \
        else normalize_placement(init, cfg)
    if set(parent) & blocked:
        raise ValueError(
            f"init placement occupies blocked routers "
            f"{sorted(set(parent) & blocked)} — repair it first "
            f"(search.repair_placement)")

    def random_placement():
        idx = rng.choice(len(coords), size=gmax, replace=False)
        return normalize_placement([coords[i] for i in idx], cfg,
                                   order="spread")

    def mutate(p, moves):
        pos = list(p)
        occupied = set(pos)
        for _ in range(moves):
            i = int(rng.randint(len(pos)))
            free = [c for c in coords if c not in occupied]
            if not free:
                break
            occupied.remove(pos[i])
            pos[i] = free[int(rng.randint(len(free)))]
            occupied.add(pos[i])
        return normalize_placement(pos, cfg, order="spread")

    best_p, best_s, best_summary = None, np.inf, None
    default_s = None
    temp = temperature
    history = []
    for gen in range(generations):
        moves = 2 if gen < max(1, generations // 3) else 1
        cands = [parent]
        if gen == 0 and parent != default_p:
            cands.append(default_p)
        while len(cands) < population:
            cands.append(random_placement()
                         if rng.rand() < restart_frac else
                         mutate(parent, moves))
        out = sweep_placement(trace, sim, cands)
        # ONE device->host sync for everything this generation consumes
        # (scores, lane summary, history values) — per-key np.asarray calls
        # here used to cost several round-trips per generation.
        pulled = jax.device_get(
            {"summary": out["summary"],
             "inter_latency": out["records"]["mean_inter_latency"]})
        scores = _placement_scores(pulled["summary"],
                                   pulled["inter_latency"], objective)
        if gen == 0:
            default_s = float(scores[cands.index(default_p)]
                              if default_p in cands else scores[0])
        ibest = int(np.argmin(scores))
        if scores[ibest] < best_s:
            best_p, best_s = cands[ibest], float(scores[ibest])
            best_summary = {k: float(v[ibest])
                            for k, v in pulled["summary"].items()}
        # Annealed incumbent move: greedy downhill, probabilistic uphill.
        delta = float(scores[ibest] - scores[0])
        rel = delta / max(abs(float(scores[0])), 1e-12)
        accepted = delta < 0 or (temp > 0
                                 and rng.rand() < math.exp(-rel / temp))
        if accepted:
            parent = cands[ibest]
        history.append({
            "generation": gen,
            "parent_score": float(scores[0]),
            "best_candidate_score": float(scores[ibest]),
            "best_score": float(best_s),
            "accepted": bool(accepted),
            "latency": float(pulled["summary"]["mean_latency"][ibest]),
            "power_mw": float(pulled["summary"]["mean_power_mw"][ibest]),
            "energy": float(pulled["summary"]["mean_energy"][ibest]),
        })
        temp *= cooling

    return {"best_placement": best_p, "best_score": best_s,
            "best_summary": best_summary,
            "default_placement": default_p, "default_score": default_s,
            "improvement_frac": 1.0 - best_s / max(default_s, 1e-12),
            "objective": objective, "generations": generations,
            "population": population, "engine": "host", "history": history}


def simulate_all_archs(trace: dict, base: SimConfig = SimConfig()) -> dict:
    out = {}
    for arch in Arch:
        out[arch.value] = simulate(trace, base.with_arch(arch))["summary"]
    return out


def __getattr__(name):
    # Lazy re-export: repro.core.search imports this module, so a top-level
    # import here would be circular. Resolved on first attribute access.
    if name in ("search_placement_device", "search_placement_islands"):
        from repro.core import search as _search
        return getattr(_search, name)
    if name in ("search_codesign", "rescore_front_host"):
        from repro.core import pareto as _pareto
        return getattr(_pareto, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
