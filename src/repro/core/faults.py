"""Fault injection for the 2.5D interposer network (robustness PR).

ReSiPI's headline claim is *run-time* reconfiguration, which only matters
if the network can react to the things that go wrong at run time: gateway
hardware dying, interposer links flapping, PCM cells sticking, and the slow
optical loss drift that the multi-terabit on-interposer pathway analyses
flag as the device-level scaling limiter. This module makes those events
first-class, with the same engine discipline as every other DSE axis:

  * `FaultSpec` hierarchy — frozen/hashable dataclasses describing WHAT
    fails (`GatewayFault` hard failures, `LinkFlap` Markov up/down link
    state, `PcmStuckCell` stuck-off/stuck-on cells, `LossDrift` slow
    dB-per-interval laser-budget erosion). Specs target either a gateway
    *slot* (activation-order index) or a physical router *position* —
    positions model broken hardware at a mesh coordinate, and resolve
    against whatever placement the config currently carries.
  * `compile_faults(specs, cfg, n_intervals)` — specs compile into a
    concrete time-varying fault *frame*: dense arrays over the whole
    horizon (`FAULT_KEYS`) that ride inside the trace dict, so the
    existing transforms (`pad_trace` / `chunk_trace` / `concat_traces`)
    align fault events to chunk boundaries for free, and the engine
    threads them through the masked scan as ordinary traced xs — fault
    grids vmap/zip with every existing sweep axis and one executable per
    (shape, config) serves every fault pattern.
  * The masking invariant extends to faults: a failed gateway lane is
    provably dead — zero laser/ring power, zero capacity, zero reconfig
    energy — exactly like a padded slot, and a frame that never fires
    inside the simulated window is bit-for-bit the fault-free run
    (pinned per-arch in tests/test_faults.py).
  * `FaultInjector` — the closed-loop environment: holds physical fault
    specs, emits per-chunk frames compiled against the *current* placement
    (re-placing gateways off dead routers really heals the network), and
    plays the hardware status register (`failed_positions`) that
    `repro.serve.resilience.ResilienceRuntime` reads to mask dead routers
    out of the placement-search proposal space.
  * `placement_reconfig_cost` — the PCM switching latency/energy bill for
    a live re-placement (every moved gateway re-programs its PCM cells).

Fault frame semantics (all float32):

  gw_ok    [T, C, G]  1 = the slot's hardware is usable this interval.
                      A 0 slot is dead: it carries no traffic, draws no
                      power, and its chiplet's capacity drops to the
                      surviving active slots.
  stuck_on [T, C, G]  1 = the slot's PCM cells are stuck in the coupling
                      state: the lane burns laser/ring power even when the
                      controller wants it dark (power-only — a stuck-on
                      lane that is also failed stays dead).
  drift_db [T]        extra optical loss added to the placement's access
                      loss — the laser power manager scales every source
                      up to compensate, so drift shows up as power.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.constants import NETWORK, PHOTONIC_POWER, NetworkConfig
from repro.core.selection import normalize_placement, resolve_gateway_positions

# The reserved trace-dict keys a fault frame occupies (see module docstring
# for shapes/semantics). Kept disjoint from traffic.TRACE_KEYS.
FAULT_KEYS = ("gw_ok", "stuck_on", "drift_db")


# ---------------------------------------------------------------------------
# Spec hierarchy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Base class: a time-windowed fault. Frozen/hashable like TrafficSpec.

    `start`/`end` are reconfiguration-interval indices ([start, end), end
    None = open-ended). Subclasses add the WHAT; `compile_faults` turns a
    list of specs into the dense fault frame.
    """
    start: int = 0
    end: Optional[int] = None

    def __post_init__(self):
        if self.start < 0:
            raise ValueError(f"{type(self).__name__}.start must be >= 0, "
                             f"got {self.start}")
        if self.end is not None and self.end < self.start:
            raise ValueError(f"{type(self).__name__}: end {self.end} < "
                             f"start {self.start}")

    def _window(self, n_intervals: int) -> np.ndarray:
        t = np.arange(n_intervals)
        hi = n_intervals if self.end is None else self.end
        return (t >= self.start) & (t < hi)


def _resolve_slot(spec, cfg: NetworkConfig) -> Optional[int]:
    """Slot index a spec targets under `cfg`'s placement, or None.

    Position-targeted specs model broken hardware at a router coordinate:
    if the current placement puts no gateway there, the broken router is
    simply unused and the spec compiles to a no-op — which is exactly how
    re-placing gateways off dead routers heals the network.
    """
    if spec.position is not None:
        placement = normalize_placement(resolve_gateway_positions(cfg), cfg)
        target = (int(spec.position[0]), int(spec.position[1]))
        for s, p in enumerate(placement):
            if p == target:
                return s
        return None
    if not 0 <= spec.slot < cfg.max_gateways_per_chiplet:
        raise ValueError(
            f"{type(spec).__name__}.slot {spec.slot} out of range for "
            f"max_gateways_per_chiplet={cfg.max_gateways_per_chiplet}")
    return spec.slot


def _check_chiplet(spec, cfg: NetworkConfig) -> None:
    if not 0 <= spec.chiplet < cfg.n_chiplets:
        raise ValueError(f"{type(spec).__name__}.chiplet {spec.chiplet} out "
                         f"of range for n_chiplets={cfg.n_chiplets}")


@dataclasses.dataclass(frozen=True)
class GatewayFault(FaultSpec):
    """Hard gateway failure: the slot (or the gateway at `position`) is
    dead for the whole window — no traffic, no power, no capacity."""
    chiplet: int = 0
    slot: int = 0
    position: Optional[Tuple[int, int]] = None

    def apply(self, frame: dict, cfg: NetworkConfig, rng) -> None:
        _check_chiplet(self, cfg)
        s = _resolve_slot(self, cfg)
        if s is None:
            return
        w = self._window(frame["gw_ok"].shape[0])
        frame["gw_ok"][w, self.chiplet, s] = 0.0


@dataclasses.dataclass(frozen=True)
class LinkFlap(FaultSpec):
    """Transient interposer-link flaps: a 2-state Markov chain (up/down)
    over intervals. While down, every gateway slot of the chiplet is
    unusable (the chiplet's access waveguide is the shared cut).

    p_down: P(up -> down) per interval; p_up: P(down -> up). The chain is
    drawn at compile time from the frame's seed, so a fault grid is
    reproducible and fully traced once compiled.
    """
    chiplet: int = 0
    p_down: float = 0.05
    p_up: float = 0.5

    def __post_init__(self):
        super().__post_init__()
        for name in ("p_down", "p_up"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"LinkFlap.{name} must be in [0, 1], "
                                 f"got {v}")

    def apply(self, frame: dict, cfg: NetworkConfig, rng) -> None:
        _check_chiplet(self, cfg)
        t = frame["gw_ok"].shape[0]
        w = self._window(t)
        up = True
        for i in range(t):
            if w[i]:
                if up and rng.rand() < self.p_down:
                    up = False
                elif not up and rng.rand() < self.p_up:
                    up = True
                if not up:
                    frame["gw_ok"][i, self.chiplet, :] = 0.0
            else:
                up = True     # the link is healthy outside the window


@dataclasses.dataclass(frozen=True)
class PcmStuckCell(FaultSpec):
    """PCM cell stuck in one crystallization state from `start` on.

    mode="off": the cell cannot couple — the lane is dead (same effect as
    a hard gateway failure). mode="on": the cell cannot decouple — the
    lane burns power even when the controller gates it (power-only; it
    still carries traffic whenever the controller wants it active).
    """
    chiplet: int = 0
    slot: int = 0
    position: Optional[Tuple[int, int]] = None
    mode: str = "off"

    def __post_init__(self):
        super().__post_init__()
        if self.mode not in ("off", "on"):
            raise ValueError(f"PcmStuckCell.mode must be 'off' or 'on', "
                             f"got {self.mode!r}")

    def apply(self, frame: dict, cfg: NetworkConfig, rng) -> None:
        _check_chiplet(self, cfg)
        s = _resolve_slot(self, cfg)
        if s is None:
            return
        w = self._window(frame["gw_ok"].shape[0])
        if self.mode == "off":
            frame["gw_ok"][w, self.chiplet, s] = 0.0
        else:
            frame["stuck_on"][w, self.chiplet, s] = 1.0


@dataclasses.dataclass(frozen=True)
class LossDrift(FaultSpec):
    """Slow optical loss drift: `db_per_interval` extra dB accumulates per
    interval from `start`, clamped at `max_db` (laser aging / coupling
    drift — the device-level limiter in the on-interposer pathway
    analyses). The laser manager compensates, so drift costs power."""
    db_per_interval: float = 0.01
    max_db: float = 3.0

    def __post_init__(self):
        super().__post_init__()
        if self.db_per_interval < 0 or self.max_db < 0:
            raise ValueError("LossDrift rates must be >= 0, got "
                             f"{self.db_per_interval}/{self.max_db}")

    def apply(self, frame: dict, cfg: NetworkConfig, rng) -> None:
        t = frame["drift_db"].shape[0]
        w = self._window(t)
        ramp = np.clip((np.arange(t) - self.start + 1)
                       * self.db_per_interval, 0.0, self.max_db)
        frame["drift_db"][w] += ramp[w]


# ---------------------------------------------------------------------------
# Compilation: specs -> dense time-varying frame
# ---------------------------------------------------------------------------

def no_faults(cfg: NetworkConfig, n_intervals: int) -> Dict[str, np.ndarray]:
    """The all-healthy frame (every slot usable, zero drift)."""
    c, g = cfg.n_chiplets, cfg.max_gateways_per_chiplet
    return {"gw_ok": np.ones((n_intervals, c, g), np.float32),
            "stuck_on": np.zeros((n_intervals, c, g), np.float32),
            "drift_db": np.zeros((n_intervals,), np.float32)}


def compile_faults(specs: Sequence[FaultSpec], cfg: NetworkConfig = NETWORK,
                   n_intervals: int = 64, *, seed: int = 0
                   ) -> Dict[str, np.ndarray]:
    """Compile a list of FaultSpecs into one dense fault frame.

    Specs compose: `gw_ok` ANDs (any spec can kill a slot), `stuck_on` ORs,
    `drift_db` sums. Stochastic specs (LinkFlap) draw from `seed`
    deterministically, independent of list order (one sub-stream per spec
    index). The frame is plain numpy — attach it to a trace with
    `attach_faults` and it becomes traced engine input.
    """
    frame = no_faults(cfg, n_intervals)
    for i, spec in enumerate(specs):
        if not isinstance(spec, FaultSpec):
            raise TypeError(f"specs[{i}] is {type(spec).__name__}, expected "
                            f"a FaultSpec (GatewayFault / LinkFlap / "
                            f"PcmStuckCell / LossDrift)")
        spec.apply(frame, cfg, np.random.RandomState(seed * 9973 + i))
    return frame


def attach_faults(trace: dict, frame: Dict[str, np.ndarray]) -> dict:
    """Return `trace` with the fault frame riding in it (FAULT_KEYS).

    The frame's horizon must match the trace's T axis; after attachment
    the ordinary trace transforms slice/pad/concat the fault arrays along
    with the loads, so fault events stay aligned to chunk boundaries.
    """
    missing = [k for k in FAULT_KEYS if k not in frame]
    if missing:
        raise ValueError(f"fault frame is missing {missing} "
                         f"(build it with compile_faults/no_faults)")
    t = int(jnp.shape(trace["ext_load"])[0])
    tf = int(jnp.shape(frame["gw_ok"])[0])
    if t != tf:
        raise ValueError(f"fault frame covers {tf} intervals but the trace "
                         f"has {t} — compile the frame at the trace length")
    return dict(trace, **{k: jnp.asarray(frame[k], jnp.float32)
                          for k in FAULT_KEYS})


def strip_faults(trace: dict) -> dict:
    """The trace without its fault frame (for fault-free baselines and for
    scoring re-placement candidates on the clean traffic model)."""
    return {k: v for k, v in trace.items() if k not in FAULT_KEYS}


def stack_fault_frames(frames: Sequence[dict]) -> Dict[str, jnp.ndarray]:
    """Stack K frames along a new leading axis (the `sweep_faults` grid)."""
    if not frames:
        raise ValueError("stack_fault_frames() needs at least one frame")
    return {k: jnp.stack([jnp.asarray(f[k], jnp.float32) for f in frames])
            for k in FAULT_KEYS}


# ---------------------------------------------------------------------------
# Reconfiguration cost + the closed-loop fault environment
# ---------------------------------------------------------------------------

def placement_reconfig_cost(old_placement, new_placement,
                            power=PHOTONIC_POWER) -> dict:
    """PCM switching bill for a live re-placement.

    Every gateway that moves re-programs its PCM coupler pair (the removed
    site decouples, the added site couples): `pcmc_reconfig_nj` each, and
    the re-placement stalls reconfiguration for one `pcmc_reconfig_cycles`
    window (cells re-program in parallel).
    """
    old = set(tuple(map(int, p)) for p in (old_placement or ()))
    new = set(tuple(map(int, p)) for p in (new_placement or ()))
    moved = len(old - new) + len(new - old)
    return {"moved_gateways": moved,
            "pcm_nj": moved * power.pcmc_reconfig_nj,
            "stall_cycles": power.pcmc_reconfig_cycles if moved else 0}


class FaultInjector:
    """The closed-loop fault environment (the demo/benchmark's 'hardware').

    Holds *physical* fault specs over a fixed horizon and, per chunk,
    compiles the frame the network actually experiences under its CURRENT
    placement — a position-targeted fault stops biting once the gateways
    move off the dead router. It also plays the hardware status register:
    `failed_positions(t)` is what a board-management controller would
    report, and is what `ResilienceRuntime` masks out of the search
    proposal space.
    """

    def __init__(self, specs: Sequence[FaultSpec], horizon: int, *,
                 seed: int = 0, cache_size: int = 8):
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        import collections

        self.specs = tuple(specs)
        self.horizon = int(horizon)
        self.seed = int(seed)
        self.cache_size = int(cache_size)
        # Placement-keyed compiled frames, LRU-bounded: a long-running
        # serving loop that keeps re-placing gateways (every heal is a new
        # placement key) would otherwise grow this dict without bound.
        self._frames: "collections.OrderedDict" = collections.OrderedDict()

    def frame_for(self, cfg: NetworkConfig, t0: int, t1: int) -> dict:
        """The fault frame for intervals [t0, t1) under `cfg`'s placement."""
        if not 0 <= t0 < t1 <= self.horizon:
            raise ValueError(f"window [{t0}, {t1}) outside horizon "
                             f"{self.horizon}")
        key = normalize_placement(resolve_gateway_positions(cfg), cfg)
        if key in self._frames:
            self._frames.move_to_end(key)
        else:
            self._frames[key] = compile_faults(self.specs, cfg, self.horizon,
                                               seed=self.seed)
            while len(self._frames) > self.cache_size:
                self._frames.popitem(last=False)
        full = self._frames[key]
        return {k: full[k][t0:t1] for k in FAULT_KEYS}

    def inject(self, chunk: dict, cfg: NetworkConfig, t0: int) -> dict:
        """Attach the chunk-aligned frame to a trace chunk starting at t0."""
        t = int(jnp.shape(chunk["ext_load"])[0])
        return attach_faults(chunk, self.frame_for(cfg, t0, t0 + t))

    def failed_positions(self, t: int) -> List[Tuple[int, int]]:
        """Router positions whose gateway hardware is dead at interval t
        (the status-register view: physical, placement-independent)."""
        out = []
        for spec in self.specs:
            pos = getattr(spec, "position", None)
            dead = isinstance(spec, GatewayFault) or (
                isinstance(spec, PcmStuckCell) and spec.mode == "off")
            if pos is None or not dead:
                continue
            hi = self.horizon if spec.end is None else spec.end
            if spec.start <= t < hi:
                out.append((int(pos[0]), int(pos[1])))
        return sorted(set(out))
