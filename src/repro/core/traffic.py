"""Calibrated synthetic PARSEC-like traffic traces.

GEM5 full-system traces are unavailable offline (DESIGN.md §9.1), so we
generate per-interval chiplet traffic with per-application parameters
calibrated to the paper's own characterization (§4.2, §4.5):

  * blackscholes  — highest inter-chiplet load (saturates 18 gateways)
  * facesim       — lowest load
  * dedup         — median load
  * remaining five PARSEC apps spread between those anchors.

A trace is a dict of arrays over reconfiguration intervals:
  ext_load   [T, C] — inter-chiplet packet injection per chiplet (pkts/cycle)
  mem_load   [T]    — traffic to the 2 memory-controller gateways (pkts/cycle)
  int_load   [T, C] — intra-chiplet-only traffic (pkts/cycle per chiplet)
  ext_frac   []     — fraction of packets that cross the interposer

Temporal structure = slow phase oscillation (application phases) + lognormal
per-interval jitter (burst clustering). All generation is jax.random-based and
reproducible by seed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.constants import NETWORK, NetworkConfig


@dataclasses.dataclass(frozen=True)
class AppProfile:
    name: str
    mean_ext_load: float    # per-chiplet inter-chiplet pkts/cycle
    cv: float               # coefficient of variation across intervals
    phase_period: float     # intervals per application phase
    ext_frac: float         # share of traffic that is inter-chiplet
    mem_frac: float         # share of ext traffic destined to memory


# Anchors per the paper; the other apps interpolated by their known
# communication intensity ordering in PARSEC characterization literature.
PARSEC: Dict[str, AppProfile] = {
    "blackscholes": AppProfile("blackscholes", 0.044, 0.25, 20.0, 0.40, 0.30),
    "swaptions":    AppProfile("swaptions",    0.018, 0.30, 16.0, 0.30, 0.25),
    "streamcluster":AppProfile("streamcluster",0.034, 0.35, 12.0, 0.45, 0.35),
    "facesim":      AppProfile("facesim",      0.006, 0.20, 24.0, 0.25, 0.30),
    "fluidanimate": AppProfile("fluidanimate", 0.028, 0.40, 10.0, 0.35, 0.25),
    "bodytrack":    AppProfile("bodytrack",    0.022, 0.35, 14.0, 0.30, 0.30),
    "canneal":      AppProfile("canneal",      0.038, 0.30, 18.0, 0.50, 0.40),
    "dedup":        AppProfile("dedup",        0.024, 0.45,  8.0, 0.35, 0.30),
}

APP_NAMES = list(PARSEC)


def generate_trace(app: str, n_intervals: int, key: jax.Array,
                   cfg: NetworkConfig = NETWORK) -> dict:
    """Generate one application trace over `n_intervals` epochs."""
    prof = PARSEC[app]
    c = cfg.n_chiplets
    k_phase, k_jit, k_chip = jax.random.split(key, 3)

    t = jnp.arange(n_intervals, dtype=jnp.float32)
    # Application phases: raised cosine keeps load non-negative and gives the
    # controller real transitions to track.
    phase = 1.0 + 0.5 * jnp.sin(2.0 * jnp.pi * t / prof.phase_period
                                + jax.random.uniform(k_phase) * 6.28)
    # Lognormal jitter with the app's cv.
    sigma = jnp.sqrt(jnp.log1p(prof.cv ** 2))
    jitter = jnp.exp(jax.random.normal(k_jit, (n_intervals, c)) * sigma
                     - 0.5 * sigma ** 2)
    # Mild static per-chiplet imbalance (placement effects).
    chip_w = 1.0 + 0.15 * jax.random.normal(k_chip, (c,))
    chip_w = jnp.clip(chip_w, 0.7, 1.3)

    ext = prof.mean_ext_load * phase[:, None] * jitter * chip_w[None, :]
    mem = prof.mem_frac * jnp.sum(ext, axis=1)
    intra = ext * (1.0 - prof.ext_frac) / jnp.maximum(prof.ext_frac, 1e-6)
    return {"ext_load": ext, "mem_load": mem, "int_load": intra,
            "ext_frac": jnp.float32(prof.ext_frac), "app": app}


def slice_trace(trace: dict, n_chiplets: int) -> dict:
    """Restrict a trace to its first `n_chiplets` chiplet columns.

    The per-topology view used by topology sweeps: a trace generated at the
    grid's maximum chiplet count is narrowed per grid point. `mem_load` and
    `ext_frac` are chiplet-count-free and shared across grid points.
    """
    c = trace["ext_load"].shape[-1]
    if n_chiplets > c:
        raise ValueError(f"trace has {c} chiplets, needs >= {n_chiplets}")
    return dict(trace,
                ext_load=trace["ext_load"][..., :n_chiplets],
                int_load=trace["int_load"][..., :n_chiplets])


def concat_traces(traces: list) -> dict:
    """Stitch application traces back-to-back (Fig. 12 adaptivity runs)."""
    out = {k: jnp.concatenate([tr[k] for tr in traces], axis=0)
           for k in ("ext_load", "mem_load", "int_load")}
    out["ext_frac"] = jnp.mean(jnp.stack([tr["ext_frac"] for tr in traces]))
    out["app"] = "+".join(tr["app"] for tr in traces)
    return out


def all_app_traces(n_intervals: int, seed: int = 0,
                   cfg: NetworkConfig = NETWORK) -> Dict[str, dict]:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(APP_NAMES))
    return {name: generate_trace(name, n_intervals, k, cfg)
            for name, k in zip(APP_NAMES, keys)}
