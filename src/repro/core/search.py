"""Device-resident gateway-placement search (PR 5).

ReSiPI's headline claim is *run-time* reconfiguration — redeploying
inter-chiplet gateways against observed traffic — which makes placement
search a serving-path workload, not an offline design step. The PR-3
`search_placement` host loop (numpy proposals, one dispatch plus several
device->host syncs per generation) tops out around a hundred generations
per second on CPU: the compiled sweep engine underneath it idles while
Python shuttles candidates back and forth.

This module moves the ENTIRE annealed search on-device. Proposal
generation (collision-free single-gateway moves + random restarts via
`jax.random`, spread-ordered by the traceable
`gateway_controller.activation_order_jnp`), candidate table construction
(`selection.placement_tables_jnp` — the jnp twin of the numpy builder),
candidate scoring (the existing masked scan body), annealed acceptance,
elitist best-tracking and the per-generation history all live inside ONE
`lax.scan` with a donated carry:

  * `search_placement_device` — a full search is a single dispatch with
    zero host round-trips between generations (`engine_stats()` shows one
    scan-body trace and one `search_dispatches` per search). The public
    entry point is `simulator.search_placement` (engine="device" default,
    engine="host" keeps the PR-3 loop as the parity oracle).
  * `search_placement_islands` — K independent annealed chains vmapped
    over seeds, sharing the single executable (embarrassingly parallel
    restarts). Runtime `SWEEPABLE_FIELDS` grids of length K zip with the
    island axis, so "search the placement under l_m[k]" is a joint
    placement x runtime-knob exploration in one compiled call; the island
    axis shards across devices via NamedSharding when more than one is
    present.

Proposal/acceptance semantics mirror the host loop exactly (same move
kinds, same annealing law, same elitism and default-scheme scoring in
generation 0); the PRNG streams differ (`jax.random` vs numpy
RandomState), so the two engines explore different — equally valid —
trajectories from the same seed while each stays fully deterministic.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import NetworkConfig
from repro.core.gateway_controller import activation_order_jnp
from repro.core.selection import (_router_coords, normalize_placement,
                                  placement_tables_jnp,
                                  resolve_gateway_positions)
# One source of truth with the host engine: the summary schema (fixed
# vector order for the elitist best-candidate carry), the short objective
# aliases and the objective validator all live next to _summary_from_sums.
# (simulator does not import this module at top level, so this import is
# cycle-free.)
from repro.core.simulator import (PLACEMENT_OBJECTIVE_ALIASES, SUMMARY_KEYS,
                                  check_placement_objective)


def _objective_value(out: dict, objective: str) -> jax.Array:
    """Scalar objective from one candidate's simulate output (traced)."""
    if objective == "inter_latency":
        return jnp.mean(out["records"]["mean_inter_latency"])
    return out["summary"][
        PLACEMENT_OBJECTIVE_ALIASES.get(objective, objective)]


def _mesh_coords(cfg: NetworkConfig) -> jnp.ndarray:
    """[R, 2] router coordinates, flat index x*mesh_y + y.

    Same ordering as `selection._router_coords` (which
    `placement_tables_jnp` builds against) — `_one_move`'s flat-index
    occupancy test depends on the two staying in lockstep.
    """
    return jnp.asarray(_router_coords(cfg), jnp.int32)


# ---------------------------------------------------------------------------
# On-device proposal kernels
# ---------------------------------------------------------------------------
#
# All random draws are pre-generated OUTSIDE the generation scan in a few
# vectorized jax.random calls (threefry per tiny in-scan draw is the single
# biggest CPU cost of a naive port): the scan body consumes pre-drawn
# restart flags / restart placements / move indices / Gumbel noise and does
# only arithmetic. Data-dependent choices (which *free* router a gateway
# moves to) use the pre-drawn Gumbel noise via masked argmax — exactly a
# categorical sample over the free slots.

def _one_move(pos: jax.Array, i: jax.Array, gumbel: jax.Array,
              coords: jax.Array,
              blocked: jax.Array) -> jax.Array:
    """Collision-free single-gateway move (host `mutate` semantics).

    Relocates gateway `i` to a router chosen uniformly among the currently
    unoccupied ones (the mover's own slot counts as occupied, exactly like
    the host loop, so a move never stays in place). `blocked` [R] marks
    routers excluded from the proposal space (failed hardware) — they count
    as permanently occupied. Scatter-free on purpose — tiny batched
    scatters lower poorly on CPU, and this runs per candidate per
    generation inside the search scan. Occupancy is a coordinate-equality
    test against `coords` rows, so arbitrary layouts (explicit
    NetworkConfig.coords) need no flat-index arithmetic.
    """
    g_max = pos.shape[0]
    occupied = jnp.any(
        jnp.all(coords[:, None, :] == pos[None, :, :], axis=-1), axis=1)
    occupied = occupied | (blocked > 0.5)
    j = jnp.argmax(jnp.where(occupied, -jnp.inf, gumbel))
    # No free router (placement fills the mesh): skip the move, exactly
    # like the host loop's empty-free-list break.
    movable = jnp.any(~occupied)
    return jnp.where(movable & (jnp.arange(g_max)[:, None] == i),
                     coords[j], pos)


def _propose(parent: jax.Array, restart: jax.Array,
             restart_pos: jax.Array, move_i: jax.Array,
             move_gumbel: jax.Array, moves: jax.Array, coords: jax.Array,
             blocked: jax.Array, cfg: NetworkConfig) -> jax.Array:
    """One candidate: random restart or 1-2 collision-free moves, then
    spread-reordered by the traceable activation rule (host parity)."""
    m1 = _one_move(parent, move_i[0], move_gumbel[0], coords, blocked)
    m2 = _one_move(m1, move_i[1], move_gumbel[1], coords, blocked)
    pos = jnp.where(restart, restart_pos, jnp.where(moves > 1, m2, m1))
    return pos[activation_order_jnp(pos, cfg)]


# ---------------------------------------------------------------------------
# The one-scan search core
# ---------------------------------------------------------------------------

# One history record per generation, packed as a single [len(HISTORY_KEYS)]
# vector so the scan emits one ys leaf (fewer per-step update ops).
HISTORY_KEYS = ("generation", "parent_score", "best_candidate_score",
                "best_score", "accepted", "latency", "power_mw", "energy")


def _search_core(carry0: dict, key: jax.Array, ext, mem, intra, ext_frac,
                 t_mask, default_pos: jax.Array, hyper: dict,
                 ov: Dict[str, jax.Array], blocked: jax.Array, dest=None,
                 *, sim, generations: int, population: int, objective: str,
                 inject_default: bool, moves_hi: int) -> dict:
    """The whole annealed search as ONE `lax.scan` over generations.

    Every generation: propose population-1 candidates on device, build
    their placement tables with the jnp twins, score all of them through
    the existing masked scan body (one vmap), apply annealed acceptance to
    the incumbent and elitist best-tracking — no value ever crosses to the
    host. All randomness is pre-drawn from `key` in a handful of vectorized
    calls before the scan; the scan carry is donated by the jit wrappers,
    so a warm search reuses its buffers in place.
    """
    from repro.core import simulator as _sim

    cfg = sim.cfg
    coords = _mesh_coords(cfg)
    n_r = coords.shape[0]
    g_max = cfg.max_gateways_per_chiplet
    n_prop = population - 1

    k_flag, k_perm, k_idx, k_gum, k_acc = jax.random.split(key, 5)
    restart = jax.random.bernoulli(k_flag, hyper["restart_frac"],
                                   (generations, n_prop))
    # Restart placements: Gumbel-top-k = a uniform sample of g_max routers
    # WITHOUT replacement over the allowed (non-blocked) ones. With nothing
    # blocked this is distributionally the random permutation the engine
    # used pre-faults; blocking `blocked` routers just renormalizes it.
    rest_gum = jnp.where(blocked[None, None, :] > 0.5, -jnp.inf,
                         jax.random.gumbel(k_perm,
                                           (generations, n_prop, n_r)))
    _, rest_idx = jax.lax.top_k(rest_gum, g_max)
    restart_pos = coords[rest_idx]             # [T, n_prop, G, 2]
    move_i = jax.random.randint(k_idx, (generations, n_prop, 2), 0, g_max)
    move_gum = jax.random.gumbel(k_gum, (generations, n_prop, 2, n_r))
    acc_u = jax.random.uniform(k_acc, (generations,))

    def gen_body(carry, xs):
        gen, rst, rst_pos, mv_i, mv_gum, u = xs
        # Host schedule: 2 moves for the first max(1, generations//3)
        # generations (coarse), 1 afterwards (fine).
        moves = jnp.where(gen < moves_hi, 2, 1)
        props = jax.vmap(
            lambda r, rp, mi, mg: _propose(carry["parent"], r, rp, mi, mg,
                                           moves, coords, blocked, cfg)
        )(rst, rst_pos, mv_i, mv_gum)
        cands = jnp.concatenate([carry["parent"][None], props])  # [P, G, 2]
        if inject_default:
            # Host: generation 0 always scores the default edge scheme when
            # the search starts elsewhere (init != default).
            cands = cands.at[1].set(
                jnp.where(gen == 0, default_pos, cands[1]))

        tables = jax.vmap(lambda p: placement_tables_jnp(p, cfg))(cands)

        def score_one(tbl):
            out = _sim._simulate_impl(ext, mem, intra, ext_frac, t_mask,
                                      sim, tbl, ov, dest=dest)
            return (_objective_value(out, objective),
                    jnp.stack([out["summary"][k] for k in SUMMARY_KEYS]))

        scores, summaries = jax.vmap(score_one)(tables)   # [P], [P, 8]

        default_lane = 1 if inject_default else 0
        default_score = jnp.where(gen == 0, scores[default_lane],
                                  carry["default_score"])

        # Elitist best over everything ever scored.
        ibest = jnp.argmin(scores)
        sbest = scores[ibest]
        improved = sbest < carry["best_score"]
        best_score = jnp.where(improved, sbest, carry["best_score"])
        best_pos = jnp.where(improved, cands[ibest], carry["best_pos"])
        best_summary = jnp.where(improved, summaries[ibest],
                                 carry["best_summary"])

        # Annealed incumbent move: greedy downhill, probabilistic uphill.
        delta = sbest - scores[0]
        rel = delta / jnp.maximum(jnp.abs(scores[0]), 1e-12)
        temp = (hyper["temperature"]
                * hyper["cooling"] ** gen.astype(jnp.float32))
        metropolis = (temp > 0) & (u < jnp.exp(-rel / jnp.maximum(temp,
                                                                  1e-30)))
        accepted = (delta < 0) | metropolis
        parent = jnp.where(accepted, cands[ibest], carry["parent"])

        lat_i = SUMMARY_KEYS.index("mean_latency")
        pow_i = SUMMARY_KEYS.index("mean_power_mw")
        en_i = SUMMARY_KEYS.index("mean_energy")
        rec = jnp.stack([gen.astype(jnp.float32), scores[0], sbest,
                         best_score, accepted.astype(jnp.float32),
                         summaries[ibest, lat_i], summaries[ibest, pow_i],
                         summaries[ibest, en_i]])
        new_carry = {"parent": parent, "best_pos": best_pos,
                     "best_score": best_score, "best_summary": best_summary,
                     "default_score": default_score}
        return new_carry, rec

    carry, history = jax.lax.scan(
        gen_body, carry0,
        (jnp.arange(generations, dtype=jnp.int32), restart, restart_pos,
         move_i, move_gum, acc_u))
    # Returning the final incumbent (a) lets callers warm-restart a search
    # from where annealing left off and (b) gives every donated carry
    # buffer a same-shape output slot, so donation is fully usable.
    return {"best_placement": carry["best_pos"],
            "best_score": carry["best_score"],
            "best_summary": carry["best_summary"],
            "default_score": carry["default_score"],
            "incumbent_placement": carry["parent"],
            "history": history}


def _init_carry(init_pos: jax.Array) -> dict:
    # parent/best_pos must be distinct buffers: the carry is donated, and
    # XLA rejects the same buffer appearing in two donated slots.
    return {"parent": jnp.array(init_pos, jnp.int32, copy=True),
            "best_pos": jnp.array(init_pos, jnp.int32, copy=True),
            "best_score": jnp.float32(jnp.inf),
            "best_summary": jnp.zeros((len(SUMMARY_KEYS),), jnp.float32),
            "default_score": jnp.float32(0.0)}


_SEARCH_STATICS = ("sim", "generations", "population", "objective",
                   "inject_default", "moves_hi")


@functools.partial(jax.jit, static_argnames=_SEARCH_STATICS,
                   donate_argnums=(0,))
def _search_jit(carry0, key, ext, mem, intra, ext_frac, t_mask,
                default_pos, hyper, ov, blocked, dest=None, *, sim,
                generations, population, objective, inject_default,
                moves_hi):
    return _search_core(carry0, key, ext, mem, intra, ext_frac, t_mask,
                        default_pos, hyper, ov, blocked, dest, sim=sim,
                        generations=generations, population=population,
                        objective=objective, inject_default=inject_default,
                        moves_hi=moves_hi)


@functools.partial(jax.jit, static_argnames=_SEARCH_STATICS,
                   donate_argnums=(0,))
def _search_islands_jit(carry0, key, ext, mem, intra, ext_frac, t_mask,
                        default_pos, hyper, ov, blocked, dest=None, *, sim,
                        generations, population, objective, inject_default,
                        moves_hi):
    """K chains, ONE executable: vmap over (carry, key, overrides)."""
    return jax.vmap(
        lambda c0, ks, o: _search_core(
            c0, ks, ext, mem, intra, ext_frac, t_mask, default_pos, hyper,
            o, blocked, dest, sim=sim, generations=generations,
            population=population, objective=objective,
            inject_default=inject_default, moves_hi=moves_hi)
    )(carry0, key, ov)


def clear_search_caches() -> None:
    """Drop the compiled search executables (cold-start measurement)."""
    _search_jit.clear_cache()
    _search_islands_jit.clear_cache()


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _check_search_params(generations: int, population: int,
                         objective: str) -> None:
    if population < 2:
        raise ValueError("population must be >= 2 (incumbent + candidates)")
    if generations < 1:
        raise ValueError("generations must be >= 1")
    check_placement_objective(objective)


def repair_placement(placement, blocked_positions, cfg) -> tuple:
    """Move gateways off blocked routers to the nearest allowed free ones.

    Host-side (numpy) helper for warm-restarting a search from an
    incumbent that predates a failure: every gateway sitting on a blocked
    router relocates to the Manhattan-nearest unoccupied allowed router
    (deterministic: ties break by flat router index). Returns a
    spread-normalized placement that is valid under `blocked_positions`.
    """
    from repro.core import topology

    p = list(normalize_placement(placement, cfg))
    blocked = {(int(x), int(y)) for (x, y) in blocked_positions}
    occupied = set(p)
    free = [(int(x), int(y)) for x, y in topology.router_coords(cfg)
            if (x, y) not in blocked and (x, y) not in occupied]
    for i, pos in enumerate(p):
        if pos not in blocked:
            continue
        if not free:
            raise ValueError(
                f"cannot repair placement: {len(blocked)} blocked routers "
                f"leave no free position for the gateway at {pos}")
        j = min(range(len(free)),
                key=lambda k: (int(topology.pair_hops(cfg, free[k], pos)),
                               k))
        p[i] = free.pop(j)
    return normalize_placement(p, cfg, order="spread")


def _blocked_mask(blocked_positions, cfg) -> jnp.ndarray:
    """[R] float mask in `_mesh_coords` row order (1 = excluded router)."""
    from repro.core import topology

    idx_lut = topology.router_index_lut(cfg)
    bx, by = idx_lut.shape
    mask = np.zeros(cfg.routers_per_chiplet, np.float32)
    for (x, y) in (blocked_positions or ()):
        x, y = int(x), int(y)
        r = int(idx_lut[x, y]) if (0 <= x < bx and 0 <= y < by) else -1
        if r < 0:
            raise ValueError(f"blocked position ({x}, {y}) is outside the "
                             f"{bx}x{by} mesh")
        mask[r] = 1.0
    return jnp.asarray(mask)


def _prepare_search(trace: dict, sim, init, blocked_positions=None):
    """Shared setup: trace arrays, default/init placements, static flags.

    Blocked routers shrink the proposal space as a *traced* [R] mask, so
    every blocked set (including the empty one) shares the same compiled
    search executable. The scored default placement is repaired off blocked
    hardware; an `init` occupying a blocked router raises (callers repair
    explicitly so the warm-restart move cost is attributable).
    """
    from repro.core import simulator as _sim

    arrays = _sim._trace_arrays(trace)
    cfg = sim.cfg
    blocked = {(int(x), int(y)) for (x, y) in (blocked_positions or ())}
    g_max = cfg.max_gateways_per_chiplet
    if cfg.routers_per_chiplet - len(blocked) < g_max:
        raise ValueError(
            f"{len(blocked)} blocked routers leave fewer than "
            f"{g_max} allowed positions on the "
            f"{cfg.mesh_x}x{cfg.mesh_y} mesh")
    default_p = normalize_placement(resolve_gateway_positions(cfg), cfg)
    if set(default_p) & blocked:
        default_p = repair_placement(default_p, blocked, cfg)
    parent_p = default_p if init is None else normalize_placement(init, cfg)
    if set(parent_p) & blocked:
        raise ValueError(
            f"init placement occupies blocked routers "
            f"{sorted(set(parent_p) & blocked)} — repair it first "
            f"(search.repair_placement)")
    if len(parent_p) != g_max:
        raise ValueError(
            f"init places {len(parent_p)} gateways but "
            f"max_gateways_per_chiplet={g_max}")
    inject_default = parent_p != default_p
    return (arrays, jnp.asarray(default_p, jnp.int32),
            jnp.asarray(parent_p, jnp.int32), default_p, inject_default,
            _blocked_mask(blocked, cfg))


def _hyper(temperature, cooling, restart_frac) -> dict:
    return {"temperature": jnp.float32(temperature),
            "cooling": jnp.float32(cooling),
            "restart_frac": jnp.float32(restart_frac)}


def _history_list(hist: np.ndarray) -> list:
    """[T, len(HISTORY_KEYS)] record matrix -> host-engine list of dicts."""
    out = []
    for row in np.asarray(hist):
        rec = dict(zip(HISTORY_KEYS, (float(v) for v in row)))
        rec["generation"] = int(rec["generation"])
        rec["accepted"] = rec["accepted"] > 0.5
        out.append(rec)
    return out


def _as_placement(pos) -> tuple:
    return tuple((int(x), int(y)) for x, y in np.asarray(pos))


def search_placement_device(trace: dict, sim, *,
                            objective: str = "inter_latency",
                            generations: int = 10, population: int = 12,
                            seed: int = 0, init=None,
                            temperature: float = 0.05, cooling: float = 0.7,
                            restart_frac: float = 0.25,
                            blocked_positions=None) -> dict:
    """Device-resident annealed placement search: ONE dispatch per search.

    Same searcher semantics and return structure as the host engine (see
    `simulator.search_placement`, which wraps this), but the whole
    generation loop is a single compiled `lax.scan`: `engine_stats()` shows
    one scan-body trace for the entire search, `search_dispatches` counts
    exactly one executable launch, and the only device->host transfer is
    the final result pytree.
    """
    from repro.core import simulator as _sim

    _check_search_params(generations, population, objective)
    (ext, mem, intra, ext_frac, t_mask, dest), default_pos, init_pos, \
        default_p, inject_default, blocked = _prepare_search(
            trace, sim, init, blocked_positions)

    res = _search_jit(
        _init_carry(init_pos), jax.random.PRNGKey(seed), ext, mem, intra,
        ext_frac, t_mask, default_pos,
        _hyper(temperature, cooling, restart_frac), {}, blocked, dest,
        sim=sim, generations=generations, population=population,
        objective=objective, inject_default=inject_default,
        moves_hi=max(1, generations // 3))
    # Counted after the launch (like the islands path): a raising
    # compile/trace never inflates the one-search == one-dispatch stats.
    _sim._STATS["search_dispatches"] += 1
    host = jax.device_get(res)          # the ONE transfer for the search

    best_s = float(host["best_score"])
    default_s = float(host["default_score"])
    return {"best_placement": _as_placement(host["best_placement"]),
            "best_score": best_s,
            "best_summary": dict(zip(SUMMARY_KEYS,
                                     map(float, host["best_summary"]))),
            "default_placement": default_p, "default_score": default_s,
            "improvement_frac": 1.0 - best_s / max(default_s, 1e-12),
            "incumbent_placement": _as_placement(
                host["incumbent_placement"]),
            "objective": objective, "generations": generations,
            "population": population, "engine": "device",
            "history": _history_list(host["history"])}


def search_placement_islands(trace: dict, sim, *, islands: int = None,
                             objective: str = "inter_latency",
                             generations: int = 10, population: int = 12,
                             seed: int = 0, init=None,
                             temperature: float = 0.05,
                             cooling: float = 0.7,
                             restart_frac: float = 0.25,
                             devices=None, blocked_positions=None,
                             **grids) -> dict:
    """K independent annealed chains in ONE compiled executable.

    Each island runs the full `search_placement_device` chain from its own
    PRNG stream (`fold_in(seed, k)`), vmapped so all K populations score in
    the same executable launch — embarrassingly parallel restarts at the
    cost of one. Runtime `SWEEPABLE_FIELDS` grids of length K zip with the
    island axis::

        search_placement_islands(tr, sim, islands=4,
                                 l_m=[0.008, 0.012, 0.02, 0.03])

    searches the best placement *per L_m operating point* — a joint
    placement x runtime-knob exploration (the concrete step toward the
    ROADMAP's joint search item). With more than one device the island
    axis is sharded via NamedSharding (graceful single-device fallback).

    Returns the overall winner plus per-island bests/defaults/histories
    (`island_*` arrays, leading [K] axis), all from one `device_get`.
    """
    from repro.core import simulator as _sim

    _check_search_params(generations, population, objective)
    (ext, mem, intra, ext_frac, t_mask, dest), default_pos, init_pos, \
        default_p, inject_default, blocked = _prepare_search(
            trace, sim, init, blocked_positions)

    unknown = set(grids) - set(_sim.SWEEPABLE_FIELDS)
    if unknown:
        raise ValueError(
            f"non-sweepable fields: {sorted(unknown)} (islands zip with "
            f"runtime fields: {_sim.SWEEPABLE_FIELDS})")
    if islands is not None and (isinstance(islands, bool)
                                or not isinstance(islands,
                                                  (int, np.integer))):
        raise ValueError(
            f"islands must be an int, got {type(islands).__name__} "
            f"{islands!r}")
    lengths = {f: _sim._grid_len(f, v) for f, v in grids.items()}
    if islands is None:
        if lengths:
            if len(set(lengths.values())) != 1:
                raise ValueError(f"swept fields must share one length, "
                                 f"got {lengths}")
            islands = next(iter(lengths.values()))
        else:
            islands = 8
    bad = {f: n for f, n in lengths.items() if n != islands}
    if bad:
        raise ValueError(
            f"island grids must have length islands={islands}, got {bad} "
            f"— every runtime grid zips element-wise with the island axis")
    if islands < 1:
        raise ValueError("islands must be >= 1")

    ov = {f: jnp.asarray(v) for f, v in grids.items()}
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(islands))
    carry0 = jax.vmap(lambda _: _init_carry(init_pos))(jnp.arange(islands))
    hyper = _hyper(temperature, cooling, restart_frac)
    static = dict(sim=sim, generations=generations, population=population,
                  objective=objective, inject_default=inject_default,
                  moves_hi=max(1, generations // 3))

    devices = list(devices if devices is not None else jax.devices())
    res = None
    if len(devices) > 1:
        try:
            from repro.core.distributed import GridSharding

            # The island axis shards over the fleet's "grid" mesh axis —
            # with init_distributed up, across every host's devices. The
            # shared trace/search inputs replicate fleet-wide; the result
            # pytree is all-gathered so every process sees all islands.
            gs = GridSharding(islands, devices=devices,
                              logical_axis="islands")
            carry_s, keys_s, ov_s = gs.shard((carry0, keys, ov))
            ext_r, mem_r, intra_r, frac_r, mask_r, dpos_r, hyper_r, \
                blocked_r, dest_r = gs.replicate(
                    (ext, mem, intra, ext_frac, t_mask, default_pos,
                     hyper, blocked, dest))
            res = _search_islands_jit(
                carry_s, keys_s, ext_r, mem_r, intra_r, frac_r, mask_r,
                dpos_r, hyper_r, ov_s, blocked_r, dest_r, **static)
            res = gs.gather(res)
        except Exception as e:  # pragma: no cover - depends on device layout
            import warnings
            warnings.warn(f"sharded island search failed ({e!r}); falling "
                          f"back to single-device path")
            res = None
            carry0 = jax.vmap(lambda _: _init_carry(init_pos))(
                jnp.arange(islands))
    if res is None:
        res = _search_islands_jit(carry0, keys, ext, mem, intra, ext_frac,
                                  t_mask, default_pos, hyper, ov, blocked,
                                  dest, **static)
    # Counted once per *successful* launch (a failed sharded attempt that
    # fell back above raised before dispatching), preserving the
    # one-search == one-dispatch accounting on every device layout.
    _sim._STATS["search_dispatches"] += 1
    host = jax.device_get(res)          # the ONE transfer for all islands

    scores = np.asarray(host["best_score"])
    k_best = int(np.argmin(scores))
    defaults = np.asarray(host["default_score"])
    best_s = float(scores[k_best])
    default_best = float(defaults[k_best])
    hist = np.asarray(host["history"])       # [K, T, len(HISTORY_KEYS)]
    return {
        "best_placement": _as_placement(host["best_placement"][k_best]),
        "best_score": best_s,
        "best_island": k_best,
        "best_summary": dict(zip(
            SUMMARY_KEYS, map(float, host["best_summary"][k_best]))),
        "default_placement": default_p,
        "default_score": default_best,
        "improvement_frac": 1.0 - best_s / max(default_best, 1e-12),
        "island_best_placements": [
            _as_placement(p) for p in host["best_placement"]],
        "island_incumbents": [
            _as_placement(p) for p in host["incumbent_placement"]],
        "island_best_scores": scores,
        "island_default_scores": defaults,
        "island_overrides": {f: np.asarray(v) for f, v in grids.items()},
        "history": {k: hist[..., i] for i, k in enumerate(HISTORY_KEYS)},
        "objective": objective, "generations": generations,
        "population": population, "islands": islands, "engine": "device",
    }
