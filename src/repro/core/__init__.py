"""ReSiPI core: the paper's contribution as composable JAX modules.

Level 1 (faithful reproduction): photonics, gateway_controller, selection,
noc, traffic, simulator — the 2.5D photonic-interposer network of the paper.

Level 2 (framework integration): reconfig_runtime — the same controller
driving communication-lane reconfiguration in the multi-pod trainer.
"""
from repro.core import constants, photonics, gateway_controller, selection
from repro.core import noc, traffic, simulator, reconfig_runtime

__all__ = ["constants", "photonics", "gateway_controller", "selection",
           "noc", "traffic", "simulator", "reconfig_runtime"]
