"""ReSiPI core: the paper's contribution as composable JAX modules.

Level 1 (faithful reproduction): photonics, gateway_controller, selection,
noc, traffic, simulator — the 2.5D photonic-interposer network of the paper.

Level 2 (framework integration): reconfig_runtime — the same controller
driving communication-lane reconfiguration in the multi-pod trainer.

Robustness: faults — frozen FaultSpecs compiled to time-varying validity/
loss frames that ride the same masked scan (never-firing frames match the
fault-free run bit-for-bit); serve.resilience closes the loop.
"""
from repro.core import constants, photonics, gateway_controller, selection
from repro.core import noc, traffic, simulator, reconfig_runtime, faults

__all__ = ["constants", "photonics", "gateway_controller", "selection",
           "noc", "traffic", "simulator", "reconfig_runtime", "faults"]
