"""One-dispatch Pareto co-design engine (PR 10).

ReSiPI's design space is three-axed: the interposer *topology* (chiplet
count, per-chiplet gateway budget, intra-chiplet mesh radix), the gateway
*placement* on each chiplet's router mesh, and the controller's runtime
*knobs* (L_m thresholds, wavelength budget, gateway bounds). PR 4 swept
topology shapes in one padded executable; PR 5 moved the annealed
placement search on-device. This module closes the loop: a joint
topology x placement x knob search whose ENTIRE trajectory — an outer
`lax.scan` over padded topology grid points, the PR-5 annealed island
chains inside each, periodic ring migration of island incumbents, and a
device-resident Pareto archive over (latency, power, energy) — is ONE
compiled dispatch (`engine_stats()["search_dispatches"]` counts exactly
one launch per `search_codesign`, and the only device->host transfer is
the final result pytree).

Multi-objective mechanics, all on device:

  * Each of the K islands carries a fixed scalarization weight vector
    (`island_weights`, a Das-Dennis-style simplex spread), normalized per
    topology point by its generation-0 default-placement objectives, so
    the K annealed chains climb toward *different* regions of the front.
  * Every (island, candidate) scored anywhere in the search is offered to
    a fixed-capacity archive carried through both scans: a vectorized
    dominance + duplicate mask keeps only non-dominated points, and
    capacity eviction is deterministic (ascending sum-of-log objectives,
    ties by insertion index). The archive spans ALL topology points —
    dominance is global, so the returned front is the co-design answer,
    not a per-topology best.
  * Every `migrate_every` generations each island adopts its ring
    neighbor's incumbent (island k inherits island k-1's best placement),
    so good placements discovered under one weight vector seed the
    neighboring objective trade-offs.

The topology axes ride the PR-4 padding scheme (chiplet/router axes at
grid maxima, per-point validity masks); candidate placement tables are
built by `selection.placement_tables_from_lut_jnp`, the traced-topology
twin whose hop/edge LUTs arrive as scan inputs instead of static config.
`engine="host"` runs the same searcher semantics as a host-driven loop
over the public `sweep_topology_batch` machinery (the parity oracle:
different PRNG streams, identical scoring path), and
`rescore_front_host` re-scores a device front through that public path
for the 1e-6 device==host parity check.

Derived-mesh grids only: explicit-coords layouts (hex) fix the topology,
so their placement search is `search_placement_islands` on that config.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import PHOTONIC_POWER
from repro.core.search import _one_move
from repro.core.selection import (N_DEFAULT_EDGE_SLOTS, normalize_placement,
                                  placement_tables_from_lut_jnp,
                                  resolve_gateway_positions)
# Cycle-free for the same reason repro.core.search's import is: simulator
# re-exports this module's entry points lazily, never at module top.
from repro.core.simulator import (SWEEPABLE_FIELDS, TOPOLOGY_SWEEPABLE_FIELDS,
                                  stack_traces)

# Objective vector order — columns of every [.., 3] objectives array.
PARETO_OBJECTIVES = ("mean_latency", "mean_power_mw", "mean_energy")

# Topology axes the co-design grid accepts (placements are *searched*, so
# the gateway_positions sweep axis is deliberately absent).
CODESIGN_TOPOLOGY_FIELDS = ("n_chiplets", "gateways_per_chiplet",
                            "mesh_radix")

# Per-(topology, generation) history row layout.
CODESIGN_HISTORY_KEYS = ("archive_size", "best_scalar")


def island_weights(islands: int) -> np.ndarray:
    """[K, 3] deterministic scalarization weights spread over the simplex.

    Das-Dennis construction: the smallest simplex-lattice layer with at
    least K points, enumerated lexicographically, subsampled at evenly
    spaced indices — so K=3 gives the pure corners (one island per single
    objective) and larger K fills the interior trade-offs. K=1 uses the
    uniform weight (balanced compromise search).
    """
    if islands < 1:
        raise ValueError("islands must be >= 1")
    if islands == 1:
        return np.full((1, 3), 1.0 / 3.0, np.float32)
    h = 1
    while (h + 1) * (h + 2) // 2 < islands:
        h += 1
    pts = [(i, j, h - i - j)
           for i in range(h + 1) for j in range(h + 1 - i)]
    idx = np.round(np.linspace(0, len(pts) - 1, islands)).astype(int)
    return np.asarray([pts[i] for i in idx], np.float32) / float(h)


# ---------------------------------------------------------------------------
# Device-resident Pareto archive
# ---------------------------------------------------------------------------

def _empty_archive(capacity: int, g: int) -> dict:
    return {"obj": jnp.full((capacity, 3), jnp.inf, jnp.float32),
            "pos": jnp.zeros((capacity, g, 2), jnp.int32),
            "topo": jnp.full((capacity,), -1, jnp.int32),
            "island": jnp.full((capacity,), -1, jnp.int32),
            "valid": jnp.zeros((capacity,), bool)}


def _archive_insert(arch: dict, cobj, cpos, ctopo, cisland, *,
                    capacity: int) -> dict:
    """Offer a candidate batch to the archive (traced, fixed shapes).

    Vectorized dominance: row i eliminates row j when i's objectives are
    <= everywhere and < somewhere, or when the rows are equal and i was
    inserted earlier (duplicate dedup). Capacity eviction sorts survivors
    by ascending sum-of-log objectives (a geometric-mean quality proxy),
    stable, ties by index — fully deterministic, no RNG. The archive can
    therefore evict genuinely non-dominated points once the front exceeds
    `capacity`; what it NEVER holds is a dominated one (property-tested).
    """
    obj = jnp.concatenate([arch["obj"], jnp.asarray(cobj, jnp.float32)])
    pos = jnp.concatenate([arch["pos"], jnp.asarray(cpos, jnp.int32)])
    tix = jnp.concatenate([arch["topo"], jnp.asarray(ctopo, jnp.int32)])
    kix = jnp.concatenate([arch["island"], jnp.asarray(cisland, jnp.int32)])
    cvalid = jnp.all(jnp.isfinite(jnp.asarray(cobj, jnp.float32)), axis=1)
    valid = jnp.concatenate([arch["valid"], cvalid])

    idx = jnp.arange(obj.shape[0])
    both = valid[:, None] & valid[None, :]
    le = jnp.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = jnp.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    beaten = jnp.any(both & le & (lt | (idx[:, None] < idx[None, :])),
                     axis=0)
    keep = valid & ~beaten
    key = jnp.where(keep,
                    jnp.sum(jnp.log(jnp.maximum(obj, 1e-12)), axis=-1),
                    jnp.inf)
    top = jnp.argsort(key)[:capacity]
    kt = keep[top]
    return {"obj": jnp.where(kt[:, None], obj[top], jnp.inf),
            "pos": pos[top],
            "topo": jnp.where(kt, tix[top], -1),
            "island": jnp.where(kt, kix[top], -1),
            "valid": kt}


def _archive_insert_np(arch: dict, cobj, cpos, ctopo, cisland,
                       capacity: int) -> dict:
    """Numpy mirror of `_archive_insert` (host engine + property tests)."""
    obj = np.concatenate([arch["obj"], np.asarray(cobj, np.float32)])
    pos = np.concatenate([arch["pos"], np.asarray(cpos, np.int32)])
    tix = np.concatenate([arch["topo"], np.asarray(ctopo, np.int32)])
    kix = np.concatenate([arch["island"], np.asarray(cisland, np.int32)])
    cvalid = np.all(np.isfinite(np.asarray(cobj, np.float32)), axis=1)
    valid = np.concatenate([arch["valid"], cvalid])

    idx = np.arange(obj.shape[0])
    both = valid[:, None] & valid[None, :]
    le = np.all(obj[:, None, :] <= obj[None, :, :], axis=-1)
    lt = np.any(obj[:, None, :] < obj[None, :, :], axis=-1)
    beaten = np.any(both & le & (lt | (idx[:, None] < idx[None, :])),
                    axis=0)
    keep = valid & ~beaten
    key = np.where(keep,
                   np.sum(np.log(np.maximum(obj, 1e-12)), axis=-1),
                   np.inf)
    top = np.argsort(key, kind="stable")[:capacity]
    kt = keep[top]
    return {"obj": np.where(kt[:, None], obj[top], np.inf),
            "pos": pos[top],
            "topo": np.where(kt, tix[top], -1),
            "island": np.where(kt, kix[top], -1),
            "valid": kt}


def _empty_archive_np(capacity: int, g: int) -> dict:
    return {"obj": np.full((capacity, 3), np.inf, np.float32),
            "pos": np.zeros((capacity, g, 2), np.int32),
            "topo": np.full((capacity,), -1, np.int32),
            "island": np.full((capacity,), -1, np.int32),
            "valid": np.zeros((capacity,), bool)}


def hypervolume(points, ref) -> float:
    """Dominated 3-D hypervolume of a minimization front w.r.t. `ref`.

    Host-side numpy (bench metric): slice the volume along the third
    objective and accumulate 2-D staircase areas — exact for any front
    size the archive can hold. Points outside the reference box are
    clipped away (they contribute nothing).
    """
    pts = np.asarray(points, np.float64).reshape(-1, 3)
    ref = np.asarray(ref, np.float64).reshape(3)
    pts = pts[np.all(np.isfinite(pts), axis=1)]
    pts = pts[np.all(pts < ref, axis=1)]
    if pts.shape[0] == 0:
        return 0.0
    pts = np.unique(pts, axis=0)
    keep = [i for i in range(len(pts))
            if not any(np.all(pts[j] <= pts[i]) and np.any(pts[j] < pts[i])
                       for j in range(len(pts)) if j != i)]
    pts = pts[keep]

    def area2d(xy):
        if xy.shape[0] == 0:
            return 0.0
        xy = xy[np.argsort(xy[:, 0], kind="stable")]
        area, y_best = 0.0, ref[1]
        for x, y in xy:
            if y < y_best:
                area += (ref[0] - x) * (y_best - y)
                y_best = y
        return area

    zs = np.unique(pts[:, 2])
    hv = 0.0
    for i, z in enumerate(zs):
        z_next = zs[i + 1] if i + 1 < len(zs) else ref[2]
        hv += area2d(pts[pts[:, 2] <= z, :2]) * (z_next - z)
    return float(hv)


# ---------------------------------------------------------------------------
# Traced-topology activation order (mesh rule with traced radix)
# ---------------------------------------------------------------------------

def _activation_order_mesh(pos, mx, my, *, a_bound: int,
                           big_bound: int) -> jax.Array:
    """`activation_order_jnp`'s mesh rule with the radix as traced data.

    `mx`/`my` are per-topology-point scalars riding the co-design scan;
    `a_bound`/`big_bound` are static grid-maximum bounds. The composite
    integer keys order identically for any bound >= the per-point exact
    one (the tie-break terms stay strictly below `a`), so the result
    matches `activation_order_jnp(pos, cfg_t)` per point exactly — pinned
    in tests/test_pareto.py.
    """
    pos = jnp.asarray(pos, jnp.int32).reshape(-1, 2)
    n = int(pos.shape[0])
    idx = jnp.arange(n, dtype=jnp.int32)
    cent2 = (jnp.abs(2 * pos[:, 0] - (mx - 1))
             + jnp.abs(2 * pos[:, 1] - (my - 1)))
    pair = jnp.sum(jnp.abs(pos[:, None, :] - pos[None, :, :]), axis=-1)
    big = jnp.int32(big_bound)
    b = n
    a = int(a_bound) * b
    taken = jnp.iinfo(jnp.int32).max

    first = jnp.argmin(cent2 * b + idx).astype(jnp.int32)
    order = jnp.zeros((n,), jnp.int32).at[0].set(first)
    selected = idx == first
    for k in range(1, n):
        dmin = jnp.min(jnp.where(selected[None, :], pair, big), axis=1)
        key = jnp.where(selected, taken, -dmin * a + cent2 * b + idx)
        nxt = jnp.argmin(key).astype(jnp.int32)
        order = order.at[k].set(nxt)
        selected = selected | (idx == nxt)
    return order


# ---------------------------------------------------------------------------
# The one-dispatch co-design core
# ---------------------------------------------------------------------------

def _codesign_core(key, topo, ov, weights, hyper, ext, mem, intra, ext_frac,
                   t_mask, dest, *, sim, generations: int, population: int,
                   migrate_every: int, archive: int, d_pad: int,
                   db_per_hop: float, moves_hi: int, a_bound: int,
                   big_bound: int) -> dict:
    """Outer scan over topology points, inner scan over generations.

    All randomness is pre-drawn in a handful of vectorized calls (the
    PR-5 lesson: threefry-per-draw inside the scan dominates CPU cost);
    the scan bodies do only arithmetic. `sim.cfg` is the PADDED shape
    (grid maxima); everything per-point arrives in `topo` as [T, ...]
    stacks consumed as outer-scan xs.
    """
    from repro.core import simulator as _sim

    g = sim.cfg.max_gateways_per_chiplet
    t_pts = int(topo["n_chiplets"].shape[0])
    k_isl = int(weights.shape[0])
    n_prop = population - 1
    r_pad = int(topo["coords"].shape[1])

    k_flag, k_perm, k_idx, k_gum, k_acc = jax.random.split(key, 5)
    restart = jax.random.bernoulli(
        k_flag, hyper["restart_frac"],
        (t_pts, generations, k_isl, n_prop))
    rest_gum = jax.random.gumbel(
        k_perm, (t_pts, generations, k_isl, n_prop, r_pad))
    move_i = jax.random.randint(
        k_idx, (t_pts, generations, k_isl, n_prop, 2), 0, g)
    move_gum = jax.random.gumbel(
        k_gum, (t_pts, generations, k_isl, n_prop, 2, r_pad))
    acc_u = jax.random.uniform(k_acc, (t_pts, generations, k_isl))

    def topo_body(arch, xs):
        tp, rst, rgum, mvi, mvg, u_all, t_idx = xs
        coords_t = tp["coords"]
        blocked_t = tp["blocked"]
        # Gumbel-top-g over the real routers = uniform g-subset without
        # replacement (restart placements, same construction as PR 5).
        gum = jnp.where(blocked_t[None, None, None, :] > 0.5, -jnp.inf,
                        rgum)
        _, ridx = jax.lax.top_k(gum, g)
        rpos = coords_t[ridx]            # [GEN, K, n_prop, g, 2]

        # Controller gateway bounds clamp to this point's gateway count —
        # the same per-point clamp sweep_topology applies on the host.
        maxg = jnp.minimum(ov["max_gateways"].astype(jnp.int32),
                           tp["g_max"])
        ming = jnp.minimum(ov["min_gateways"].astype(jnp.int32), maxg)
        ov_t = dict(ov, max_gateways=maxg, min_gateways=ming)
        topo_base = {"n_chiplets": tp["n_chiplets"], "g_max": tp["g_max"],
                     "mesh_hops": tp["mesh_hops"], "mesh_x": tp["feed"],
                     "total_gateways": tp["total_gateways"]}
        parent0 = jnp.broadcast_to(tp["default_pos"][None],
                                   (k_isl, g, 2)).astype(jnp.int32)

        def spread(p):
            return p[_activation_order_mesh(p, tp["mx"], tp["my"],
                                            a_bound=a_bound,
                                            big_bound=big_bound)]

        def gen_body(c, xs_g):
            gen, rst_g, rpos_g, mvi_g, mvg_g, u = xs_g
            parent = c["parent"]
            if migrate_every > 0:
                # Ring migration: island k adopts island k-1's incumbent.
                do_mig = (gen > 0) & (gen % migrate_every == 0)
                parent = jnp.where(do_mig,
                                   jnp.roll(c["inc_pos"], 1, axis=0),
                                   parent)
            moves = jnp.where(gen < moves_hi, 2, 1)

            def prop_one(par, r, rp, mi, mg):
                m1 = _one_move(par, mi[0], mg[0], coords_t, blocked_t)
                m2 = _one_move(m1, mi[1], mg[1], coords_t, blocked_t)
                return spread(jnp.where(r, rp,
                                        jnp.where(moves > 1, m2, m1)))

            props = jax.vmap(lambda par, r, rp, mi, mg: jax.vmap(
                functools.partial(prop_one, par))(r, rp, mi, mg))(
                    parent, rst_g, rpos_g, mvi_g, mvg_g)
            cands = jnp.concatenate([parent[:, None], props], axis=1)

            tbls = jax.vmap(jax.vmap(
                lambda p: placement_tables_from_lut_jnp(
                    p, tp["hop_lut"], tp["edge_lut"], tp["router_mask"],
                    tp["caps"], d_pad=d_pad, db_per_hop=db_per_hop)
            ))(cands)

            def score_one(tbl, o):
                tc = dict(topo_base, src_hops=tbl["src_hops"],
                          gw_loss_db=tbl["gw_loss_db"])

                def one_w(e, m, i, f, t, d):
                    out = _sim._simulate_impl(e, m, i, f, t, sim, None, o,
                                              topo=tc, dest=d)
                    return jnp.stack([out["summary"][x]
                                      for x in PARETO_OBJECTIVES])

                per_w = jax.vmap(one_w)(ext, mem, intra, ext_frac, t_mask,
                                        dest)
                return jnp.mean(per_w, axis=0)

            objs = jax.vmap(lambda tb, o: jax.vmap(
                lambda t1: score_one(t1, o))(tb))(tbls, ov_t)   # [K, P, 3]

            # Per-island normalization: this point's generation-0 parent
            # (the default placement) anchors the scalarization scale.
            norm = jnp.where(gen == 0, objs[:, 0, :], c["norm"])
            denom = jnp.maximum(jnp.abs(norm), 1e-12)
            s = jnp.sum(weights[:, None, :] * objs / denom[:, None, :],
                        axis=-1)                                 # [K, P]

            ib = jnp.argmin(s, axis=1)
            sb = jnp.take_along_axis(s, ib[:, None], axis=1)[:, 0]
            cb = jnp.take_along_axis(
                cands, ib[:, None, None, None], axis=1)[:, 0]
            improved = sb < c["inc_s"]
            inc_pos = jnp.where(improved[:, None, None], cb, c["inc_pos"])
            inc_s = jnp.minimum(sb, c["inc_s"])

            # Annealed metropolis per island (host-engine law).
            delta = sb - s[:, 0]
            rel = delta / jnp.maximum(jnp.abs(s[:, 0]), 1e-12)
            temp = (hyper["temperature"]
                    * hyper["cooling"] ** gen.astype(jnp.float32))
            metropolis = (temp > 0) & (
                u < jnp.exp(-rel / jnp.maximum(temp, 1e-30)))
            accepted = (delta < 0) | metropolis
            parent = jnp.where(accepted[:, None, None], cb, parent)

            arch_new = _archive_insert(
                c["arch"], objs.reshape(-1, 3),
                cands.reshape(-1, g, 2),
                jnp.full((k_isl * population,), t_idx, jnp.int32),
                jnp.repeat(jnp.arange(k_isl, dtype=jnp.int32), population),
                capacity=archive)
            rec = jnp.stack([jnp.sum(arch_new["valid"].astype(jnp.float32)),
                             jnp.min(inc_s)])
            return {"parent": parent, "inc_pos": inc_pos, "inc_s": inc_s,
                    "norm": norm, "arch": arch_new}, rec

        c0 = {"parent": parent0, "inc_pos": parent0,
              "inc_s": jnp.full((k_isl,), jnp.inf, jnp.float32),
              "norm": jnp.ones((k_isl, 3), jnp.float32), "arch": arch}
        cend, hist = jax.lax.scan(
            gen_body, c0,
            (jnp.arange(generations, dtype=jnp.int32), rst, rpos, mvi, mvg,
             u_all))
        return cend["arch"], (hist, cend["inc_pos"], cend["inc_s"])

    arch0 = _empty_archive(archive, g)
    arch_fin, (hist, inc_pos, inc_s) = jax.lax.scan(
        topo_body, arch0,
        (topo, restart, rest_gum, move_i, move_gum, acc_u,
         jnp.arange(t_pts, dtype=jnp.int32)))
    return {"archive": arch_fin, "history": hist,
            "island_incumbents": inc_pos, "island_scores": inc_s}

_CODESIGN_STATICS = ("sim", "generations", "population", "migrate_every",
                     "archive", "d_pad", "db_per_hop", "moves_hi",
                     "a_bound", "big_bound")


@functools.partial(jax.jit, static_argnames=_CODESIGN_STATICS)
def _codesign_jit(key, topo, ov, weights, hyper, ext, mem, intra, ext_frac,
                  t_mask, dest=None, *, sim, generations, population,
                  migrate_every, archive, d_pad, db_per_hop, moves_hi,
                  a_bound, big_bound):
    return _codesign_core(key, topo, ov, weights, hyper, ext, mem, intra,
                          ext_frac, t_mask, dest, sim=sim,
                          generations=generations, population=population,
                          migrate_every=migrate_every, archive=archive,
                          d_pad=d_pad, db_per_hop=db_per_hop,
                          moves_hi=moves_hi, a_bound=a_bound,
                          big_bound=big_bound)


def clear_codesign_caches() -> None:
    """Drop the compiled co-design executables (cold-start measurement)."""
    _codesign_jit.clear_cache()


# ---------------------------------------------------------------------------
# Grid validation + host-side preparation
# ---------------------------------------------------------------------------

def _check_codesign_params(generations, population, migrate_every,
                           archive) -> None:
    if population < 2:
        raise ValueError("population must be >= 2 (incumbent + candidates)")
    if generations < 1:
        raise ValueError("generations must be >= 1")
    if migrate_every < 0:
        raise ValueError("migrate_every must be >= 0 (0 disables migration)")
    if archive < 1:
        raise ValueError("archive must be >= 1")


def _check_topology_grids(sim, topo_grids: dict):
    """Pre-jit topology-axis validation with actionable messages.

    Returns (cs, gs, rs) integer lists of one shared length T (T=1 for an
    empty grid: placement x knob search on the base topology).
    """
    from repro.core import simulator as _sim

    cfg = sim.cfg
    if cfg.coords is not None:
        raise ValueError(
            "search_codesign sweeps derived-mesh topology grids; explicit-"
            "coords layouts (NetworkConfig.coords) fix the topology — "
            "search placements there with search_placement_islands")
    if "gateway_positions" in topo_grids:
        raise ValueError(
            "gateway_positions is not a co-design axis: placements are "
            "SEARCHED per topology point, not swept (pin one with "
            "sweep_topology instead)")
    unknown = set(topo_grids) - set(CODESIGN_TOPOLOGY_FIELDS)
    runtime = unknown & set(SWEEPABLE_FIELDS)
    if runtime:
        raise ValueError(
            f"runtime fields {sorted(runtime)} zip with the island axis — "
            f"pass them via knob_grids={{field: [K values]}}, not as "
            f"topology grids")
    if unknown:
        raise ValueError(
            f"non-sweepable fields: {sorted(unknown)} (co-design topology "
            f"axes: {CODESIGN_TOPOLOGY_FIELDS}; runtime knobs ride "
            f"knob_grids)")
    lengths = {k: _sim._grid_len(k, v) for k, v in topo_grids.items()}
    if lengths and len(set(lengths.values())) != 1:
        raise ValueError(
            f"topology grids must share one length, got {lengths}")
    t_pts = next(iter(lengths.values())) if lengths else 1
    cs = [int(x) for x in topo_grids.get("n_chiplets",
                                         [cfg.n_chiplets] * t_pts)]
    gs = [int(x) for x in topo_grids.get(
        "gateways_per_chiplet", [cfg.max_gateways_per_chiplet] * t_pts)]
    rs = [int(x) for x in topo_grids.get("mesh_radix",
                                         [cfg.mesh_x] * t_pts)]
    if min(cs) < 1 or min(gs) < 1 or min(rs) < 2:
        raise ValueError(f"invalid topology grid: n_chiplets {cs}, "
                         f"gateways {gs}, radix {rs}")
    if len(set(gs)) != 1:
        raise ValueError(
            f"gateways_per_chiplet must be constant across a co-design "
            f"grid (got {gs}): the placement axis is [g, 2] per candidate "
            f"and cannot change width mid-scan — trade gateway counts at "
            f"runtime with knob_grids={{'max_gateways': [...]}} instead")
    g = gs[0]
    if g > N_DEFAULT_EDGE_SLOTS:
        raise ValueError(
            f"gateways_per_chiplet={g} exceeds the {N_DEFAULT_EDGE_SLOTS} "
            f"default edge slots that seed the search")
    for i, r in enumerate(rs):
        if g > r * r:
            raise ValueError(
                f"grid point {i}: gateways_per_chiplet={g} exceeds the "
                f"{r}x{r} mesh's {r * r} routers")
    return cs, gs, rs


def _check_knob_grids(knob_grids, islands):
    """Pre-jit knob validation. Returns (knobs dict of lists, islands)."""
    from repro.core import simulator as _sim

    if islands is not None and (isinstance(islands, bool)
                                or not isinstance(islands,
                                                  (int, np.integer))):
        raise ValueError(
            f"islands must be an int, got {type(islands).__name__} "
            f"{islands!r}")
    knobs = dict(knob_grids or {})
    unknown = set(knobs) - set(SWEEPABLE_FIELDS)
    if unknown:
        topo = unknown & set(TOPOLOGY_SWEEPABLE_FIELDS)
        if topo:
            raise ValueError(
                f"topology fields {sorted(topo)} are grid axes, not island "
                f"knobs — pass them as keyword grids "
                f"(search_codesign(tr, sim, n_chiplets=[...]))")
        raise ValueError(
            f"non-sweepable knob fields: {sorted(unknown)} (runtime knobs: "
            f"{SWEEPABLE_FIELDS})")
    lengths = {f: _sim._grid_len(f, v) for f, v in knobs.items()}
    if islands is None:
        if lengths:
            if len(set(lengths.values())) != 1:
                raise ValueError(
                    f"knob grids must share one length, got {lengths}")
            islands = next(iter(lengths.values()))
        else:
            islands = 8
    bad = {f: n for f, n in lengths.items() if n != islands}
    if bad:
        raise ValueError(
            f"knob grids must have length islands={islands}, got {bad} — "
            f"every knob grid zips element-wise with the island axis")
    if islands < 1:
        raise ValueError("islands must be >= 1")
    return {f: list(np.asarray(v).tolist()) for f, v in knobs.items()}, \
        int(islands)


def _prepare_codesign(sim, cs, gs, rs):
    """Padded per-topology stacks + the padded static config.

    Everything shape-defining is padded to the grid maxima and stacked
    [T, ...] so the whole grid rides one executable as outer-scan xs;
    validity masks (`router_mask`, `blocked`) make padded router rows
    provably inert (a blocked row is never proposed, a masked row never
    contributes to a table mean).
    """
    from repro.core import topology
    from repro.core.noc import uniform_mesh_mean_hops

    cfg = sim.cfg
    g = gs[0]
    cfgs = tuple(cfg.with_topology(n_chiplets=c, gateways_per_chiplet=g,
                                   mesh_radix=r)
                 for c, r in zip(cs, rs))
    t_pts = len(cfgs)
    c_max = max(cs)
    shapes = [topology.lut_shape(c) for c in cfgs]
    x_max = max(s[0] for s in shapes)
    y_max = max(s[1] for s in shapes)
    r_max = max(c.routers_per_chiplet for c in cfgs)
    d_pad = max(topology.max_hops(c) for c in cfgs) + 1
    a_bound = max(topology.centrality_bound(c) for c in cfgs)
    big_bound = 4 * (x_max + y_max)

    hop = np.full((t_pts, r_max, x_max, y_max), d_pad, np.int32)
    edge = np.zeros((t_pts, x_max, y_max), np.int32)
    rmask = np.zeros((t_pts, r_max), np.float32)
    caps = np.zeros((t_pts, g), np.int32)
    coords = np.zeros((t_pts, r_max, 2), np.int32)
    blocked = np.ones((t_pts, r_max), np.float32)
    dpos = np.zeros((t_pts, g, 2), np.int32)
    for t, c in enumerate(cfgs):
        r_t = c.routers_per_chiplet
        bx, by = topology.lut_shape(c)
        hop[t, :r_t, :bx, :by] = topology.hop_lut(c)
        edge[t, :bx, :by] = topology.edge_lut(c)
        rmask[t, :r_t] = 1.0
        caps[t] = [-(-r_t // lvl) for lvl in range(1, g + 1)]
        coords[t, :r_t] = topology.router_coords(c)
        blocked[t, :r_t] = 0.0
        dpos[t] = normalize_placement(resolve_gateway_positions(c), c)

    topo = {
        "n_chiplets": jnp.asarray(cs, jnp.int32),
        "g_max": jnp.asarray(gs, jnp.int32),
        "mesh_hops": jnp.asarray(
            [uniform_mesh_mean_hops(c) for c in cfgs], jnp.float32),
        "feed": jnp.asarray(
            [topology.feed_width(c) for c in cfgs], jnp.float32),
        "total_gateways": jnp.asarray(
            [c.total_gateways for c in cfgs], jnp.float32),
        "mx": jnp.asarray([c.mesh_x for c in cfgs], jnp.int32),
        "my": jnp.asarray([c.mesh_y for c in cfgs], jnp.int32),
        "hop_lut": jnp.asarray(hop),
        "edge_lut": jnp.asarray(edge),
        "router_mask": jnp.asarray(rmask),
        "caps": jnp.asarray(caps),
        "coords": jnp.asarray(coords),
        "blocked": jnp.asarray(blocked),
        "default_pos": jnp.asarray(dpos),
    }
    sim_padded = dataclasses.replace(sim, cfg=dataclasses.replace(
        cfg, n_chiplets=c_max, max_gateways_per_chiplet=g, mesh_x=x_max,
        mesh_y=y_max, gateway_positions=None))
    db_per_hop = float(cfg.router_pitch_mm
                       * PHOTONIC_POWER.waveguide_db_per_mm)
    statics = dict(d_pad=int(d_pad), db_per_hop=db_per_hop,
                   a_bound=int(a_bound), big_bound=int(big_bound))
    return sim_padded, topo, cfgs, c_max, statics


def _codesign_batch(trace, c_max):
    """Accept a trace dict, a stacked batch, or a list of W workloads."""
    from repro.core import simulator as _sim

    if isinstance(trace, dict) and jnp.ndim(trace["ext_load"]) == 3:
        batch = trace
    else:
        batch = stack_traces(
            list(trace) if isinstance(trace, (list, tuple)) else [trace],
            pad=True)
    return _sim._topo_trace_arrays(batch, c_max), batch


def _knob_overrides(knobs: dict, islands: int, sim) -> Dict[str, jax.Array]:
    """[K] override arrays; gateway bounds always present (clamped per
    topology point inside the scan, mirroring sweep_topology)."""
    ov = {f: jnp.asarray(v) for f, v in knobs.items()}
    user_max = ov.pop("max_gateways", jnp.int32(sim.ctl.max_gateways))
    user_min = ov.pop("min_gateways", jnp.int32(sim.ctl.min_gateways))
    ov["max_gateways"] = jnp.broadcast_to(
        jnp.asarray(user_max, jnp.int32), (islands,))
    ov["min_gateways"] = jnp.broadcast_to(
        jnp.asarray(user_min, jnp.int32), (islands,))
    return ov


def _codesign_operands(trace, sim, *, islands: int = None,
                       generations: int = 10, population: int = 8,
                       migrate_every: int = 4, archive: int = 32,
                       knob_grids: Optional[dict] = None, seed: int = 0,
                       temperature: float = 0.05, cooling: float = 0.7,
                       restart_frac: float = 0.25, **topo_grids):
    """(operands, statics, info): exactly what the device engine feeds
    `_codesign_jit`. Shared by `search_codesign` and the runtime cache's
    "search" AOT builder, so a pre-compiled executable is guaranteed to
    see operands identical to the jit path's."""
    _check_codesign_params(generations, population, migrate_every, archive)
    cs, gs, rs = _check_topology_grids(sim, topo_grids)
    knobs, islands = _check_knob_grids(knob_grids, islands)
    sim_p, topo, _cfgs, c_max, statics = _prepare_codesign(sim, cs, gs, rs)
    (ext, mem, intra, ext_frac, t_mask, dest), _batch = \
        _codesign_batch(trace, c_max)
    ov = _knob_overrides(knobs, islands, sim)
    weights = jnp.asarray(island_weights(islands))
    hyper = {"temperature": jnp.float32(temperature),
             "cooling": jnp.float32(cooling),
             "restart_frac": jnp.float32(restart_frac)}
    key = jax.random.PRNGKey(seed)
    static = dict(sim=sim_p, generations=generations, population=population,
                  migrate_every=migrate_every, archive=archive,
                  moves_hi=max(1, generations // 3), **statics)
    info = {"cs": cs, "gs": gs, "rs": rs, "knobs": knobs,
            "islands": islands, "workloads": int(ext.shape[0])}
    return ((key, topo, ov, weights, hyper, ext, mem, intra, ext_frac,
             t_mask, dest), static, info)


def _as_placement(pos) -> tuple:
    return tuple((int(x), int(y)) for x, y in np.asarray(pos))


def _codesign_result(arch: dict, hist, inc_pos, inc_s, weights, cs, gs, rs,
                     knobs, islands, engine, meta) -> dict:
    """Shared device/host result assembly (host-side numpy)."""
    obj = np.asarray(arch["obj"], np.float64)
    pos = np.asarray(arch["pos"])
    tix = np.asarray(arch["topo"])
    kix = np.asarray(arch["island"])
    valid = np.asarray(arch["valid"])
    front = []
    for i in range(obj.shape[0]):
        if not valid[i]:
            continue
        t, k = int(tix[i]), int(kix[i])
        entry = {
            "objectives": dict(zip(("latency", "power_mw", "energy"),
                                   (float(v) for v in obj[i]))),
            "placement": _as_placement(pos[i]),
            "topology": {"n_chiplets": cs[t],
                         "gateways_per_chiplet": gs[t],
                         "mesh_radix": rs[t]},
            "knobs": {f: v[k] for f, v in knobs.items()},
            "topology_index": t,
            "island": k,
        }
        front.append(entry)
    front.sort(key=lambda e: (e["objectives"]["latency"],
                              e["objectives"]["power_mw"],
                              e["objectives"]["energy"]))
    hist = np.asarray(hist, np.float64)
    out = {
        "front": front,
        "objectives": PARETO_OBJECTIVES,
        "archive": {"objectives": obj, "valid": valid,
                    "topology_index": tix, "island": kix,
                    "placements": [_as_placement(p) for p in pos]},
        "history": {k: hist[..., i]
                    for i, k in enumerate(CODESIGN_HISTORY_KEYS)},
        "island_incumbents": [[_as_placement(p) for p in per_t]
                              for per_t in np.asarray(inc_pos)],
        "island_scores": np.asarray(inc_s, np.float64),
        "weights": np.asarray(weights, np.float64),
        "grid": {"n_chiplets": list(cs),
                 "gateways_per_chiplet": list(gs),
                 "mesh_radix": list(rs)},
        "knob_grids": {f: list(v) for f, v in knobs.items()},
        "islands": islands,
        "engine": engine,
    }
    out.update(meta)
    return out


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------

def search_codesign(trace, sim, *, islands: int = None,
                    generations: int = 10, population: int = 8,
                    migrate_every: int = 4, archive: int = 32,
                    knob_grids: Optional[dict] = None, seed: int = 0,
                    temperature: float = 0.05, cooling: float = 0.7,
                    restart_frac: float = 0.25, engine: str = "device",
                    devices=None, **topo_grids) -> dict:
    """Joint topology x placement x knob Pareto search, ONE dispatch.

    ::

        search_codesign(traces, sim,
                        n_chiplets=[64, 144, 256],
                        mesh_radix=[4, 4, 4],
                        knob_grids={"l_m": [0.008, 0.012, 0.02, 0.03]},
                        islands=4)

    Topology axes (`n_chiplets` / `gateways_per_chiplet` / `mesh_radix`)
    are zipped length-T grids scanned by an outer `lax.scan`; within each
    point, K annealed island chains (PR-5 semantics: collision-free
    moves + restarts, spread ordering, annealed metropolis acceptance)
    search placements under K scalarization weight vectors, zipped with
    optional length-K `knob_grids` runtime overrides. Every scored
    candidate feeds a device-resident Pareto archive over
    (mean_latency, mean_power_mw, mean_energy); islands exchange
    incumbents on a ring every `migrate_every` generations. `trace` is a
    single trace dict or a list of W workload traces (objectives average
    over workloads). The whole search compiles to ONE executable launch
    (`engine_stats()["search_dispatches"]` += 1) and the final result
    pytree is the only device->host transfer.

    `engine="host"` runs the identical searcher semantics as a
    host-driven loop over `sweep_topology_batch` (the parity oracle —
    different PRNG streams, same scoring path, same archive rules).
    Pass `devices` (more than one) to shard the island axis via
    `GridSharding` when islands divide the device count evenly.

    Returns the Pareto front as `"front"` entries — each a (topology,
    placement, knobs, objectives) record — plus the raw archive,
    per-(topology, generation) history, island incumbents/weights and
    the searched grids.
    """
    from repro.core import simulator as _sim

    if engine not in ("device", "host"):
        raise ValueError(f"unknown engine {engine!r} (device|host)")
    _check_codesign_params(generations, population, migrate_every, archive)
    cs, gs, rs = _check_topology_grids(sim, topo_grids)
    knobs, islands = _check_knob_grids(knob_grids, islands)

    if engine == "host":
        return _host_codesign(
            trace, sim, cs, gs, rs, knobs, islands,
            generations=generations, population=population,
            migrate_every=migrate_every, archive=archive, seed=seed,
            temperature=temperature, cooling=cooling,
            restart_frac=restart_frac)

    built, static, info = _codesign_operands(
        trace, sim, islands=islands, generations=generations,
        population=population, migrate_every=migrate_every, archive=archive,
        knob_grids=knob_grids, seed=seed, temperature=temperature,
        cooling=cooling, restart_frac=restart_frac, **topo_grids)
    (key, topo, ov, weights, hyper, ext, mem, intra, ext_frac, t_mask,
     dest) = built
    w_axis = info["workloads"]

    devices = list(devices if devices is not None else jax.devices())
    res = None
    sharding = None
    if len(devices) > 1 and islands % len(devices) == 0:
        try:
            from repro.core.distributed import GridSharding

            gsh = GridSharding(islands, devices=devices,
                               logical_axis="islands")
            ov_s, w_s = gsh.shard((ov, weights))
            topo_r, hyper_r, ext_r, mem_r, intra_r, frac_r, mask_r, \
                dest_r = gsh.replicate((topo, hyper, ext, mem, intra,
                                        ext_frac, t_mask, dest))
            res = _codesign_jit(key, topo_r, ov_s, w_s, hyper_r, ext_r,
                                mem_r, intra_r, frac_r, mask_r, dest_r,
                                **static)
            sharding = gsh.describe()
        except Exception as e:  # pragma: no cover - device-layout dependent
            import warnings
            warnings.warn(f"sharded co-design search failed ({e!r}); "
                          f"falling back to single-device path")
            res = None
            sharding = None
    if res is None:
        res = _codesign_jit(key, topo, ov, weights, hyper, ext, mem, intra,
                            ext_frac, t_mask, dest, **static)
    # Counted after the launch (PR-5 convention): a raising compile never
    # inflates the one-search == one-dispatch stats.
    _sim._STATS["search_dispatches"] += 1
    host = jax.device_get(res)          # the ONE transfer for the search

    meta = {"generations": generations, "population": population,
            "migrate_every": migrate_every, "archive_capacity": archive,
            "workloads": w_axis,
            "candidate_evals": len(cs) * generations * islands
            * population * w_axis}
    if sharding is not None:
        meta["sharding"] = sharding
    return _codesign_result(host["archive"], host["history"],
                            host["island_incumbents"],
                            host["island_scores"], np.asarray(weights),
                            cs, gs, rs, knobs, islands, "device", meta)


# ---------------------------------------------------------------------------
# Host engine (parity oracle) + front re-scoring
# ---------------------------------------------------------------------------

def _host_propose(parent, cfg_t, coords, rng, moves, restart_frac, g):
    """One host candidate: restart or 1-2 collision-free moves, spread-
    ordered — the device proposal semantics with numpy randomness."""
    if rng.rand() < restart_frac:
        idx = rng.choice(len(coords), size=g, replace=False)
        pos = [coords[int(i)] for i in idx]
    else:
        pos = list(parent)
        for _ in range(moves):
            i = int(rng.randint(g))
            occupied = set(pos)
            free = [c for c in coords if c not in occupied]
            if not free:
                break
            pos[i] = free[int(rng.randint(len(free)))]
    return normalize_placement(pos, cfg_t, order="spread")


def _host_codesign(trace, sim, cs, gs, rs, knobs, islands, *, generations,
                   population, migrate_every, archive, seed, temperature,
                   cooling, restart_frac) -> dict:
    """Host-driven mirror of the device search (the parity oracle).

    Identical searcher semantics — same migration/acceptance/archive
    rules, same per-point knob clamps (delegated to `sweep_topology_batch`
    whose `_prepare_topology_sweep` applies them) — with numpy randomness
    and one public sweep call per (topology point, generation). Same
    return structure as the device engine; the PRNG streams differ, so
    the two engines walk different, equally valid trajectories.
    """
    from repro.core import simulator as _sim
    from repro.core import topology

    g = gs[0]
    cfg = sim.cfg
    cfgs = [cfg.with_topology(n_chiplets=c, gateways_per_chiplet=g,
                              mesh_radix=r) for c, r in zip(cs, rs)]
    if isinstance(trace, dict) and jnp.ndim(trace["ext_load"]) == 3:
        batch = trace
    else:
        batch = stack_traces(
            list(trace) if isinstance(trace, (list, tuple)) else [trace],
            pad=True)
    w_axis = int(jnp.shape(batch["ext_load"])[0])
    weights = island_weights(islands).astype(np.float64)
    rng = np.random.RandomState(seed)
    moves_hi = max(1, generations // 3)
    lanes = islands * population
    arch = _empty_archive_np(archive, g)
    hist = np.zeros((len(cfgs), generations, len(CODESIGN_HISTORY_KEYS)))
    inc_pos_all, inc_s_all = [], []

    for t, cfg_t in enumerate(cfgs):
        coords = [tuple(int(v) for v in c)
                  for c in topology.router_coords(cfg_t)]
        dflt = normalize_placement(resolve_gateway_positions(cfg_t), cfg_t)
        parent = [dflt] * islands
        inc_pos = list(parent)
        inc_s = np.full((islands,), np.inf)
        norm = np.ones((islands, 3))
        for gen in range(generations):
            if migrate_every > 0 and gen > 0 \
                    and gen % migrate_every == 0:
                parent = [inc_pos[(k - 1) % islands]
                          for k in range(islands)]
            moves = 2 if gen < moves_hi else 1
            cands = [[parent[k]]
                     + [_host_propose(parent[k], cfg_t, coords, rng,
                                      moves, restart_frac, g)
                        for _ in range(population - 1)]
                     for k in range(islands)]

            grids = {"n_chiplets": [cs[t]] * lanes,
                     "gateways_per_chiplet": [g] * lanes,
                     "mesh_radix": [rs[t]] * lanes,
                     "gateway_positions": [cands[k][p]
                                           for k in range(islands)
                                           for p in range(population)]}
            for f, vals in knobs.items():
                grids[f] = [vals[k] for k in range(islands)
                            for _ in range(population)]
            out = _sim.sweep_topology_batch(batch, sim, **grids)
            objs = np.stack(
                [np.asarray(out["summary"][m], np.float64).mean(axis=0)
                 for m in PARETO_OBJECTIVES],
                axis=-1).reshape(islands, population, 3)

            if gen == 0:
                norm = objs[:, 0, :].copy()
            denom = np.maximum(np.abs(norm), 1e-12)
            s = np.sum(weights[:, None, :] * objs / denom[:, None, :],
                       axis=-1)
            ib = np.argmin(s, axis=1)
            sb = s[np.arange(islands), ib]
            cb = [cands[k][int(ib[k])] for k in range(islands)]
            for k in range(islands):
                if sb[k] < inc_s[k]:
                    inc_s[k] = sb[k]
                    inc_pos[k] = cb[k]
            u = rng.rand(islands)
            temp = temperature * cooling ** gen
            for k in range(islands):
                delta = sb[k] - s[k, 0]
                rel = delta / max(abs(s[k, 0]), 1e-12)
                metropolis = temp > 0 \
                    and u[k] < np.exp(-rel / max(temp, 1e-30))
                if delta < 0 or metropolis:
                    parent[k] = cb[k]
            arch = _archive_insert_np(
                arch, objs.reshape(-1, 3),
                np.asarray([cands[k][p] for k in range(islands)
                            for p in range(population)], np.int32),
                np.full((lanes,), t, np.int32),
                np.repeat(np.arange(islands, dtype=np.int32), population),
                archive)
            hist[t, gen] = [float(np.sum(arch["valid"])),
                            float(np.min(inc_s))]
        inc_pos_all.append([np.asarray(p, np.int32) for p in inc_pos])
        inc_s_all.append(inc_s.copy())

    meta = {"generations": generations, "population": population,
            "migrate_every": migrate_every, "archive_capacity": archive,
            "workloads": w_axis,
            "candidate_evals": len(cfgs) * generations * islands
            * population * w_axis}
    return _codesign_result(arch, hist, np.asarray(inc_pos_all),
                            np.asarray(inc_s_all), weights, cs, gs, rs,
                            knobs, islands, "host", meta)


def rescore_front_host(result, trace, sim) -> np.ndarray:
    """Re-score a co-design front through the public host sweep path.

    Every front entry becomes one `sweep_topology_batch` lane — its
    topology point, its (already spread-ordered) placement pinned via the
    `gateway_positions` axis, its island knobs as runtime-override lanes
    — and the per-workload objective summaries average exactly like the
    in-scan scoring. The returned [n_front, 3] array matches the device
    archive's objectives to float tolerance (the 1e-6 parity oracle in
    tests/test_pareto.py): same masked scan body, reached through a
    completely different (host-prepared, unfused) path.
    """
    from repro.core import simulator as _sim

    entries = result["front"]
    if not entries:
        return np.zeros((0, 3), np.float64)
    if isinstance(trace, dict) and jnp.ndim(trace["ext_load"]) == 3:
        batch = trace
    else:
        batch = stack_traces(
            list(trace) if isinstance(trace, (list, tuple)) else [trace],
            pad=True)
    grids = {
        "n_chiplets": [e["topology"]["n_chiplets"] for e in entries],
        "gateways_per_chiplet": [e["topology"]["gateways_per_chiplet"]
                                 for e in entries],
        "mesh_radix": [e["topology"]["mesh_radix"] for e in entries],
        "gateway_positions": [e["placement"] for e in entries],
    }
    for f in result.get("knob_grids", {}):
        grids[f] = [e["knobs"][f] for e in entries]
    out = _sim.sweep_topology_batch(batch, sim, **grids)
    return np.stack(
        [np.asarray(out["summary"][m], np.float64).mean(axis=0)
         for m in PARETO_OBJECTIVES], axis=-1)
