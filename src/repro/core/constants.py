"""Physical and simulation constants.

Paper-side constants come from ReSiPI Table 1 and §4.1 (power model inherited
from PROWAVES [16]/Polster [19]); TPU-side constants are the v5e targets used
by the roofline analysis (§Roofline in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# ReSiPI paper constants (Table 1 + §4.1 + §4.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PhotonicPower:
    """Silicon-photonic power model (PROWAVES model, §4.1)."""

    laser_mw_per_wavelength: float = 30.0   # per wavelength per waveguide
    tia_mw: float = 2.0                     # per active photodiode/receiver
    tuning_mw_per_mr: float = 3.0           # thermal tuning per active MR
    driver_mw: float = 3.0                  # per active modulator driver
    pcmc_reconfig_nj: float = 2.0           # PCM switch reconfiguration energy
    pcmc_reconfig_cycles: int = 100         # 100 ns @ 1 GHz (Kato et al. [10])
    laser_tune_cycles: int = 1              # SOA laser power tuning: 20-50 ps
    awgr_loss_db: float = 1.8               # AWGR insertion loss (§4.4)
    controller_lgc_uw: float = 172.0        # Table 2, per-chiplet local ctl
    controller_inc_uw: float = 787.0        # Table 2, interposer controller
    # Access-waveguide propagation loss from a gateway's TSV/coupler down to
    # the interposer waveguide: ~3 dB/cm for standard SOI strip waveguides.
    # An edge-placed gateway pays ~0; an interior placement pays its distance
    # to the nearest chiplet edge — the placement latency/power trade-off.
    waveguide_db_per_mm: float = 0.3


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """2.5D system topology (Table 1)."""

    n_chiplets: int = 4
    mesh_x: int = 4                         # intra-chiplet mesh is 4x4
    mesh_y: int = 4
    max_gateways_per_chiplet: int = 4       # ReSiPI / AWGR
    memory_gateways: int = 2                # gateways for memory controllers
    gateway_buffer_flits: int = 8           # ReSiPI/AWGR (PROWAVES uses 32)
    router_buffer_flits: int = 4
    noc_freq_ghz: float = 1.0
    link_gbps_per_wavelength: float = 12.0  # optical data rate
    flit_bits: int = 32
    packet_flits: int = 8
    reconfig_interval_cycles: int = 1_000_000
    sim_cycles: int = 100_000_000
    warmup_cycles: int = 10_000
    # Gateway-attached router coordinates on the chiplet mesh, in activation
    # order (row k lights up at activation level k+1). None selects the
    # edge-distributed default scheme (selection.default_gateway_positions);
    # an explicit value is a tuple of (x, y) pairs — kept hashable so the
    # config stays a valid static jit key and an lru_cache key, which is what
    # makes placement a compile-free DSE axis (sweep_placement).
    gateway_positions: Optional[Tuple[Tuple[int, int], ...]] = None
    router_pitch_mm: float = 1.0            # mesh tile pitch (waveguide mm/hop)
    # Arbitrary router-layout model (PR 10). `coords=None` keeps the derived
    # mesh_x x mesh_y grid — every distance/table builder then uses the exact
    # mesh closed forms (bit parity with the pre-coords code). An explicit
    # `coords` tuple of (x, y) pairs pins an arbitrary layout whose adjacency
    # is given by `coord_model` ("mesh": 4-neighbor grid steps; "hex":
    # 6-neighbor axial steps — see repro.core.topology). Kept hashable for
    # the same static-jit-key reasons as gateway_positions.
    coord_model: str = "mesh"
    coords: Optional[Tuple[Tuple[int, int], ...]] = None

    def __post_init__(self):
        if self.gateway_positions is not None:
            try:
                norm = tuple((int(x), int(y))
                             for x, y in self.gateway_positions)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    "gateway_positions must be a sequence of (x, y) pairs, "
                    f"got {self.gateway_positions!r}") from e
            object.__setattr__(self, "gateway_positions", norm)
        if self.coords is not None:
            try:
                norm = tuple((int(x), int(y)) for x, y in self.coords)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    "coords must be a sequence of (x, y) pairs, "
                    f"got {self.coords!r}") from e
            if not norm:
                raise ValueError("coords must name at least one router; "
                                 "use None for the derived mesh layout")
            object.__setattr__(self, "coords", norm)

    @property
    def routers_per_chiplet(self) -> int:
        if self.coords is not None:
            return len(self.coords)
        return self.mesh_x * self.mesh_y

    @property
    def packet_bits(self) -> int:
        return self.packet_flits * self.flit_bits

    @property
    def total_gateways(self) -> int:
        """All chiplet gateways + memory-controller gateways (18 in Table 1)."""
        return (self.n_chiplets * self.max_gateways_per_chiplet
                + self.memory_gateways)

    def with_topology(self, *, n_chiplets: int | None = None,
                      gateways_per_chiplet: int | None = None,
                      mesh_radix: int | None = None) -> "NetworkConfig":
        """Topology-DSE variant: one grid point of a `sweep_topology` scan.

        `mesh_radix` sets a square r x r intra-chiplet mesh. These are the
        shape-defining topology axes (TOPOLOGY_SWEEPABLE_FIELDS in
        repro.core.simulator); everything else is inherited. A radix change
        invalidates any explicit `gateway_positions` (coordinates belong to
        the old mesh), so it resets them to the default edge scheme — pin a
        per-radix placement via `with_placement` / the `gateway_positions`
        sweep axis instead.
        """
        kw = {}
        if n_chiplets is not None:
            kw["n_chiplets"] = int(n_chiplets)
        if gateways_per_chiplet is not None:
            kw["max_gateways_per_chiplet"] = int(gateways_per_chiplet)
        if mesh_radix is not None:
            kw["mesh_x"] = int(mesh_radix)
            kw["mesh_y"] = int(mesh_radix)
            if int(mesh_radix) != self.mesh_x \
                    or int(mesh_radix) != self.mesh_y \
                    or self.coords is not None:
                # An actual radix change: the placement's coordinates
                # belong to the old mesh, so reset to the default scheme.
                # Likewise a radix request on an explicit-coords config
                # asks for the derived r x r grid, dropping the layout.
                kw["gateway_positions"] = None
                kw["coords"] = None
        return dataclasses.replace(self, **kw)

    def with_placement(self, positions) -> "NetworkConfig":
        """Placement-DSE variant: pin explicit gateway coordinates.

        `positions` is a sequence of (x, y) router coordinates in activation
        order (None restores the default edge scheme); normalization to a
        hashable tuple happens in `__post_init__`. Validation (bounds,
        collisions, enough slots for `max_gateways_per_chiplet`) happens in
        `selection.resolve_gateway_positions` when tables are built.
        """
        return dataclasses.replace(self, gateway_positions=positions)

    def gateway_service_cycles(self, wavelengths: int) -> float:
        """Cycles to serialize one packet through a gateway with W wavelengths.

        bits/cycle = W * (link_gbps / freq_ghz); one packet = packet_bits.
        """
        bits_per_cycle = wavelengths * (self.link_gbps_per_wavelength
                                        / self.noc_freq_ghz)
        return self.packet_bits / bits_per_cycle


# Architecture-variant wavelength budgets (§4.1): PROWAVES uses up to 16
# wavelengths on a single gateway per chiplet; ReSiPI uses 4 wavelengths on up
# to 4 gateways per chiplet (equal peak bisection bandwidth); AWGR statically
# uses one wavelength per port (18 total).
RESIPI_WAVELENGTHS = 4
PROWAVES_MAX_WAVELENGTHS = 16
PROWAVES_MIN_WAVELENGTHS = 4   # Fig. 12.d floor: PROWAVES never drops below
                               # ~4 active wavelengths on its single gateway
AWGR_WAVELENGTHS = 18

# The paper's empirically selected maximum allowable gateway load (§4.2),
# in packets/cycle/gateway, chosen accepting <=10% latency overhead.
PAPER_L_M = 0.0152


# ---------------------------------------------------------------------------
# TPU v5e roofline constants (targets for the dry-run analysis)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUv5e:
    peak_bf16_flops: float = 197e12        # FLOP/s per chip
    hbm_bytes_per_s: float = 819e9         # HBM bandwidth per chip
    ici_bytes_per_s_per_link: float = 50e9 # ICI per link
    hbm_bytes: int = 16 * 1024 ** 3        # 16 GiB HBM per chip
    vmem_bytes: int = 128 * 1024 ** 2      # ~128 MiB VMEM
    mxu_dim: int = 128                     # systolic array tile


PHOTONIC_POWER = PhotonicPower()
NETWORK = NetworkConfig()
TPU = TPUv5e()
