"""Multi-process (fleet) execution layer for the DSE sweeps.

One process per host, every process running the SAME program: this module
brings up `jax.distributed`, builds a 1-D "grid" mesh over every device in
the fleet, and shards the leading grid axis of a sweep's inputs across it
(NamedSharding / GSPMD), so `shard_sweep`, `sweep_workload`, and
`search_placement_islands` partition their vmapped lanes over hosts with
the same executable they run on one device. Three rules keep it honest:

  * single-host fallback everywhere — with one process and one device every
    helper is a passthrough, so the engine's behaviour (and every existing
    test) is unchanged;
  * all processes construct identical host-side grids (deterministic from
    the seed), so sharding is a pure data-placement decision: each process
    materializes only the rows its devices own (`make_array_from_callback`)
    and closed-over arrays are replicated explicitly;
  * no silent padding — the grid is padded to a device-count multiple by
    repeating the last point, and the pad count is logged and surfaced in
    the sweep's returned summary (`GridSharding.describe`).

The logical->mesh axis mapping rides the MaxText-style rules table
(`repro.sharding.rules`): the DSE axes "sweep" and "islands" both resolve
to the fleet mesh's "grid" axis.
"""
from __future__ import annotations

import logging
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import Rules

log = logging.getLogger("repro.distributed")

# Environment contract between the fleet launcher and its workers
# (repro.launch.fleet sets these before spawning each worker process).
ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_STATE = {"initialized": False, "info": None}


def init_distributed(*, coordinator: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None,
                     collectives: str = "gloo") -> dict:
    """Join (or skip) the fleet: `jax.distributed.initialize` from explicit
    args or the REPRO_* environment, with a single-process no-op fallback.

    MUST run before anything touches the jax backend (device queries,
    any jit) — both the coordinator handshake and the CPU collectives
    implementation bind at backend initialization. `collectives` selects
    the CPU cross-process collective transport ("gloo" is the portable
    default); non-CPU backends ignore it. Idempotent: the second call
    returns the first call's info.
    """
    if _STATE["initialized"]:
        return dict(_STATE["info"])
    env = os.environ
    coordinator = coordinator or env.get(ENV_COORDINATOR)
    if num_processes is None:
        num_processes = int(env.get(ENV_NUM_PROCESSES, "1"))
    if process_id is None:
        process_id = int(env.get(ENV_PROCESS_ID, "0"))
    if num_processes <= 1 or coordinator is None:
        info = {"distributed": False, "coordinator": None,
                "num_processes": 1, "process_id": 0}
        _STATE.update(initialized=True, info=info)
        return dict(info)
    if not 0 <= process_id < num_processes:
        raise ValueError(f"process_id {process_id} out of range for "
                         f"{num_processes} processes")
    if collectives:
        try:  # must land before the CPU client exists; older jax: no knob
            jax.config.update("jax_cpu_collectives_implementation",
                              collectives)
        except Exception:  # pragma: no cover - jax version dependent
            log.warning("could not select %r CPU collectives", collectives)
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    info = {"distributed": True, "coordinator": coordinator,
            "num_processes": num_processes, "process_id": process_id}
    _STATE.update(initialized=True, info=info)
    log.info("joined fleet: process %d/%d via %s (%d global devices)",
             process_id, num_processes, coordinator, len(jax.devices()))
    return dict(info)


def shutdown_distributed() -> None:
    """Leave the fleet (tests / clean worker exit); no-op if never joined."""
    if _STATE["initialized"] and _STATE["info"]["distributed"]:
        jax.distributed.shutdown()
    _STATE.update(initialized=False, info=None)


def is_distributed() -> bool:
    """More than one process in this jax runtime?"""
    return jax.process_count() > 1


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def partition_bounds(grid_points: int, num_shards: int, shard: int):
    """Contiguous [start, stop) of grid shard `shard` of `num_shards`.

    Exactly the block partition a 1-D NamedSharding lays over the padded
    grid axis (pad rows land in the last block and are sliced off), so an
    emulated-host worker computing `grid[start:stop]` reproduces the rows
    a real fleet member owns. The shards are disjoint and cover the grid.
    """
    if not 0 <= shard < num_shards:
        raise ValueError(f"shard {shard} out of range for {num_shards}")
    padded = grid_points + ((-grid_points) % num_shards)
    block = padded // num_shards
    start = min(shard * block, grid_points)
    stop = min(start + block, grid_points)
    return start, stop


class GridSharding:
    """Pad + place a sweep's leading grid axis over the fleet mesh.

    ::

        gs = GridSharding(k)                  # all global devices
        topo = gs.shard(topo)                 # leading axis -> "grid"
        ext = gs.replicate(ext)               # closed-over trace arrays
        out = fn(...)                         # same jitted entry point
        out = gs.gather(out)                  # full results on every host

    Single-device meshes degrade to passthroughs (`replicate` is identity
    when every device is process-local, preserving the single-host
    executables bit-for-bit); multi-process placement materializes only
    the locally-addressable rows per host. The grid is padded to a
    device-count multiple by repeating the last point; `gather` slices the
    pad back off and `describe()` reports it (no silent caps).
    """

    def __init__(self, grid_points: int, *, devices=None,
                 logical_axis: str = "sweep", mesh_axis: str = "grid"):
        self.devices = list(devices) if devices is not None \
            else list(jax.devices())
        if not self.devices:
            raise ValueError("GridSharding needs at least one device")
        self.grid_points = int(grid_points)
        self.n_devices = len(self.devices)
        self.pad = (-self.grid_points) % self.n_devices
        self.mesh = Mesh(np.asarray(self.devices), (mesh_axis,))
        self.rules = Rules(self.mesh, {logical_axis: (mesh_axis,)})
        self.sharding = self.rules.sharding(logical_axis)
        self.replicated = NamedSharding(self.mesh, P())
        self.processes = len({d.process_index for d in self.devices})
        self.multiprocess = self.processes > 1
        self._gather_jit = None
        if self.pad:
            log.info(
                "grid sharding: %d grid points padded with %d repeated "
                "lanes to fill %d devices (%d processes)", self.grid_points,
                self.pad, self.n_devices, self.processes)

    def describe(self) -> dict:
        """Sharding metadata surfaced in sweep summaries (no silent pads)."""
        return {"grid_points": self.grid_points, "pad_lanes": self.pad,
                "devices": self.n_devices, "processes": self.processes}

    # ---------------------------------------------------------- placement
    def pad_tree(self, tree):
        """Repeat each leaf's last grid row `pad` times (sliced off by
        `gather`; repeated points cost compute, never correctness)."""
        if not self.pad:
            return tree

        def _pad(a):
            a = jnp.asarray(a)
            return jnp.concatenate(
                [a, jnp.repeat(a[-1:], self.pad, axis=0)], axis=0)
        return jax.tree.map(_pad, tree)

    def _put(self, a, sharding):
        if not self.multiprocess:
            return jax.device_put(a, sharding)
        # Every process holds the identical host-side grid; each
        # materializes exactly the rows its devices own.
        arr = np.asarray(a)
        return jax.make_array_from_callback(
            arr.shape, sharding, lambda idx: arr[idx])

    def shard(self, tree):
        """Pad the leading axis and place it over the mesh's grid axis."""
        tree = self.pad_tree(tree)
        return jax.tree.map(lambda a: self._put(a, self.sharding), tree)

    def replicate(self, tree):
        """Make closed-over arrays fleet-global (fully replicated).

        Identity on single-process meshes — the engine's existing arrays
        already live where the executable runs, and re-placing them would
        perturb the warm-cache behaviour the tests pin.
        """
        if not self.multiprocess:
            return tree
        return jax.tree.map(
            lambda a: a if a is None else self._put(a, self.replicated),
            tree, is_leaf=lambda x: x is None)

    # ------------------------------------------------------------ results
    def gather(self, tree, *, axis: int = 0):
        """Full (unpadded) results, addressable on every process.

        Multi-process: an all-gather via a jit identity with replicated
        output sharding (each host then holds every shard). The pad rows
        are sliced off along `axis` (axis 1 for [N, K] batched sweeps).
        """
        if self.multiprocess:
            if self._gather_jit is None:
                self._gather_jit = jax.jit(
                    lambda t: t, out_shardings=self.replicated)
            tree = self._gather_jit(tree)
        if self.pad:
            k = self.grid_points
            sl = (slice(None),) * axis + (slice(0, k),)
            tree = jax.tree.map(lambda a: a[sl], tree)
        return tree
