"""Sharded checkpointing without external deps.

Layout: <dir>/step_<N>/
    manifest.json            — tree structure, shapes, dtypes, shard map
    shard_<host>_<i>.npz     — per-host shard files (addressable data only)

Design points for 1000+-node runs:
  * each host writes ONLY its addressable shards (no gather — no network
    traffic, no single-writer bottleneck);
  * manifest carries the logical->physical map so restore can reshard onto
    a DIFFERENT mesh (elastic restart after node loss);
  * writes are atomic (tmp dir + rename) so a failure mid-write never
    corrupts the latest checkpoint;
  * a `keep` policy garbage-collects old steps.

On this single-host container every array is fully addressable, so save /
restore exercise the same code path with host_count=1.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], \
        treedef


def save_checkpoint(tree: Any, directory: str, step: int,
                    keep: int = 3) -> str:
    """Write the pytree's addressable shards + manifest atomically."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    host = jax.process_index()

    flat, _ = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "entries": {},
                                "host_count": jax.process_count()}
    arrays = {}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        manifest["entries"][path] = {
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        arrays[key] = arr

    tmp = Path(tempfile.mkdtemp(dir=directory))
    try:
        np.savez(tmp / f"shard_{host}.npz", **arrays)
        if host == 0:
            (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    # GC old steps
    steps = sorted(p for p in directory.glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(directory.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(like: Any, directory: str,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore into the structure of `like`; reshard via `shardings` if the
    restore mesh differs from the save mesh (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    host = jax.process_index()
    data = np.load(d / f"shard_{host}.npz")

    flat, treedef = _flatten_with_paths(like)
    sh_flat = None
    if shardings is not None:
        sh_list, _ = jax.tree_util.tree_flatten(shardings)
        sh_flat = sh_list
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        ent = manifest["entries"][path]
        arr = data[ent["key"]]
        expect = tuple(getattr(leaf, "shape", ()))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {path}: "
                             f"{arr.shape} vs {expect}")
        val = jnp.asarray(arr)
        if sh_flat is not None:
            val = jax.device_put(val, sh_flat[i])
        leaves.append(val)
    children = jax.tree_util.tree_unflatten(
        treedef, leaves)
    return children
