"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analyses.

MUST be the very first two lines — before ANY other import — since jax locks
the device count on first initialization:
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_NAMES, SHAPES, cell_applicable,  # noqa: E402
                           get_config, shape_by_name)
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch import specs as S                              # noqa: E402
from repro.models import get_model                               # noqa: E402
from repro.models.params import partition_specs                  # noqa: E402
from repro.sharding.rules import Rules, use_rules                # noqa: E402
from repro.train.train_step import (abstract_train_state,        # noqa: E402
                                    make_train_step, state_pspecs)
from repro.launch.hlo_analysis import analyze_hlo                # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def collective_analysis(hlo_text: str, n_devices: int) -> dict:
    """Per-device wire bytes by collective type, parsed from compiled HLO.

    Wire-byte factors (per device, bidirectional-ring model):
      all-reduce:        2 (n-1)/n * buffer      (result shape == buffer)
      all-gather:        (n-1)/n  * result       (result is the gathered buf)
      reduce-scatter:    (n-1)    * result       (input n x result)
      all-to-all:        (n-1)/n  * buffer
      collective-permute: 1       * buffer
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        n = max(_group_size(line, n_devices), 1)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op == "all-gather":
            wire = (n - 1) / n * nbytes
        elif op == "reduce-scatter":
            wire = float(n - 1) * nbytes
        elif op == "all-to-all":
            wire = (n - 1) / n * nbytes
        else:
            wire = float(nbytes)
        out[op] += wire
        out["count"] += 1
    out["total_wire_bytes"] = sum(out[k] for k in
                                  ("all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute"))
    return out


def to_shardings(pspec_tree, mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        pspec_tree, is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rule_overrides: dict | None = None,
               accum: int = 1):
    """Build (lowered, n_devices) for one dry-run cell."""
    cfg = get_config(arch)
    cell = shape_by_name(shape_name)
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return None, reason
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = Rules(mesh, overrides=rule_overrides)
    model = get_model(cfg)

    with use_rules(rules):
        if cell.kind == "train":
            step = make_train_step(model, accum=accum)
            state = abstract_train_state(model)
            batch, batch_ps = S.batch_specs(cfg, cell, rules)
            in_sh = (to_shardings(state_pspecs(model, rules), mesh),
                     to_shardings(batch_ps, mesh))
            fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0,))
            lowered = fn.lower(state, batch)
        elif cell.kind == "prefill":
            from repro.models.params import abstract_params
            # serving runs bf16 weights (cast once at load, as in prod)
            params = abstract_params(model.spec(), dtype=jnp.bfloat16)
            batch, batch_ps = S.batch_specs(cfg, cell, rules)
            param_ps = partition_specs(model.spec(), rules)
            in_sh = (to_shardings(param_ps, mesh),
                     to_shardings(batch_ps, mesh))
            max_len = cell.seq_len

            def prefill(p, b):
                return model.prefill(p, b, max_len)

            fn = jax.jit(prefill, in_shardings=in_sh)
            lowered = fn.lower(params, batch)
        else:  # decode
            from repro.models.params import abstract_params
            params = abstract_params(model.spec(), dtype=jnp.bfloat16)
            param_ps = partition_specs(model.spec(), rules)
            tokens, tokens_ps = S.decode_tokens_specs(cfg, cell, rules)
            caches, caches_ps = S.decode_cache_specs(cfg, cell, rules)
            in_sh = (to_shardings(param_ps, mesh),
                     to_shardings(tokens_ps, mesh),
                     to_shardings(caches_ps, mesh))
            fn = jax.jit(model.decode_step, in_shardings=in_sh,
                         donate_argnums=(2,))
            lowered = fn.lower(params, tokens, caches)
    return (lowered, mesh.size), ""


def analyze_cell(arch: str, shape_name: str, multi_pod: bool,
                 rule_overrides: dict | None = None,
                 accum: int = 1) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "status": "ok"}
    t0 = time.time()
    try:
        result, reason = lower_cell(arch, shape_name, multi_pod,
                                    rule_overrides, accum)
        if result is None:
            rec["status"] = "skipped"
            rec["reason"] = reason
            return rec
        lowered, n_dev = result
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device": int(ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops_per_device": float(ca.get("flops", -1.0)),
                       "bytes_accessed_per_device":
                           float(ca.get("bytes accessed", -1.0))}
        # Trip-count-corrected static analysis (XLA's cost_analysis counts
        # every while body once — see launch/hlo_analysis.py).
        corrected = analyze_hlo(compiled.as_text(), n_dev)
        rec["corrected"] = {
            "flops_per_device": corrected["flops_per_device"],
            "bytes_per_device": corrected["bytes_per_device"]}
        rec["collectives"] = corrected["collectives"]
        rec["n_devices"] = n_dev
    except Exception as e:  # record failures — they are bugs to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def save_result(rec: dict, path: Path):
    path.parent.mkdir(parents=True, exist_ok=True)
    data = {}
    if path.exists():
        data = json.loads(path.read_text())
    key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
    data[key] = rec
    path.write_text(json.dumps(data, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(RESULTS_DIR / "dryrun.json"))
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--rules", default="default",
                    choices=["default", "tp_only", "sp"],
                    help="sharding-rule overlay (perf A/B comparisons)")
    args = ap.parse_args()

    from repro.sharding.rules import SP_OVERLAY, TP_ONLY_OVERLAY
    overrides = {"default": None, "tp_only": TP_ONLY_OVERLAY,
                 "sp": SP_OVERLAY}[args.rules]

    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out = Path(args.out)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                rec = analyze_cell(arch, shape, mp, accum=args.accum,
                                   rule_overrides=overrides)
                save_result(rec, out)
                status = rec["status"]
                if status == "ok":
                    mem = rec["memory"]["peak_per_device"] / 2**30
                    fl = rec["cost"]["flops_per_device"]
                    cw = rec["collectives"]["total_wire_bytes"] / 2**20
                    print(f"  ok: peak {mem:.2f} GiB/dev, "
                          f"{fl:.3g} flop/dev, wire {cw:.1f} MiB/dev "
                          f"(lower {rec['lower_s']}s, "
                          f"compile {rec['compile_s']}s)", flush=True)
                elif status == "skipped":
                    print(f"  skipped: {rec['reason']}", flush=True)
                else:
                    print(f"  ERROR: {rec['error']}", flush=True)

    data = json.loads(out.read_text())
    n_ok = sum(1 for r in data.values() if r["status"] == "ok")
    n_skip = sum(1 for r in data.values() if r["status"] == "skipped")
    n_err = sum(1 for r in data.values() if r["status"] == "error")
    print(f"[dryrun] total: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
