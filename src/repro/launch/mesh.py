"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests and benches keep their 1-CPU view while
dryrun.py (which sets XLA_FLAGS first) sees 512 placeholder devices.

Version compat: `jax.sharding.AxisType` (and the `axis_types` kwarg of
`jax.make_mesh`) only exist in newer jax releases. On older jax we fall back
to a plain mesh — every axis there is implicitly Auto anyway.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (axis sizes 1)."""
    return _make_mesh((1, 1), ("data", "model"))


def make_fleet_mesh(devices=None):
    """1-D ("grid",) mesh over every device in the fleet.

    The DSE mesh: `jax.devices()` spans all processes after
    `repro.core.distributed.init_distributed`, so the sweep axes that the
    rules table maps to "grid" (logical "sweep" / "islands") shard across
    hosts. With one local device this is a size-1 mesh and every sharding
    resolves to a placement no-op — the single-host fallback.
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = list(devices) if devices is not None else list(jax.devices())
    return Mesh(np.asarray(devices), ("grid",))
