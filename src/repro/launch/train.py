"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --smoke --steps 200 --batch 8 --seq 256

Integrates every subsystem: model registry, synthetic data pipeline,
optimizer, sharded checkpointing with restart, fault-tolerance guards, and
the ReSiPI Level-2 lane controller (epoch-metered collective traffic ->
lane-width decisions -> photonic-model energy accounting). On CPU it runs
the reduced (--smoke) configs; on a real cluster the same driver runs the
full configs over the production mesh.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import reconfig_runtime as lanes
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import get_model
from repro.checkpoint import ckpt
from repro.runtime.fault_tolerance import Heartbeat, StepGuard
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--epoch-steps", type=int, default=20,
                    help="ReSiPI reconfiguration interval (steps)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq)
    data = SyntheticLM(cfg, dcfg)

    state = init_train_state(model, jax.random.PRNGKey(0))
    start_step = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state = ckpt.restore_checkpoint(state, args.ckpt_dir)
            start_step = last
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(
        model, accum=args.accum,
        opt_overrides={"lr": args.lr, "total_steps": args.steps}),
        donate_argnums=(0,))

    # --- ReSiPI Level-2 lane controller -----------------------------------
    lane_cfg = lanes.LaneConfig()
    lane_state = lanes.LaneState.init(lane_cfg)
    lane_history = []

    heartbeat = Heartbeat()
    guard = StepGuard()
    losses = []

    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.host_slice(step).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        gnorm = float(metrics["grad_norm"])
        dt = time.time() - t0
        heartbeat.beat(dt)

        # The non-finite skip already happened inside the jitted step
        # (donation-safe); the host-side guard is telemetry + abort policy.
        if not guard.check(loss, gnorm):
            print(f"[guard] step {step} skipped in-step "
                  f"(loss={loss:.4g} gnorm={gnorm:.4g})")

        # lane metering: static DP-sync bytes + dynamic MoE imbalance
        lane_state = lanes.meter_step(
            lane_state, jnp.float32(float(metrics["collective_bytes"])))
        if (step + 1) % args.epoch_steps == 0:
            lane_state, rec = lanes.epoch_update(lane_state, lane_cfg)
            lane_history.append(int(rec["lanes_after"]))
            if bool(rec["reconfigured"]):
                print(f"[lanes] epoch {int(lane_state.epoch)}: "
                      f"load={float(rec['load']):.3f} -> "
                      f"{int(rec['lanes_after'])} lanes")

        losses.append(loss)
        if step % args.log_every == 0:
            print(f"[train] step {step}: loss {loss:.4f} "
                  f"gnorm {gnorm:.3f} ({dt*1000:.0f} ms)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save_checkpoint(state, args.ckpt_dir, step + 1)
            print(f"[ckpt] saved {path}")

    if lane_history:
        energy = lanes.lane_energy_report(jnp.asarray(lane_history),
                                          lane_cfg)
        print(f"[lanes] mean width {float(energy['mean_lanes']):.2f}, "
              f"model power {float(energy['mean_power_mw']):.0f} mW, "
              f"reconfig {float(energy['reconfig_nj']):.0f} nJ")
    print(f"[train] final loss {np.mean(losses[-10:]):.4f} "
          f"(first-10 {np.mean(losses[:10]):.4f})")
    return losses


if __name__ == "__main__":
    main()
