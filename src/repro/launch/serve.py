"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --smoke --requests 8 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import get_model
from repro.models.params import init_params
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = init_params(model.spec(), jax.random.PRNGKey(0))
    engine = Engine(model, params, batch_size=args.batch,
                    max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=jnp.asarray(
        rng.integers(0, cfg.real_vocab, size=args.prompt_len),
        dtype=jnp.int32), max_new_tokens=args.new_tokens)
        for _ in range(args.requests)]

    t0 = time.time()
    outs = engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in outs)
    print(f"[serve] {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12]}...")
    return outs


if __name__ == "__main__":
    main()
