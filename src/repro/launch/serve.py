"""Continuous-batching session-server driver.

    PYTHONPATH=src python -m repro.launch.serve --ticks 24 --lanes 8 \
        --chunk 8 --arrival-rate 2 --burst-at 8 --burst-size 12

Drives a `SessionServer` under a bursty multi-tenant arrival mix (batch /
standard / premium classes), optionally with a mid-run fault storm and the
closed-loop healer, and prints the metrics/health surface. The offline
companion to benchmarks/bench_serve.py.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.core import faults, traffic
from repro.core.gateway_controller import ControllerConfig
from repro.core.simulator import Arch, SimConfig
from repro.serve.engine import SessionServer
from repro.serve.policies import PRIORITY_CLASSES, ServerPolicy
from repro.serve.resilience import ResiliencePolicy
from repro.serve.scheduler import SessionRequest


def _arrivals(args, rng):
    """Bursty multi-tenant arrival process: ~arrival_rate sessions per
    tick (priority mix 50/35/15 batch/standard/premium), plus one burst."""
    apps = ("dedup", "canneal", "streamcluster")

    def gen(now):
        n = rng.poisson(args.arrival_rate)
        if now == args.burst_at:
            n += args.burst_size
        reqs = []
        for _ in range(n):
            t = int(rng.integers(args.min_intervals, args.max_intervals + 1))
            tr = traffic.generate_trace(
                apps[int(rng.integers(len(apps)))], t,
                jax.random.PRNGKey(int(rng.integers(1 << 30))))
            pr = PRIORITY_CLASSES[
                int(rng.choice(3, p=[0.50, 0.35, 0.15]))]
            reqs.append(SessionRequest(
                trace=tr, priority=pr,
                deadline_ticks=args.deadline if args.deadline > 0 else None))
        return reqs
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--queue-capacity", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=2.0)
    ap.add_argument("--burst-at", type=int, default=8)
    ap.add_argument("--burst-size", type=int, default=12)
    ap.add_argument("--min-intervals", type=int, default=8)
    ap.add_argument("--max-intervals", type=int, default=24)
    ap.add_argument("--deadline", type=int, default=0,
                    help="per-session deadline in ticks (0 = none)")
    ap.add_argument("--storm-at", type=int, default=-1,
                    help="hardware tick a gateway fault storm starts "
                         "(-1 = no faults)")
    ap.add_argument("--heal", action="store_true",
                    help="close the self-healing loop (blocked re-place)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    sim = SimConfig().with_arch(Arch.RESIPI)
    policy = ServerPolicy(lanes=args.lanes, chunk_intervals=args.chunk,
                          queue_capacity=args.queue_capacity)
    env = None
    if args.storm_at >= 0:
        # Pin the gateway count for storm runs: with the adaptive
        # controller free to add gateways it absorbs the lost capacity and
        # the latency breach the detector keys on never materialises.
        sim = dataclasses.replace(sim, ctl=ControllerConfig(
            l_m=sim.ctl.l_m, max_gateways=4, min_gateways=4))
        horizon = args.ticks * args.chunk * policy.degrade_coalesce
        victims = SessionServer(sim, policy).placement[:2]
        env = faults.FaultInjector(
            [faults.GatewayFault(start=args.storm_at * args.chunk,
                                 position=p) for p in victims],
            horizon, seed=args.seed)
    server = SessionServer(
        sim, policy, fault_env=env,
        resilience=ResiliencePolicy(threshold_frac=0.10, hysteresis=2,
                                    cooldown=1) if args.heal else None)

    rng = np.random.default_rng(args.seed)
    server.run(args.ticks, arrivals=_arrivals(args, rng))
    drain_ticks = server.drain()
    m = server.metrics()

    print(f"[serve] {m['submitted']} submitted -> {m['admitted']} admitted, "
          f"{m['completed']} completed over {m['ticks']} ticks "
          f"(+{drain_ticks} drain)")
    print(f"[serve] shed: queue_full={m['shed_queue_full']} "
          f"memory={m['shed_memory']} priority={m['shed_priority']} "
          f"displaced={m['displaced']}; expired={m['deadline_expired']} "
          f"evicted={m['idle_evicted']} retries={m['retries']}")
    p99 = f"{m['p99_chunk_s'] * 1e3:.2f}" if m["p99_chunk_s"] else "n/a"
    p50 = f"{m['p50_chunk_s'] * 1e3:.2f}" if m["p50_chunk_s"] else "n/a"
    print(f"[serve] {m['served_chunks']} chunks in {m['dispatches']} "
          f"dispatches ({m['coalesced_dispatches']} coalesced, "
          f"{m['degraded_ticks']} degraded ticks); chunk wall "
          f"p50={p50}ms p99={p99}ms")
    if args.heal:
        print(f"[serve] heals={m['heals']} pcm={m['total_pcm_nj']:.0f}nJ "
              f"availability="
              f"{m['availability']:.0%}" if m["availability"] is not None
              else "[serve] heals=0")
    print(f"[serve] health: {server.health()}")
    return server


if __name__ == "__main__":
    main()
