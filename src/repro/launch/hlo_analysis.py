"""Static analysis of compiled HLO text — the dry-run 'profiler'.

XLA's `compiled.cost_analysis()` counts each `while` body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collectives by ~n_layers.
This module parses the HLO text into computations, extracts per-while
`known_trip_count`s, and walks the call graph multiplying costs through
nested loops. It produces:

    flops            — 2 * numel(out) * contract_dim for every dot
    bytes            — operand + result bytes of every non-fused op
                       (fusion internals stay in-register and are skipped)
    collective wire  — per-device bytes by collective type (ring model)

All values are per-device (HLO text is the SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(shape_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + [(dtype, dims)] parsed from a shape string."""
    total = 0
    shapes = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dlist = [int(d) for d in dims.split(",")] if dims else []
        total += math.prod(dlist) * _DTYPE_BYTES[dtype] if dlist else \
            _DTYPE_BYTES[dtype]
        shapes.append((dtype, dlist))
    return total, shapes


@dataclasses.dataclass
class OpInfo:
    name: str
    out_bytes: int
    out_shape: List[int]
    kind: str
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None
    children: Optional[List[Tuple[str, float]]] = None  # (comp, multiplier)

    def __post_init__(self):
        if self.coll is None:
            self.coll = {c: 0.0 for c in COLLECTIVES}
        if self.children is None:
            self.children = []


def _split_type(rhs: str) -> Tuple[str, str]:
    """Split an op RHS into (result type string, rest-with-opcode)."""
    s = rhs.strip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1], s[i + 1:].strip()
        return s, ""
    parts = s.split(None, 1)
    if len(parts) == 2:
        return parts[0], parts[1]
    return s, ""


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _wire_bytes(op: str, nbytes: int, n: int) -> float:
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * nbytes
    if op == "all-gather":
        return (n - 1) / n * nbytes
    if op == "reduce-scatter":
        return float(n - 1) * nbytes
    if op == "all-to-all":
        return (n - 1) / n * nbytes
    return float(nbytes)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _dot_flops(rhs: str, shapes_by_name: Dict[str, List[int]],
               out_shape: List[int]) -> float:
    """2 * numel(out) * contracted elements (from lhs operand shape)."""
    ops = _OPERANDS_RE.findall(rhs.split("(", 1)[1]) if "(" in rhs else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    contract = 1
    if m and ops:
        lhs_shape = shapes_by_name.get(ops[0], [])
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contract *= lhs_shape[int(d)]
    return 2.0 * math.prod(out_shape or [0]) * contract


def analyze_hlo(hlo: str, n_devices: int) -> dict:
    comps = _split_computations(hlo)
    costs: Dict[str, CompCost] = {}

    for name, lines in comps.items():
        cost = CompCost()
        shapes_by_name: Dict[str, List[int]] = {}
        out_bytes_by_name: Dict[str, int] = {}
        parsed = []
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            op_name, rhs = m.group(1), m.group(2)
            type_str, rest = _split_type(rhs)
            nbytes, shapes = _shape_info(type_str)
            dims = shapes[0][1] if shapes else []
            shapes_by_name[op_name] = dims
            out_bytes_by_name[op_name] = nbytes
            parsed.append((op_name, rest, nbytes, dims, line))

        for op_name, rhs, nbytes, dims, line in parsed:
            km = re.match(r"([a-z][\w\-]*)\s*\(", rhs)
            kind = km.group(1) if km else rhs.split("(")[0].strip()
            # --- collectives
            hit = next((c for c in COLLECTIVES
                        if re.match(rf"{c}(-start)?$", kind)), None)
            if hit:
                n = _group_size(line, n_devices)
                cost.coll[hit] += _wire_bytes(hit, nbytes, max(n, 1))
            # --- dots
            if kind == "dot":
                cost.flops += _dot_flops(rhs, shapes_by_name, dims)
            # --- while children
            if kind == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
                tm = _TRIP_RE.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                if bm:
                    cost.children.append((bm.group(1), trip))
                if cm:
                    cost.children.append((cm.group(1), trip))
            # --- fusion / call children (multiplier 1)
            for ref in re.findall(r"(?:calls|to_apply|"
                                  r"true_computation|false_computation)="
                                  r"%?([\w\.\-]+)", rhs):
                cost.children.append((ref, 1.0))
            # --- bytes: approximate true HBM traffic as write-once-per-
            # produced-buffer plus matmul reads. Counting every op's
            # operands would double-count (each tensor once as producer
            # output and once per consumer), and CPU HLO has far more
            # fusion boundaries than TPU — so we count: outputs of compute
            # ops that materialize buffers, plus dot operand reads (weight
            # and activation streams into the MXU).
            if kind in ("dot", "fusion", "convolution", "reduce", "sort",
                        "scatter", "gather", "dynamic-slice",
                        "dynamic-update-slice", "custom-call", "rng",
                        "rng-bit-generator") or kind.startswith("all-") \
                    or kind in ("reduce-scatter", "collective-permute"):
                cost.bytes += nbytes
            if kind == "dot" and "(" in rhs:
                for ref in _OPERANDS_RE.findall(
                        rhs[rhs.index("("):].split(")", 1)[0]):
                    cost.bytes += out_bytes_by_name.get(ref, 0)
        costs[name] = cost

    # entry = computation containing a while or the one named like main
    entry = next((n for n in comps if n.endswith("main") or
                  n.startswith("main")), None)
    if entry is None:
        # fall back: computation that is no one's child
        children = {c for cc in costs.values() for c, _ in cc.children}
        roots = [n for n in comps if n not in children]
        entry = roots[0] if roots else next(iter(comps))

    memo: Dict[str, dict] = {}

    def resolve(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        if name not in costs or depth > 50:
            return {"flops": 0.0, "bytes": 0.0,
                    **{c: 0.0 for c in COLLECTIVES}}
        c = costs[name]
        total = {"flops": c.flops, "bytes": c.bytes,
                 **{k: v for k, v in c.coll.items()}}
        for child, mult in c.children:
            sub = resolve(child, depth + 1)
            for k in total:
                total[k] += mult * sub[k]
        memo[name] = total
        return total

    total = resolve(entry)
    coll_total = sum(total[c] for c in COLLECTIVES)
    return {"flops_per_device": total["flops"],
            "bytes_per_device": total["bytes"],
            "collectives": {**{c: total[c] for c in COLLECTIVES},
                            "total_wire_bytes": coll_total},
            "entry": entry}
