"""Fleet launcher for co-design DSE sweeps: `python -m repro.launch.fleet`.

One launchable job runs a (chiplets x placements x workloads) co-design
grid — thousands of points at full scale — as ONE sharded sweep across a
multi-process `jax.distributed` mesh (repro.core.distributed), with the
persistent compilation cache + warmup (repro.runtime.cache) so workers
serve their first sweep warm.

Three ways to run it::

    # single process, local devices (the fallback everyone can run)
    python -m repro.launch.fleet --chiplets 4,16,36 --intervals 16

    # launcher: spawn N local worker processes, one jax.distributed mesh
    python -m repro.launch.fleet --processes 2 --out fleet.json

    # one worker of an externally-orchestrated fleet (one per host)
    python -m repro.launch.fleet --processes 8 --process-id 3 \\
        --coordinator head-node:12345

    # emulated host: compute ONLY shard 1 of 4 (the same contiguous rows
    # a real 4-process fleet member owns) — the harness-regime scaling
    # measurement on machines without enough cores for real co-scheduling
    python -m repro.launch.fleet --shard 1:4

Everything jax touches is imported lazily: the worker must pin env vars
(XLA_FLAGS device count, coordinator address) before the backend exists.
All processes build the identical grid from the seed; sharding is purely
a data-placement decision (see core.distributed.GridSharding).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time

_PLACEMENT_SEED_SALT = 0x9E37


def _parse_ints(s: str):
    return [int(x) for x in s.split(",") if x.strip()]


def _parse_names(s: str):
    return [x.strip() for x in s.split(",") if x.strip()]


def sample_placements(cfg, count: int, seed: int):
    """Deterministic placement candidates: the default edge scheme plus
    `count - 1` seeded draws of `max_gateways_per_chiplet` distinct border
    slots on the chiplet's mesh (every process reproduces the same list).
    """
    import numpy as np

    out = [None]
    if count <= 1:
        return out[:max(count, 1)]
    r = cfg.mesh_x
    border = [(x, y) for x in range(r) for y in range(r)
              if x in (0, r - 1) or y in (0, r - 1)]
    rng = np.random.default_rng(seed ^ _PLACEMENT_SEED_SALT)
    g = cfg.max_gateways_per_chiplet
    for _ in range(count - 1):
        idx = rng.choice(len(border), size=g, replace=False)
        out.append(tuple(border[i] for i in sorted(idx)))
    return out


def build_grid(cfg, *, chiplets, placements: int, workloads,
               intervals: int, seed: int) -> dict:
    """The co-design grid: K = |chiplets| x |placements| x |workloads|
    zipped element-wise lists (one grid point per combination), identical
    on every process (deterministic from `seed`).
    """
    from repro.core import traffic

    placement_list = sample_placements(cfg, placements, seed)
    specs, n_chiplets, gateway_positions, labels = [], [], [], []
    for c in chiplets:
        for p_i, pos in enumerate(placement_list):
            for w in workloads:
                specs.append(traffic.as_spec(w)
                             if not isinstance(w, str)
                             else traffic.as_spec(
                                 _spec_for(w, intervals)))
                n_chiplets.append(int(c))
                gateway_positions.append(pos)
                labels.append(f"c{c}/p{p_i}/{w}")
    return {"specs": specs, "labels": labels,
            "grids": {"n_chiplets": n_chiplets,
                      "gateway_positions": gateway_positions},
            "k": len(specs)}


def _spec_for(name: str, intervals: int):
    from repro.core import traffic

    if name == "uniform":
        return traffic.UniformSpec(n_intervals=intervals)
    if name == "bursty":
        return traffic.BurstySpec(n_intervals=intervals)
    return traffic.ParsecSpec(name, n_intervals=intervals)


def slice_grid(grid: dict, start: int, stop: int) -> dict:
    """One worker's contiguous rows (emulated-host shard)."""
    return {"specs": grid["specs"][start:stop],
            "labels": grid["labels"][start:stop],
            "grids": {g: v[start:stop] for g, v in grid["grids"].items()},
            "k": stop - start}


def run_sweep(args, *, shard=None) -> dict:
    """Build the grid, warm the caches, run the (sharded) co-design sweep,
    and return the result record. `shard=(i, n)` computes only that
    emulated-host block; otherwise all local/global devices shard it."""
    from repro.core.distributed import (init_distributed, is_distributed,
                                        partition_bounds, process_index)
    from repro.runtime import cache as rcache

    info = init_distributed(coordinator=args.coordinator,
                            num_processes=args.processes
                            if args.process_id is not None else None,
                            process_id=args.process_id)
    if not args.no_cache:
        rcache.enable_persistent_cache(args.cache_dir)

    import jax
    import numpy as np
    from repro.core.simulator import Arch, SimConfig, sweep_workload

    sim = SimConfig().with_arch(
        Arch[args.arch.upper()] if isinstance(args.arch, str) else args.arch)
    grid = build_grid(sim.cfg, chiplets=args.chiplets,
                      placements=args.placements, workloads=args.workloads,
                      intervals=args.intervals, seed=args.seed)
    k_full = grid["k"]
    # Per-lane PRNG keys and the trace-generation chiplet count are pinned
    # to the FULL grid, so an emulated-host shard reproduces exactly the
    # rows a real fleet member owns (see sweep_workload's gen_chiplets).
    keys = jax.random.split(jax.random.PRNGKey(args.seed), k_full)
    gen_c = max(args.chiplets)
    if shard is not None:
        i, n = shard
        start, stop = partition_bounds(k_full, n, i)
        grid = slice_grid(grid, start, stop)
        keys = keys[start:stop]

    devices = list(jax.devices())
    call = lambda: sweep_workload(
        grid["specs"], sim, keys=keys, gen_chiplets=gen_c,
        devices=devices if len(devices) > 1 else None, **grid["grids"])

    t0 = time.perf_counter()
    out = jax.block_until_ready(call())
    first_call_s = time.perf_counter() - t0

    walls = []
    for _ in range(max(args.reps, 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(call())
        walls.append(time.perf_counter() - t0)
    sweep_wall_s = min(walls)

    lat = np.asarray(out["summary"]["mean_latency"], np.float64)
    pwr = np.asarray(out["summary"]["mean_power_mw"], np.float64)
    result = {
        "mode": ("shard" if shard is not None else
                 "distributed" if is_distributed() else "local"),
        "shard": list(shard) if shard is not None else None,
        "grid_points": grid["k"], "grid_points_full": k_full,
        "intervals": args.intervals,
        "chiplets": args.chiplets, "placements": args.placements,
        "workloads": args.workloads,
        "process_count": jax.process_count(),
        "process_index": process_index(),
        "device_count": len(devices),
        "first_call_s": first_call_s,
        "sweep_wall_s": sweep_wall_s,
        "points_per_sec": grid["k"] / sweep_wall_s,
        "pad_lanes": int(out.get("sharding", {}).get("pad_lanes", 0)),
        "best_point": {"label": grid["labels"][int(np.argmin(lat))],
                       "mean_latency": float(lat.min())},
        "mean_latency_mean": float(lat.mean()),
        "mean_power_mw_mean": float(pwr.mean()),
        "cache": rcache.persistent_cache_stats(),
        "distributed": info,
    }
    if args.dump_points:
        result["mean_latency"] = [float(v) for v in lat]
        result["labels"] = grid["labels"]
    return result


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launch_local_fleet(args) -> int:
    """Spawn `--processes` local workers sharing one jax.distributed mesh
    (the single-machine stand-in for one-worker-per-host orchestration)."""
    port = _free_port()
    coord = f"127.0.0.1:{port}"
    env_base = os.environ.copy()
    if args.local_device_count:
        flags = env_base.get("XLA_FLAGS", "")
        env_base["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.local_device_count}").strip()
    procs = []
    for i in range(args.processes):
        cmd = [sys.executable, "-m", "repro.launch.fleet",
               "--processes", str(args.processes), "--process-id", str(i),
               "--coordinator", coord] + _passthrough(args)
        if i == 0 and args.out:
            cmd += ["--out", args.out]
        procs.append(subprocess.Popen(
            cmd, env=env_base,
            stdout=None if i == 0 else subprocess.PIPE,
            stderr=None if i == 0 else subprocess.STDOUT))
    rc = 0
    for i, p in enumerate(procs):
        out, _ = p.communicate()
        if p.returncode != 0:
            rc = p.returncode
            if out:
                sys.stderr.write(f"--- worker {i} output ---\n"
                                 f"{out.decode(errors='replace')}\n")
    return rc


def _passthrough(args):
    out = ["--chiplets", ",".join(map(str, args.chiplets)),
           "--placements", str(args.placements),
           "--workloads", ",".join(args.workloads),
           "--intervals", str(args.intervals),
           "--seed", str(args.seed),
           "--reps", str(args.reps),
           "--arch", args.arch]
    if args.cache_dir:
        out += ["--cache-dir", args.cache_dir]
    if args.no_cache:
        out += ["--no-cache"]
    if args.dump_points:
        out += ["--dump-points"]
    return out


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.fleet",
        description="Sharded co-design DSE sweep "
                    "(chiplets x placements x workloads)")
    p.add_argument("--processes", type=int, default=None,
                   help="fleet size; without --process-id, spawn this many "
                        "local workers")
    p.add_argument("--process-id", type=int, default=None,
                   help="this worker's rank (externally orchestrated fleet)")
    p.add_argument("--coordinator", default=None,
                   help="host:port of process 0 (jax.distributed)")
    p.add_argument("--shard", default=None, metavar="I:N",
                   help="emulated-host mode: compute only grid shard I of N")
    p.add_argument("--local-device-count", type=int, default=None,
                   help="XLA host-platform device count per worker "
                        "(launcher mode sets it in each child's XLA_FLAGS)")
    p.add_argument("--chiplets", type=_parse_ints, default=[4, 16, 36, 64],
                   help="comma list of chiplet counts (default 4,16,36,64)")
    p.add_argument("--placements", type=int, default=4,
                   help="placement candidates per point (default edge "
                        "scheme + seeded border draws)")
    p.add_argument("--workloads", type=_parse_names,
                   default=["uniform", "bursty", "dedup", "canneal"],
                   help="comma list: uniform,bursty,<parsec app>,...")
    p.add_argument("--intervals", type=int, default=24)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--reps", type=int, default=1,
                   help="timed repetitions after the first call")
    p.add_argument("--arch", default="resipi")
    p.add_argument("--cache-dir", default=None,
                   help="persistent compilation cache directory")
    p.add_argument("--no-cache", action="store_true")
    p.add_argument("--dump-points", action="store_true",
                   help="include per-point mean latencies in the JSON")
    p.add_argument("--out", default=None, help="result JSON path")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.local_device_count and args.process_id is not None or \
            args.local_device_count and args.shard:
        # Worker/shard invoked directly: pin the device count before any
        # jax import (too late afterwards — the backend binds XLA_FLAGS).
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.local_device_count}").strip()

    if args.process_id is None and args.processes and args.processes > 1 \
            and args.shard is None:
        return launch_local_fleet(args)

    shard = None
    if args.shard:
        i, n = (int(x) for x in args.shard.split(":"))
        shard = (i, n)

    result = run_sweep(args, shard=shard)
    if result["process_index"] == 0:
        line = (f"fleet: {result['grid_points']} points "
                f"({result['mode']}, {result['process_count']} proc x "
                f"{result['device_count']} dev) "
                f"first {result['first_call_s']:.2f}s, sweep "
                f"{result['sweep_wall_s']:.3f}s = "
                f"{result['points_per_sec']:.1f} points/s, best "
                f"{result['best_point']['label']}")
        print(line, flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(result, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
