"""Abstract input builders for every (architecture x shape) dry-run cell.

`input_specs(cfg, cell)` returns ShapeDtypeStruct stand-ins for every model
input — weak-type-correct, shardable, zero allocation — plus matching
PartitionSpec trees. Cache shapes/specs are built per model family here so
decode cells lower with fully-sharded KV / SSM state.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import get_model
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models.transformer import DecoderLM, SSMLM, HybridLM
from repro.models.encdec import EncDecLM
from repro.sharding.rules import Rules


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(d) for d in shape), dtype)


# ---------------------------------------------------------------------------
# Batch inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, cell: ShapeCell, rules: Rules
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(abstract batch, pspecs) for train/prefill inputs."""
    b, s = cell.global_batch, cell.seq_len
    train = cell.kind == "train"
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    if cfg.family == "vlm":
        text = s - cfg.frontend_embeds
        batch["tokens"] = sds((b, text), jnp.int32)
        batch["image_embeds"] = sds((b, cfg.frontend_embeds, cfg.d_model),
                                    jnp.bfloat16)
        specs["tokens"] = rules.spec_for_shape((b, text), "batch", None)
        specs["image_embeds"] = rules.spec_for_shape(
            (b, cfg.frontend_embeds, cfg.d_model), "batch", None, None)
        if train:
            batch["labels"] = sds((b, text), jnp.int32)
            specs["labels"] = rules.spec_for_shape((b, text), "batch", None)
    elif cfg.family == "encdec":
        batch["tokens"] = sds((b, s), jnp.int32)
        batch["frames"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = rules.spec_for_shape((b, s), "batch", None)
        specs["frames"] = rules.spec_for_shape((b, s, cfg.d_model),
                                               "batch", None, None)
        if train:
            batch["labels"] = sds((b, s), jnp.int32)
            specs["labels"] = rules.spec_for_shape((b, s), "batch", None)
    else:
        batch["tokens"] = sds((b, s), jnp.int32)
        specs["tokens"] = rules.spec_for_shape((b, s), "batch", None)
        if train:
            batch["labels"] = sds((b, s), jnp.int32)
            specs["labels"] = rules.spec_for_shape((b, s), "batch", None)
    return batch, specs


# ---------------------------------------------------------------------------
# Decode caches (abstract + pspecs), per family
# ---------------------------------------------------------------------------

def _kv_cache_abstract(n_layers: int, b: int, max_len: int,
                       cfg: ModelConfig, lengths_shape=()):
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return L.KVCache(
        k=sds((n_layers, b, max_len, g, hd), L.COMPUTE_DTYPE),
        v=sds((n_layers, b, max_len, g, hd), L.COMPUTE_DTYPE),
        length=sds(lengths_shape, jnp.int32))


def _kv_cache_pspec(rules: Rules, n_layers: int, b: int, max_len: int,
                    cfg: ModelConfig):
    shape = (n_layers, b, max_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    kv = rules.spec_for_shape(shape, None, "batch", "kv_seq", "kv", None)
    return L.KVCache(k=kv, v=kv, length=P())


def _ssm_cache_abstract(cfg: ModelConfig, n_layers: int, b: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return (sds((n_layers, b, n_heads, s.head_dim, s.d_state), jnp.float32),
            sds((n_layers, b, s.conv_width - 1, conv_dim), jnp.float32))


def _ssm_cache_pspec(rules: Rules, cfg: ModelConfig, n_layers: int, b: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return (rules.spec_for_shape(
                (n_layers, b, n_heads, s.head_dim, s.d_state),
                None, "batch", "heads", None, None),
            rules.spec_for_shape((n_layers, b, s.conv_width - 1, conv_dim),
                                 None, "batch", None, "ff"))


def decode_cache_specs(cfg: ModelConfig, cell: ShapeCell, rules: Rules
                       ) -> Tuple[Any, Any]:
    """(abstract caches, cache pspecs) for decode cells: the KV/SSM state
    holds `seq_len` already-generated context, batch `global_batch`."""
    b, max_len = cell.global_batch, cell.seq_len
    model = get_model(cfg)

    if isinstance(model, SSMLM):
        return (_ssm_cache_abstract(cfg, cfg.n_layers, b),
                _ssm_cache_pspec(rules, cfg, cfg.n_layers, b))

    if isinstance(model, HybridLM):
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        n_heads = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.n_groups * s.d_state
        ng, gl, tail = model.n_groups, model.group_len, model.tail
        ssm_g = (sds((ng, gl, b, n_heads, s.head_dim, s.d_state),
                     jnp.float32),
                 sds((ng, gl, b, s.conv_width - 1, conv_dim), jnp.float32))
        ssm_g_spec = (rules.spec_for_shape(
                          (ng, gl, b, n_heads, s.head_dim, s.d_state),
                          None, None, "batch", "heads", None, None),
                      rules.spec_for_shape(
                          (ng, gl, b, s.conv_width - 1, conv_dim),
                          None, None, "batch", None, "ff"))
        ssm_t = (_ssm_cache_abstract(cfg, tail, b) if tail else None)
        ssm_t_spec = (_ssm_cache_pspec(rules, cfg, tail, b)
                      if tail else None)
        kv = _kv_cache_abstract(ng, b, max_len, cfg, lengths_shape=(ng,))
        kv_spec = _kv_cache_pspec(rules, ng, b, max_len, cfg)
        return ((ssm_g, ssm_t), kv), ((ssm_g_spec, ssm_t_spec), kv_spec)

    if isinstance(model, EncDecLM):
        n_dec = cfg.decoder_layers
        kv = _kv_cache_abstract(n_dec, b, max_len, cfg,
                                lengths_shape=(n_dec,))
        kv_spec = _kv_cache_pspec(rules, n_dec, b, max_len, cfg)
        g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        mem_kv = (sds((n_dec, b, max_len, g, hd), L.COMPUTE_DTYPE),
                  sds((n_dec, b, max_len, g, hd), L.COMPUTE_DTYPE))
        mem_spec = (rules.spec_for_shape(
            (n_dec, b, max_len, g, hd),
            None, "batch", "kv_seq", "kv", None),) * 2
        return (kv, mem_kv), (kv_spec, mem_spec)

    # DecoderLM (dense / moe / vlm)
    kv = _kv_cache_abstract(cfg.n_layers, b, max_len, cfg)
    return kv, _kv_cache_pspec(rules, cfg.n_layers, b, max_len, cfg)


def decode_tokens_specs(cfg: ModelConfig, cell: ShapeCell, rules: Rules):
    return (sds((cell.global_batch, 1), jnp.int32),
            rules.spec_for_shape((cell.global_batch, 1), "batch", None))
