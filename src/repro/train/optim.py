"""Optimizers (no external deps): AdamW and Adafactor, plus LR schedules
and global-norm clipping.

Adafactor (factored second moments, no first moment, no master copy) is the
DESIGN.md §7 choice for the >=100B MoE archs — its state is ~0.1 B/param vs
AdamW's 8 B/param (f32 m+v), which is what lets kimi-k2-1t fit 512 x 16 GB.
State tensors inherit the parameter's sharding (factored stats reduce over
one axis, so their specs drop that axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.int32(0)}


def adamw_update(grads: Any, state: dict, params: Any,
                 cfg: AdamWConfig) -> Tuple[Any, dict, dict]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg.lr, cfg.warmup, cfg.total_steps)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        m_hat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p
        return p - lr * delta, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8           # beta2 exponent: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup: int = 100
    total_steps: int = 10_000
    min_dim_factored: int = 128


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor_init(params: Any) -> dict:
    def one(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
    return {"stats": jax.tree.map(one, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.int32(0)}


def adafactor_update(grads: Any, state: dict, params: Any,
                     cfg: AdafactorConfig) -> Tuple[Any, dict, dict]:
    step = state["step"] + 1
    stepf = step.astype(jnp.float32)
    lr = cosine_schedule(cfg.lr, cfg.warmup, cfg.total_steps)(step)
    beta2 = 1.0 - stepf ** (-cfg.decay)

    def upd(g, stat, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if "row" in stat:
            row = beta2 * stat["row"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            col = beta2 * stat["col"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            vhat = (row[..., None] / jnp.maximum(row_mean[..., None], 1e-30)
                    ) * col[..., None, :]
            new_stat = {"row": row, "col": col}
        else:
            vhat = beta2 * stat["v"] + (1 - beta2) * g2
            new_stat = {"v": vhat}
        update = g / jnp.sqrt(jnp.maximum(vhat, cfg.eps))
        # update clipping (RMS-based, as in the Adafactor paper)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p
        return p - lr * update, new_stat

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["stats"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, {"stats": new_s, "step": step}, \
        {"grad_norm": global_norm(grads), "lr": lr}


# ---------------------------------------------------------------------------
# Uniform facade
# ---------------------------------------------------------------------------

def make_optimizer(name: str, **overrides):
    """Returns (init_fn, update_fn, cfg)."""
    if name == "adamw":
        cfg = AdamWConfig(**overrides)
        return adamw_init, \
            lambda g, s, p: adamw_update(g, s, p, cfg), cfg
    if name == "adafactor":
        cfg = AdafactorConfig(**overrides)
        return adafactor_init, \
            lambda g, s, p: adafactor_update(g, s, p, cfg), cfg
    raise ValueError(f"unknown optimizer: {name}")
