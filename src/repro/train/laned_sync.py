"""Laned gradient synchronization — the ReSiPI lane width as an actual
XLA program difference (DESIGN.md §2 table, last row).

`make_laned_train_step(model, mesh, lanes)` builds a shard_map train step
whose data-parallel gradient all-reduce is split into `lanes` chunk
streams (`core.reconfig_runtime.laned_psum`): lanes=1 is one fused
all-reduce (the paper's design A — one deep gateway); lanes=4 is four
narrower concurrent collectives XLA can overlap with the optimizer update
(design B — more gateways). The launcher pre-compiles one executable per
width in LANE_WIDTHS and the epoch controller switches between them — the
PCM-reconfiguration analogue (switch cost = executable swap; nothing while
the width holds).

Inside shard_map, model TP collectives would need manual placement, so this
path runs the *data-parallel* axis only (model axis size 1 in its mesh) —
exactly the gradient-sync traffic the Level-1 paper manages between
chiplets. The pjit path (train_step.py) remains the TP/FSDP workhorse.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.reconfig_runtime import LANE_WIDTHS, laned_psum
from repro.train.train_step import make_optimizer_for


def make_laned_train_step(model, mesh: Mesh, lanes: int,
                          opt_overrides=None) -> Callable:
    """train_step(state, batch) with `lanes`-way chunked DP grad sync."""
    cfg = model.cfg
    _, opt_update, _ = make_optimizer_for(cfg, **(opt_overrides or {}))
    axis = "data"

    def per_shard_step(state, batch):
        def loss_fn(params):
            loss, _ = model.train_loss(params, batch)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        # THE lane choice: k chunk-streams of the gradient all-reduce.
        grads = laned_psum(grads, axis, lanes)
        # mesh.shape is static here; jax.lax.axis_size only exists on
        # newer jax, so don't depend on it.
        inv = 1.0 / mesh.shape[axis]
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = jax.lax.pmean(loss, axis)
        new_params, new_opt, opt_stats = opt_update(
            grads, state["opt"], state["params"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, **opt_stats}

    rep = P()
    batch_spec = {"tokens": P(axis, None), "labels": P(axis, None)}
    state_spec = jax.tree.map(lambda _: rep, {"dummy": 0})

    def train_step(state, batch):
        state_specs = jax.tree.map(lambda _: rep, state)
        out = shard_map(
            per_shard_step, mesh=mesh,
            in_specs=(state_specs, batch_spec),
            out_specs=(state_specs,
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
            check_rep=False)(state, batch)
        return out

    return jax.jit(train_step)


def compile_lane_variants(model, mesh: Mesh, state, batch,
                          opt_overrides=None) -> Dict[int, Callable]:
    """Pre-compile one executable per lane width (the design-time tables
    of §3.4); the epoch controller indexes into this dict at runtime."""
    out = {}
    for w in LANE_WIDTHS:
        fn = make_laned_train_step(model, mesh, w, opt_overrides)
        fn(state, batch)           # trigger compile (cached thereafter)
        out[w] = fn
    return out
