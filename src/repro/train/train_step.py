"""Train step factory: loss -> grad -> optimizer update, with gradient
accumulation, bf16 compute / f32 params, and ReSiPI lane metering.

The returned step functions are pjit-ready: `state_pspecs` /
`abstract_state` give matching sharding/abstract trees for
jit(in_shardings=...) and `.lower()` without allocating anything.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.params import (ParamSpec, abstract_params, init_params,
                                 is_spec, partition_specs)
from repro.sharding.rules import Rules
from repro.train import optim
from repro.core.reconfig_runtime import collective_bytes_of


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def make_optimizer_for(cfg: ModelConfig, **overrides):
    return optim.make_optimizer(cfg.optimizer, **overrides)


def init_train_state(model, key: jax.Array) -> dict:
    params = init_params(model.spec(), key)
    opt_init, _, _ = make_optimizer_for(model.cfg)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.int32(0)}


def abstract_train_state(model) -> dict:
    params = abstract_params(model.spec())
    opt_init, _, _ = make_optimizer_for(model.cfg)
    opt = jax.eval_shape(opt_init, params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _opt_stat_specs(spec_tree: Any, rules: Rules, optimizer: str) -> Any:
    """PartitionSpecs for optimizer state, derived from ParamSpecs.

    AdamW m/v mirror the parameter sharding. Adafactor row stats drop the
    last parameter axis, col stats drop the second-to-last.
    """
    if optimizer == "adamw":
        pspecs = partition_specs(spec_tree, rules)
        return {"m": pspecs, "v": pspecs, "step": P()}

    def one(s: ParamSpec):
        if optim._factored(s.shape):
            return {"row": rules.spec_for_shape(s.shape[:-1],
                                                *s.axes[:-1]),
                    "col": rules.spec_for_shape(
                        s.shape[:-2] + s.shape[-1:],
                        *(s.axes[:-2] + s.axes[-1:]))}
        return {"v": rules.spec_for_shape(s.shape, *s.axes)}

    return {"stats": jax.tree.map(one, spec_tree, is_leaf=is_spec),
            "step": P()}


def state_pspecs(model, rules: Rules) -> dict:
    spec_tree = model.spec()
    return {"params": partition_specs(spec_tree, rules),
            "opt": _opt_stat_specs(spec_tree, rules, model.cfg.optimizer),
            "step": P()}


# ---------------------------------------------------------------------------
# Step factory
# ---------------------------------------------------------------------------

def make_train_step(model, accum: int = 1,
                    opt_overrides: Optional[dict] = None,
                    guard: bool = True
                    ) -> Callable[[dict, dict], Tuple[dict, dict]]:
    """Build train_step(state, batch) -> (state, metrics).

    accum > 1 splits the batch into `accum` microbatches scanned
    sequentially with gradient averaging (activation memory / step-time
    trade, one of the §Perf levers).

    guard=True applies the non-finite-loss skip *inside* the jitted step
    (jnp.where select), which stays correct under buffer donation — the
    large-run SDC/poison-batch protection (runtime/fault_tolerance.py).
    """
    cfg = model.cfg
    _, opt_update, _ = make_optimizer_for(cfg, **(opt_overrides or {}))

    def loss_fn(params, microbatch):
        return model.train_loss(params, microbatch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, stats), grads = grad_fn(params, batch)
        return loss, stats, grads

    def accumulated(params, batch):
        def split(x):
            return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
        micro = jax.tree.map(split, batch)

        def step(carry, mb):
            loss_acc, grads_acc = carry
            (loss, stats), grads = grad_fn(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), stats

        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                             params)
        (loss_sum, grads), stats = jax.lax.scan(
            step, (jnp.float32(0.0), zeros), micro)
        stats = jax.tree.map(lambda s: s[-1], stats)
        scale = 1.0 / accum
        grads = jax.tree.map(lambda g: g * scale, grads)
        return loss_sum * scale, stats, grads

    def train_step(state, batch):
        if accum > 1:
            loss, stats, grads = accumulated(state["params"], batch)
        else:
            loss, stats, grads = single(state["params"], batch)
        new_params, new_opt, opt_stats = opt_update(
            grads, state["opt"], state["params"])
        if guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(opt_stats["grad_norm"])
            sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(ok, a, b), new, old)
            new_params = sel(new_params, state["params"])
            new_opt = sel(new_opt, state["opt"])
            opt_stats = dict(opt_stats, skipped=(~ok).astype(jnp.int32))
        metrics = {"loss": loss, **opt_stats,
                   # Lane-controller metering (Eq. 5 numerator, Level 2):
                   # static DP gradient-sync traffic for this step.
                   "collective_bytes": collective_bytes_of(grads, 2)}
        for k in ("aux_loss", "drop_frac"):
            if k in stats:
                metrics[k] = stats[k]
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def batch_pspecs(cfg: ModelConfig, rules: Rules, kind: str = "train"):
    """PartitionSpecs for a data batch dict."""
    specs = {"tokens": rules.spec("batch", None),
             "labels": rules.spec("batch", None)}
    if cfg.family == "vlm":
        specs["image_embeds"] = rules.spec("batch", None, None)
    if cfg.family == "encdec":
        specs["frames"] = rules.spec("batch", None, None)
    if kind != "train":
        specs.pop("labels")
    return specs
