"""Logical-axis sharding rules (DP / TP / EP / SP over the production mesh).

Model code annotates arrays with *logical* axis names; this module maps them
to mesh axes for whatever mesh is active. The production meshes are

    single-pod:  (data=16, model=16)            — 256 chips
    multi-pod:   (pod=2, data=16, model=16)     — 512 chips

Rules (MaxText-style):
    batch    -> (pod, data)   data parallelism, pods are an outer DP axis
    heads    -> model         tensor parallelism over attention heads
    kv       -> model         KV heads (padded when count < axis size)
    ff       -> model         MLP hidden
    vocab    -> model         embedding/unembedding table + logits
    experts  -> data          expert parallelism (MoE all-to-all crosses the
                              data axis — the interposer traffic ReSiPI manages)
    seq      -> None          (SP variants map it to model; see perf log)
    model_d / state / layers / capacity -> replicated

GSPMD pads uneven dimensions, so head counts that don't divide the axis are
legal (at a padding cost measured in the roofline pass).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None]

DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("data",),
    "expert_ff": ("model",),
    "seq": (),
    # Residual-stream (layer-boundary) sequence axis: sharded over model
    # under sequence parallelism (SP_OVERLAY). Kept distinct from "seq" so
    # SP never steals the model axis from heads/ff INSIDE a block —
    # Megatron-SP shards only the carries/norms between blocks.
    "seq_outer": (),
    # Decode KV caches shard their *sequence* dim over the model axis: GQA
    # kv-head counts (4/8) can't divide a 16-way axis, but 32k contexts can.
    # Decode attention then psum-reduces over the sharded seq — §Perf iter 2.
    "kv_seq": ("model",),
    # FSDP/ZeRO-3: weight embed-dims shard over the data axis, so params +
    # optimizer state divide by DP degree; XLA inserts the per-layer weight
    # all-gathers (measured in the collective roofline term). Without this,
    # params/opt replicate DP-fold — §Perf iteration 1 measures the delta.
    "model_d": ("data",),
    "state": (),
    "layers": (),
    "capacity": (),
    # DSE fleet axes (repro.core.distributed / launch.fleet): the leading
    # grid-point axis of a topology/placement/workload sweep and the island
    # axis of the annealed searches (search_placement_islands and the
    # search_codesign co-design chains) both shard over the 1-D fleet
    # mesh's "grid" axis (launch.mesh.make_fleet_mesh). On the production
    # meshes (no "grid" axis) they resolve to replicated, so sweep code
    # annotated with these axes runs unchanged everywhere.
    "sweep": ("grid",),
    "islands": ("grid",),
    # Pareto co-design outputs: the archive capacity axis and the scanned
    # topology-grid axis stay replicated — every fleet process carries the
    # whole front (the archive merges candidates from ALL islands, so
    # slicing it per-shard would drop cross-island dominators), and the
    # topology axis is a sequential lax.scan, never a data-parallel dim.
    "archive": (),
    "topology_grid": (),
}

# Overlays (hillclimb levers; see EXPERIMENTS.md §Perf).
SP_OVERLAY = {"seq_outer": ("model",)}                   # sequence parallel
TP_ONLY_OVERLAY = {"model_d": ()}                        # pre-FSDP baseline


class Rules:
    """Resolves logical axis names against the active mesh."""

    def __init__(self, mesh: Mesh, overrides: Optional[dict] = None):
        self.mesh = mesh
        table = dict(DEFAULT_RULES)
        if overrides:
            table.update(overrides)
        self.table = table

    def _mesh_axes(self, logical: Axis) -> Optional[tuple]:
        if logical is None:
            return None
        axes = tuple(a for a in self.table[logical]
                     if a in self.mesh.axis_names)
        return axes or None

    def spec(self, *logical_axes: Axis) -> P:
        resolved = []
        used = set()
        for ax in logical_axes:
            mesh_axes = self._mesh_axes(ax)
            if mesh_axes is None:
                resolved.append(None)
                continue
            fresh = tuple(a for a in mesh_axes if a not in used)
            used.update(fresh)
            if not fresh:
                resolved.append(None)
            elif len(fresh) == 1:
                resolved.append(fresh[0])
            else:
                resolved.append(fresh)
        return P(*resolved)

    def spec_for_shape(self, shape: Sequence[int],
                       *logical_axes: Axis) -> P:
        """Like spec(), but checks divisibility AT ALLOCATION TIME.

        pjit input shardings require exact divisibility; dims that don't
        divide their assigned mesh-axis product fall back to replicated —
        and crucially the mesh axis is then still AVAILABLE for a later
        dim (e.g. grok-1's 8 experts can't divide data=16, so the expert
        dim replicates and the weight's model_d dim takes the data axis
        instead of losing it — FSDP for non-dividing-expert MoE).
        """
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        resolved = []
        used = set()
        for dim, ax in zip(shape, logical_axes + (None,) * (
                len(shape) - len(logical_axes))):
            mesh_axes = self._mesh_axes(ax)
            if mesh_axes is None:
                resolved.append(None)
                continue
            fresh = tuple(a for a in mesh_axes if a not in used)
            prod = 1
            for a in fresh:
                prod *= sizes[a]
            if not fresh or dim % prod != 0:
                resolved.append(None)
                continue
            used.update(fresh)
            resolved.append(fresh[0] if len(fresh) == 1 else fresh)
        return P(*resolved)

    def sharding(self, *logical_axes: Axis) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical_axes))


_ACTIVE: list = []


def use_rules(rules: Rules):
    """Context manager installing rules for `shard()` constraints."""
    class _Ctx:
        def __enter__(self):
            _ACTIVE.append(rules)
            return rules

        def __exit__(self, *exc):
            _ACTIVE.pop()
            return False
    return _Ctx()


def active_rules() -> Optional[Rules]:
    return _ACTIVE[-1] if _ACTIVE else None


def shard(x: jax.Array, *logical_axes: Axis) -> jax.Array:
    """Apply a logical sharding constraint if rules are active (no-op in
    plain single-device tests, so model code runs everywhere unchanged)."""
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(*logical_axes))
