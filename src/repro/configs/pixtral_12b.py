"""pixtral-12b [vlm] — hf:mistralai/Pixtral-12B-2409.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. The pixtral-ViT
vision frontend is a STUB: input_specs provides precomputed patch embeddings
prepended to the token stream (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=131_072,
    activation="swiglu",
    frontend_embeds=256,        # patch embeddings per image (stub frontend)
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="pixtral-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, frontend_embeds=8)
