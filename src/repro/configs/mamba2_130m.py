"""mamba2-130m [ssm] — SSD (state-space duality), arXiv:2405.21060.

24L d_model=768, attention-free (d_ff=0), vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_432,            # padded to /256 for TP (real: 50280)
    vocab_real=50_280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                  chunk_len=128),  # 256->128: halves the [Q,Q] SSD
                                   # intermediates (§Perf iteration 5)
    activation="swiglu",
    rope_theta=0.0,
    sub_quadratic=True,
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
        vocab_real=None,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1,
                      chunk_len=32))
