"""phi4-mini-3.8b [dense] — arXiv:2412.08905. RoPE SwiGLU GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200_064,
    activation="swiglu",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="phi4-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=256, vocab=512)
