"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table), arXiv:2501.kimi2.

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840,
MoE 384 experts top-8. Adafactor optimizer (DESIGN.md §7): ~1.03T params
cannot carry 14 B/param AdamW state on 512 x 16 GB chips.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163_840,
    moe=MoEConfig(n_experts=384, top_k=8, expert_d_ff=2048,
                  capacity_factor=1.25),
    activation="swiglu",
    optimizer="adafactor",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="kimi-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=64))
