"""seamless-m4t-large-v2 [audio] — enc-dec multimodal, arXiv:2308.11596.

24L total (12 enc + 12 dec assumed split — the assignment lists the combined
depth), d_model=1024, 16H (GQA kv=16 => MHA), d_ff=8192, vocab=256206.
Modality frontend is a STUB: input_specs provides precomputed speech-frame
embeddings (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256_256,           # padded to /256 for TP (real: 256206)
    vocab_real=256_206,
    activation="gelu",
    use_bias=True,
    frontend_embeds=1,          # encoder consumes frame embeddings
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="seamless-smoke", n_layers=4, encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        vocab_real=None)
