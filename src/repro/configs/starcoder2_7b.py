"""starcoder2-7b [dense] — arXiv:2402.19173. GQA, RoPE.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18_432,
    vocab=49_152,
    activation="gelu",          # starcoder2 uses gelu MLP
    use_bias=True,
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="starcoder2-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=384, vocab=512)
