"""zamba2-7b [hybrid] — arXiv:2411.15242. Mamba2 + shared attention blocks.

81L d_model=3584 32H (MHA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Hybrid layout: Mamba2 backbone with a *shared* (weight-tied) attention+MLP
block inserted every `attn_every` layers, as in the Zamba family.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab=32_000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                  chunk_len=128),
    attn_every=6,
    activation="swiglu",
    sub_quadratic=True,
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, attn_every=2,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1,
                      chunk_len=32))
