"""Architecture/config system.

One `ModelConfig` covers all ten assigned architecture families; each
src/repro/configs/<arch>.py instantiates the exact published numbers and a
`smoke()` reduction of the same family for CPU tests. Input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are defined here as
`ShapeCell`s with per-family skip logic (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk_len: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int                              # table size (may be padded)
    vocab_real: Optional[int] = None        # true vocab when `vocab` padded
    head_dim: Optional[int] = None          # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): attention block shared + inserted every k layers
    attn_every: int = 0                     # 0 = per family default
    # encoder-decoder split (seamless): n_layers = enc + dec
    encoder_layers: int = 0
    activation: str = "swiglu"              # swiglu | gelu
    use_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # modality frontend stub: number of precomputed embedding positions the
    # input_specs provide ([audio]/[vlm] archs; DESIGN.md §5)
    frontend_embeds: int = 0
    optimizer: str = "adamw"                # adamw | adafactor (DESIGN.md §7)
    remat_policy: str = "nothing_saveable"
    # attention implementation threshold: sequences longer than this use the
    # blockwise (flash) attention path so prefill_32k lowers within memory
    flash_block_q: int = 1024
    flash_block_kv: int = 1024
    sub_quadratic: bool = False             # True for ssm/hybrid (long_500k)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def real_vocab(self) -> int:
        """True vocabulary size; `vocab` may be padded for TP divisibility
        (standard practice — MaxText/Megatron pad to the TP degree). Loss
        and sampling mask logits beyond this index."""
        return self.vocab_real or self.vocab

    @property
    def decoder_layers(self) -> int:
        return self.n_layers - self.encoder_layers

    def param_count(self) -> int:
        """Approximate parameter count (reported in dry-run tables)."""
        d, v = self.d_model, self.vocab
        if self.n_heads > 0:
            hd = self.resolved_head_dim
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        else:                       # attention-free (pure SSM)
            attn = 0
        if self.moe:
            ff = self.moe.n_experts * 3 * d * self.moe.expert_d_ff \
                + d * self.moe.n_experts
        elif self.family in ("ssm",):
            ff = 0
        else:
            mult = 3 if self.activation == "swiglu" else 2
            ff = mult * d * self.d_ff
        if self.family == "ssm" or (self.family == "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            ssm_block = d * (2 * d_in + 2 * s.n_groups * s.d_state
                             + d_in // s.head_dim) + d_in * d
            if self.family == "ssm":
                per_layer = ssm_block
            else:
                n_attn = self.n_layers // max(self.attn_every, 1)
                mult = 3 if self.activation == "swiglu" else 2
                per_layer = ssm_block + (attn + mult * d * self.d_ff) \
                    * n_attn / max(self.n_layers, 1)
        else:
            per_layer = attn + ff
        cross = attn if self.family == "encdec" else 0
        total = self.n_layers * (per_layer + cross * 0.5) + 2 * v * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_part = self.param_count() - self.n_layers * (
            self.moe.n_experts * 3 * d * self.moe.expert_d_ff)
        active_ff = self.n_layers * self.moe.top_k * 3 * d \
            * self.moe.expert_d_ff
        return int(dense_part + active_ff)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Skip policy (DESIGN.md §6). Returns (runnable, reason)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token decode skipped "
                       "per assignment brief (sub-quadratic archs only)")
    return True, ""
