"""command-r-plus-104b [dense] — hf:CohereForAI (104B class). GQA, no-bias.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33_792,
    vocab=256_000,
    activation="swiglu",
    use_bias=False,
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="commandr-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=352, vocab=512)
