"""grok-1-314b [moe] — hf:xai-org/grok-1. 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
Adafactor optimizer per DESIGN.md §7 (AdamW state would exceed 16 GB/chip).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab=131_072,
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32_768),
    activation="gelu",
    optimizer="adafactor",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="grok1-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=128))
