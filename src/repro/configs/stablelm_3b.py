"""stablelm-3b [dense] — hf:stabilityai/stablelm (3B class).

32L d_model=2560 32H (MHA: kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50_304,
    activation="swiglu",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab=512)
