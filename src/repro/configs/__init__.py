"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ModelConfig, ShapeCell, SHAPES,
                                cell_applicable, shape_by_name)

_MODULES: Dict[str, str] = {
    "mamba2-130m": "repro.configs.mamba2_130m",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3p8b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "zamba2-7b": "repro.configs.zamba2_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return importlib.import_module(_MODULES[name]).smoke()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "ARCH_NAMES",
           "get_config", "get_smoke_config", "all_configs",
           "cell_applicable", "shape_by_name"]
