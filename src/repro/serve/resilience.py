"""Closed-loop self-healing reconfiguration (the ReSiPI run-time story).

`ResilienceRuntime` closes the loop the open-loop layers left dangling:
`SimSession` streams telemetry per chunk, a threshold+hysteresis policy
detects degradation against an EWMA healthy-latency baseline, and a
detected fault triggers a *warm-restarted* device placement search
(`search_placement`, engine="device") seeded from the incumbent placement
with the failed routers — as reported by the hardware status register
(`faults.FaultInjector.failed_positions`) — masked out of the proposal
space. The recovered placement swaps in live (`SimSession.swap_placement`
is zero-recompile: placement reaches the executable only through traced
selection tables) and every re-placement is billed its physical PCM
switching cost (`faults.placement_reconfig_cost`).

The control loop is deliberately host-side and cheap: one float of
telemetry per chunk crosses the device boundary (the chunk summary the
session already returns), and the expensive reaction — the search — is a
single compiled dispatch.

The detection core (`DegradationDetector`) and the reaction core
(`plan_replacement`) are standalone so the continuous-batching
`SessionServer` (serve.engine) runs the same closed loop over its packed
lanes: one detector on the per-tick mean latency, one planned
re-placement swapped into every lane at once.

Driven by benchmarks/bench_faults.py (detection latency / recovery time /
availability under a fault storm) and examples/noc_reconfig_demo.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.faults import placement_reconfig_cost, strip_faults
from repro.core.search import repair_placement
from repro.core.simulator import SimSession, search_placement


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """When to declare degradation and how hard to search for a fix.

    A chunk breaches when its mean latency exceeds
    ``(1 + threshold_frac) x baseline``; `hysteresis` consecutive breaches
    trigger a re-placement (one noisy chunk never does); `cooldown` chunks
    must pass after a re-placement before the next one (the PCM cells are
    re-programming and the search needs fresh post-swap telemetry). The
    baseline is an EWMA over *healthy* chunks only, so it remembers the
    pre-fault level while the fault is biting — recovery is measured
    against what the network used to deliver, not against the degraded
    present.
    """
    threshold_frac: float = 0.15
    hysteresis: int = 2
    cooldown: int = 2
    baseline_ewma: float = 0.25
    search_generations: int = 8
    search_population: int = 8
    search_seed: int = 0

    def __post_init__(self):
        if not self.threshold_frac > 0:
            raise ValueError(f"threshold_frac must be > 0, got "
                             f"{self.threshold_frac}")
        if self.hysteresis < 1:
            raise ValueError(f"hysteresis must be >= 1, got "
                             f"{self.hysteresis}")
        if self.cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {self.cooldown}")
        if not 0 < self.baseline_ewma <= 1:
            raise ValueError(f"baseline_ewma must be in (0, 1], got "
                             f"{self.baseline_ewma}")


class DegradationDetector:
    """The detection half of the closed loop, as a reusable state machine.

    Feed it one latency sample per chunk/tick (`update`); it maintains the
    healthy-EWMA baseline (frozen while breaching, so recovery is judged
    against the pre-fault level), counts consecutive breaches against the
    hysteresis, and reports `fire=True` exactly when the caller should
    react — at which point the detector arms its own cooldown.
    """

    def __init__(self, policy: ResiliencePolicy = ResiliencePolicy()):
        self.policy = policy
        self.baseline: Optional[float] = None
        self._breaches = 0
        self._cooldown = 0

    def in_band(self, latency: float) -> bool:
        """Is this sample within the acceptance band of the baseline?"""
        return self.baseline is None or \
            latency <= (1.0 + self.policy.threshold_frac) * self.baseline

    def update(self, latency: float) -> dict:
        """One telemetry sample -> {latency, baseline, breach, fire}."""
        p = self.policy
        lat = float(latency)
        if self.baseline is None:
            self.baseline = lat
        breach = lat > (1.0 + p.threshold_frac) * self.baseline
        if breach:
            self._breaches += 1
        else:
            self._breaches = 0
            self.baseline = ((1.0 - p.baseline_ewma) * self.baseline
                             + p.baseline_ewma * lat)
        fire = False
        if self._cooldown > 0:
            self._cooldown -= 1
        elif self._breaches >= p.hysteresis:
            fire = True
            self._breaches = 0
            self._cooldown = p.cooldown
        return {"latency": lat, "baseline": float(self.baseline),
                "breach": bool(breach), "fire": fire}


def plan_replacement(clean_chunk: dict, sim, current_placement,
                     blocked: Sequence[Tuple[int, int]],
                     policy: ResiliencePolicy, *,
                     incumbent=None, seed_offset: int = 0) -> dict:
    """The reaction half: one warm-restarted blocked re-placement plan.

    Scores candidates on the CLEAN traffic model (the fault frame only
    constrains WHERE, via `blocked`), warm-restarts from `incumbent` (or
    the live placement) repaired off the dead routers, and returns the
    swap-ready plan with its physical PCM bill. The caller applies it
    (`SimSession.swap_placement` / `SessionServer` lane-wide swap) and
    accumulates the accounting.
    """
    old = current_placement
    start = incumbent if incumbent is not None else old
    init = repair_placement(start, tuple(blocked), sim.cfg)
    res = search_placement(
        clean_chunk, sim, engine="device",
        generations=policy.search_generations,
        population=policy.search_population,
        seed=policy.search_seed + seed_offset, init=init,
        blocked_positions=tuple(blocked))
    new_p = res["best_placement"]
    cost = placement_reconfig_cost(old, new_p)
    return {"old_placement": old, "new_placement": new_p,
            "incumbent_placement": res.get("incumbent_placement", new_p),
            "blocked_positions": tuple(blocked),
            "search_best_score": res["best_score"],
            "moved_gateways": cost["moved_gateways"],
            "pcm_nj": cost["pcm_nj"],
            "stall_cycles": cost["stall_cycles"]}


class ResilienceRuntime:
    """Watch a `SimSession`, heal it by re-placing gateways around faults.

    Usage (the closed loop, see examples/noc_reconfig_demo.py)::

        runtime = ResilienceRuntime(SimSession.init(sim))
        for t0, chunk in enumerate_chunks(trace):
            faulted = injector.inject(chunk, current_cfg, t0)
            runtime.report_failed_positions(injector.failed_positions(t0))
            out = runtime.observe(faulted)
            if out["healed"]:
                ...  # placement moved; injector re-compiles vs new cfg

    Accounting lives on the instance: `total_pcm_nj` / `total_stall_cycles`
    accumulate the physical re-placement bill, `events` records one dict
    per chunk (latency, baseline, breach, heal details) for the
    detection-latency / recovery-time metrics in BENCH_faults.json.
    """

    def __init__(self, session: SimSession,
                 policy: ResiliencePolicy = ResiliencePolicy()):
        self.session = session
        self.policy = policy
        self.detector = DegradationDetector(policy)
        self.events: List[dict] = []
        self.total_pcm_nj = 0.0
        self.total_stall_cycles = 0
        self.replacements = 0
        self._blocked: Tuple[Tuple[int, int], ...] = ()
        self._incumbent = None        # annealer state for warm restarts
        self._last_clean_chunk: Optional[dict] = None

    @property
    def baseline(self) -> Optional[float]:
        """Healthy-EWMA latency baseline (the detector's view)."""
        return self.detector.baseline

    @property
    def current_cfg(self):
        """NetworkConfig carrying the session's LIVE placement — what a
        placement-aware fault environment (FaultInjector.inject) should
        compile against, so position-targeted faults stop biting once the
        gateways have moved off the dead routers."""
        return self.session.sim.cfg.with_placement(self.session.placement)

    def report_failed_positions(
            self, positions: Sequence[Tuple[int, int]]) -> None:
        """Feed the hardware status register (FaultInjector.failed_positions
        or a real BMC): routers listed here are masked out of the next
        search's proposal space."""
        self._blocked = tuple(sorted(
            {(int(x), int(y)) for (x, y) in positions}))

    def observe(self, chunk: dict) -> dict:
        """Stream one chunk; detect degradation; heal when policy fires.

        Returns {records, summary, latency, baseline, breach, healed} —
        `healed` is None or the heal event dict (old/new placement, search
        result, PCM bill).
        """
        out = self.session.step_chunk(chunk)
        # Re-placement candidates are scored on the clean traffic model:
        # the search explores placements for the demand, the fault frame
        # only ever constrains WHERE via the blocked mask.
        self._last_clean_chunk = strip_faults(chunk)
        det = self.detector.update(float(out["summary"]["mean_latency"]))
        healed = self._heal() if det["fire"] else None
        event = {"latency": det["latency"], "baseline": det["baseline"],
                 "breach": det["breach"], "healed": healed}
        self.events.append(event)
        return dict(out, **event)

    def _heal(self) -> dict:
        """One live re-placement: warm-restarted blocked search + swap."""
        plan = plan_replacement(
            self._last_clean_chunk, self.session.sim,
            self.session.placement, self._blocked, self.policy,
            incumbent=self._incumbent, seed_offset=self.replacements)
        self.session.swap_placement(plan["new_placement"])
        self._incumbent = plan["incumbent_placement"]
        self.total_pcm_nj += plan["pcm_nj"]
        self.total_stall_cycles += plan["stall_cycles"]
        self.replacements += 1
        return {k: plan[k] for k in
                ("old_placement", "new_placement", "blocked_positions",
                 "search_best_score", "moved_gateways", "pcm_nj",
                 "stall_cycles")}
