"""Overload-robust continuous-batching session server.

The serving layer the ROADMAP's "millions of users" item asks for: N
concurrent `SimSession`-style streams share ONE padded executable. Each
tick the server packs the next padded chunk of every resident session
into a `[lanes, chunk_intervals]` batch and advances all of them with a
single vmapped dispatch (`simulator.session_tick`); the `t_mask` freeze
semantics make every irregularity exact — an empty lane, a session
backing off after a transient failure, or a final partial chunk all ride
along as masked rows that inject nothing, record zeros, and freeze their
carry. Destination-aware traces (a `dest` [C, C] matrix) serve too: each
tick packs the dest-carrying lanes as their own group with a per-lane
`dest` [B, C, C] batch (the dest path is all-or-nothing per executable,
and mixing would silently change dest-free lanes' numbers). Lane k of
the batched tick is bit-identical to a standalone `SimSession` stepping
the same chunks (pinned by `replay_standalone` and tests/test_serve.py),
so sharing the executable costs nothing in fidelity.

Around that hot loop sits the robustness envelope, every decision a
`policies.ServerPolicy` knob:

  * bounded admission queue with backpressure — `submit` answers
    accept / throttle / shed by priority class, premium displaces queued
    batch work, a queued-interval budget bounds memory, and every
    refusal carries a taxonomy reason;
  * per-session deadlines — queued or mid-stream, an expired session
    terminates with a well-formed partial `summary()` (never a raise);
  * transient-failure retry — a failed lane step rolls its carry back
    (the tick does not donate its inputs), backs off exponentially, and
    terminates RETRY_EXHAUSTED past the retry budget;
  * idle eviction — an open stream that stops feeding frees its lane;
  * graceful degradation — sustained queue pressure (hysteresis band)
    switches the server to coalesced ticks: several same-shape dispatches
    back-to-back drain residents faster, and low-priority submissions
    shed at the door, instead of latency collapse;
  * closed-loop self-healing — a `resilience.DegradationDetector` on the
    per-tick mean latency plus `plan_replacement` swap a blocked-search
    re-placement into EVERY lane at once (zero recompile: placement
    reaches the executable only through the traced selection tables),
    healthy sessions never drop;
  * a metrics/health surface — admit/shed/evict/retry counters, queue
    depth, p50/p99 dispatch wall latency, availability — consumed by
    benchmarks/bench_serve.py.

Fault frames live on HARDWARE time (tick index x chunk_intervals), shared
by every lane: all sessions experience the same interposer each tick.
"""
from __future__ import annotations

import time
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import normalize_placement, resolve_gateway_positions
from repro.core.simulator import (SimConfig, SimSession, init_session_states,
                                  selection_tables_jax, session_tick)
from repro.serve import policies as P
from repro.serve.policies import ServerPolicy
from repro.serve.resilience import (DegradationDetector, ResiliencePolicy,
                                    plan_replacement)
from repro.serve.scheduler import AdmissionQueue, ServeSession, SessionRequest

_COUNTER_KEYS = (
    "submitted", "admitted", "completed", "shed_queue_full", "shed_memory",
    "shed_priority", "displaced", "deadline_expired", "idle_evicted",
    "retries", "retry_exhausted", "dispatches", "coalesced_dispatches",
    "served_chunks", "degraded_ticks", "heals")


class SessionServer:
    """Continuous-batching multi-session simulation server.

    ::

        server = SessionServer(sim, ServerPolicy(lanes=8))
        out = server.submit(SessionRequest(trace=tr))   # accept/throttle/shed
        server.run(ticks=32)                            # or tick() by hand
        server.drain()
        summaries = [s.summary() for s in server.completed]

    `fault_env` (a `faults.FaultInjector`) plays the hardware; pass a
    `ResiliencePolicy` as `resilience` to close the self-healing loop.
    `step_fault_hook(tick, session)` -> bool injects transient *server*
    step failures for the retry path (tests/benchmarks).
    """

    def __init__(self, sim: SimConfig,
                 policy: ServerPolicy = ServerPolicy(), *,
                 fault_env=None,
                 resilience: Optional[ResiliencePolicy] = None,
                 step_fault_hook: Optional[
                     Callable[[int, ServeSession], bool]] = None):
        self.sim = sim
        self.policy = policy
        self.fault_env = fault_env
        self.step_fault_hook = step_fault_hook
        self.placement = normalize_placement(
            resolve_gateway_positions(sim.cfg), sim.cfg)
        self._tables = selection_tables_jax(sim.cfg)
        self._states = init_session_states(sim, policy.lanes)
        self._fresh = init_session_states(sim, 1)
        self._lanes: List[Optional[ServeSession]] = [None] * policy.lanes
        self.queue = AdmissionQueue(policy)
        self.sessions: Dict[str, ServeSession] = {}
        self.completed: List[ServeSession] = []
        self.terminated: List[ServeSession] = []   # non-completed endings
        self.tick_count = 0
        self.hw_intervals = 0        # hardware time consumed (fault frames)
        self.counters = Counter({k: 0 for k in _COUNTER_KEYS})
        self.events: List[dict] = []
        self.detector = DegradationDetector(resilience) \
            if resilience is not None else None
        self.resilience = resilience
        self.replacements = 0
        self.total_pcm_nj = 0.0
        self.total_stall_cycles = 0
        self._incumbent = None
        self._blocked: Tuple[Tuple[int, int], ...] = ()
        self._degraded = False
        self._over = 0
        self._under = 0
        self._dispatch_wall_s: List[float] = []
        self._in_band: List[bool] = []
        self._last_demand: Optional[dict] = None

    # ------------------------------------------------------------------ API
    @property
    def current_cfg(self):
        """NetworkConfig carrying the LIVE placement (what a
        placement-aware FaultInjector compiles frames against)."""
        return self.sim.cfg.with_placement(self.placement)

    @property
    def degraded(self) -> bool:
        return self._degraded

    @property
    def sessions_in_flight(self) -> int:
        return sum(s is not None for s in self._lanes)

    def submit(self, req) -> dict:
        """Admit a request (or bare trace dict): the backpressure door.

        Returns {signal, reason, session_id}: ACCEPT or THROTTLE means
        queued (throttle = "slow down"); SHED means refused, with the
        taxonomy reason, and the session object still yields a well-formed
        zero-served `summary()`.
        """
        if isinstance(req, dict):
            req = SessionRequest(trace=req)
        sess = ServeSession(req, self.policy, self.sim.cfg.n_chiplets,
                            self.tick_count)
        self.counters["submitted"] += 1
        self.sessions[sess.id] = sess
        if self._degraded and sess.priority < self.policy.degrade_min_priority:
            self._reject(sess, P.SHED_PRIORITY)
            return {"signal": P.SHED, "reason": P.SHED_PRIORITY,
                    "session_id": sess.id}
        signal, reason, displaced = self.queue.offer(sess)
        for victim, why in displaced:
            self._reject(victim, why)
            self.counters["displaced"] += 1
        if signal == P.SHED:
            self._reject(sess, reason)
        return {"signal": signal, "reason": reason, "session_id": sess.id}

    def feed(self, session_id: str, trace: dict) -> int:
        """Append intervals to an open (streaming) session."""
        return self._live(session_id).feed(trace)

    def close(self, session_id: str) -> None:
        """End an open session's input; it completes once drained."""
        self._live(session_id).closed = True

    def tick(self) -> dict:
        """One server tick: expire -> evict -> admit -> pack -> dispatch
        (coalesced when degraded) -> retry/complete -> heal. Never raises
        for per-session conditions — they terminate via the taxonomy."""
        now = self.tick_count
        self._expire_deadlines(now)
        self._complete_drained(now)
        self._evict_idle(now)
        admitted = self._admit(now)
        self._update_degraded()
        reps = self.policy.degrade_coalesce if self._degraded else 1
        served_lanes = 0
        lat_sum, valid_sum = 0.0, 0.0
        for rep in range(reps):
            # Ready lanes split into destination-free and destination-
            # carrying groups: `session_tick`'s dest path is all-or-nothing
            # per batch, and serving a dest-free lane through a uniform
            # matrix would silently change its numbers (replay parity).
            # Each group is its own (cached) executable; pure workloads
            # still dispatch exactly once per tick.
            dispatched = 0
            for want_dest in (False, True):
                packed = self._pack(now, want_dest=want_dest)
                if packed is None:
                    continue
                dispatched += 1
                s_lat, s_valid, n = self._dispatch(packed, now)
                lat_sum += s_lat
                valid_sum += s_valid
                served_lanes += n
            if dispatched == 0:
                break
            if rep > 0:
                self.counters["coalesced_dispatches"] += 1
        det = self._observe(lat_sum, valid_sum, served_lanes)
        self.tick_count += 1
        event = {"tick": now, "admitted": admitted,
                 "in_flight": self.sessions_in_flight,
                 "queue_depth": len(self.queue),
                 "degraded": self._degraded,
                 "served_lanes": served_lanes, **det}
        self.events.append(event)
        return event

    def run(self, ticks: int, arrivals: Optional[
            Callable[[int], Sequence[SessionRequest]]] = None) -> List[dict]:
        """Drive `ticks` ticks; `arrivals(tick)` submits before each."""
        out = []
        for _ in range(ticks):
            if arrivals is not None:
                for req in arrivals(self.tick_count):
                    self.submit(req)
            out.append(self.tick())
        return out

    def drain(self, max_ticks: int = 10_000) -> int:
        """Tick until no session is queued or resident; returns ticks used.

        Raises only if `max_ticks` elapses with work still pending (a
        liveness bug — with deadlines/retry bounds every session
        terminates in bounded time)."""
        for i in range(max_ticks):
            if not len(self.queue) and self.sessions_in_flight == 0:
                return i
            self.tick()
        raise RuntimeError(
            f"drain() did not converge in {max_ticks} ticks "
            f"({self.sessions_in_flight} resident, {len(self.queue)} queued)")

    def metrics(self) -> dict:
        """The monitoring surface (bench_serve.py -> BENCH_serve.json)."""
        wall = np.asarray(self._dispatch_wall_s)
        pct = (lambda q: float(np.percentile(wall, q))) if wall.size else \
            (lambda q: None)
        return {
            **{k: int(self.counters[k]) for k in _COUNTER_KEYS},
            "ticks": self.tick_count,
            "queue_depth": len(self.queue),
            "queued_intervals": self.queue.pending_intervals,
            "sessions_in_flight": self.sessions_in_flight,
            "degraded": self._degraded,
            "p50_chunk_s": pct(50),
            "p99_chunk_s": pct(99),
            "availability": float(np.mean(self._in_band))
            if self._in_band else None,
            "baseline_latency": None if self.detector is None
            else self.detector.baseline,
            "replacements": self.replacements,
            "total_pcm_nj": self.total_pcm_nj,
            "total_stall_cycles": self.total_stall_cycles,
        }

    def health(self) -> dict:
        """Coarse health verdict for load balancers / dashboards."""
        fill = len(self.queue) / max(self.policy.queue_capacity, 1)
        status = "degraded" if self._degraded else (
            "overloaded" if fill >= self.policy.degrade_hi else "ok")
        return {"status": status, "queue_fill": fill,
                "sessions_in_flight": self.sessions_in_flight,
                "degraded": self._degraded,
                "blocked_positions": list(self._blocked)}

    def swap_placement(self, positions) -> dict:
        """Operator-initiated live re-placement of EVERY lane at once
        (zero recompile — tables are traced inputs); returns the PCM bill."""
        from repro.core.faults import placement_reconfig_cost

        new_p = normalize_placement(positions, self.sim.cfg)
        cost = placement_reconfig_cost(self.placement, new_p)
        self._tables = selection_tables_jax(
            self.sim.cfg.with_placement(new_p))
        self.placement = new_p
        self.total_pcm_nj += cost["pcm_nj"]
        self.total_stall_cycles += cost["stall_cycles"]
        return cost

    # ------------------------------------------------------------ internals
    def _live(self, session_id: str) -> ServeSession:
        sess = self.sessions.get(session_id)
        if sess is None or sess.terminal:
            raise KeyError(f"no live session {session_id!r}")
        return sess

    def _reject(self, sess: ServeSession, reason: str) -> None:
        sess.terminate(reason, self.tick_count)
        self.counters[reason] += 1
        self.terminated.append(sess)

    def _free_lane(self, sess: ServeSession, reason: str, now: int) -> None:
        lane = sess.lane
        sess.terminate(reason, now)
        if lane is not None:
            self._lanes[lane] = None
        if reason == P.COMPLETED:
            self.counters["completed"] += 1
            self.completed.append(sess)
        else:
            self.counters[reason] += 1
            self.terminated.append(sess)

    def _expire_deadlines(self, now: int) -> None:
        for victim in self.queue.remove_expired(now):
            victim.terminate(P.DEADLINE_EXPIRED, now)
            self.counters["deadline_expired"] += 1
            self.terminated.append(victim)
        for sess in list(self._lanes):
            if sess is not None and sess.deadline_tick is not None \
                    and now >= sess.deadline_tick:
                self._free_lane(sess, P.DEADLINE_EXPIRED, now)

    def _complete_drained(self, now: int) -> None:
        """A resident stream closed AFTER its last fed chunk was served
        completes here (the in-dispatch check only sees closes that
        precede the final chunk)."""
        for sess in list(self._lanes):
            if sess is not None and sess.closed and not sess.pending:
                self._free_lane(sess, P.COMPLETED, now)

    def _evict_idle(self, now: int) -> None:
        for sess in list(self._lanes):
            if sess is not None and not sess.pending and not sess.closed \
                    and now - sess.last_progress_tick \
                    >= self.policy.idle_evict_ticks:
                self._free_lane(sess, P.IDLE_EVICTED, now)

    def _admit(self, now: int) -> int:
        admitted = 0
        for lane, occupant in enumerate(self._lanes):
            if occupant is not None:
                continue
            sess = self.queue.pop_next()
            if sess is None:
                break
            sess.lane = lane
            sess.status = "running"
            sess.admitted_tick = now
            sess.placement_at_admit = self.placement
            sess.last_progress_tick = now
            self._lanes[lane] = sess
            # Fresh lane carry: row `lane` becomes a standalone session's
            # initial state, so the lane replays `SimSession.init` exactly.
            self._states = jax.tree.map(
                lambda b, f: b.at[lane].set(f[0]), self._states, self._fresh)
            self.counters["admitted"] += 1
            admitted += 1
        return admitted

    def _update_degraded(self) -> None:
        p = self.policy
        fill = len(self.queue) / max(p.queue_capacity, 1)
        if fill >= p.degrade_hi:
            self._over += 1
            self._under = 0
        elif fill <= p.degrade_lo:
            self._under += 1
            self._over = 0
        else:
            self._over = 0
            self._under = 0
        if not self._degraded and self._over >= p.degrade_patience:
            self._degraded = True
        elif self._degraded and self._under >= p.degrade_patience:
            self._degraded = False
        if self._degraded:
            self.counters["degraded_ticks"] += 1

    def _pack(self, now: int, *, want_dest: bool = False) -> Optional[dict]:
        """Stack each ready lane's next padded chunk into the [B, T] batch
        (idle lanes ride as all-masked rows); None if nothing to serve.

        `want_dest` selects the destination-carrying lane group: those
        batches add a per-lane `dest` [B, C, C] and route through the
        dest-aware tick executable. Non-member rows get a valid uniform
        matrix but are fully masked (zero injection, frozen carry), so the
        filler never contributes."""
        p = self.policy
        b, t, c = p.lanes, p.chunk_intervals, self.sim.cfg.n_chiplets
        ext = np.zeros((b, t, c), np.float32)
        mem = np.zeros((b, t), np.float32)
        intra = np.zeros((b, t, c), np.float32)
        frac = np.zeros((b,), np.float32)
        mask = np.zeros((b, t), np.float32)
        dmat = None
        if want_dest:
            uniform = np.full((c, c), 1.0 / max(c - 1, 1), np.float32)
            np.fill_diagonal(uniform, 0.0)
            dmat = np.broadcast_to(uniform, (b, c, c)).copy()
        ready = []
        for lane, sess in enumerate(self._lanes):
            if sess is None or not sess.ready(now):
                continue
            ch = sess.pending[0]
            if (ch.get("dest") is not None) != want_dest:
                continue
            ext[lane] = np.asarray(ch["ext_load"], np.float32)
            mem[lane] = np.asarray(ch["mem_load"], np.float32)
            intra[lane] = np.asarray(ch["int_load"], np.float32)
            frac[lane] = float(np.asarray(ch["ext_frac"]))
            mask[lane] = np.asarray(
                ch.get("t_mask", np.ones((t,), np.float32)), np.float32)
            if want_dest:
                dmat[lane] = np.asarray(ch["dest"], np.float32)
            ready.append(lane)
        if not ready:
            return None
        batch = {"ext_load": ext, "mem_load": mem, "int_load": intra,
                 "ext_frac": frac, "t_mask": mask}
        if want_dest:
            batch["dest"] = dmat
        return {"batch": batch, "ready": ready}

    def _tick_frame(self) -> Optional[dict]:
        """The shared hardware-time fault frame for this dispatch window
        (None once past the injector's horizon — storms are finite)."""
        if self.fault_env is None:
            return None
        t0, t1 = self.hw_intervals, \
            self.hw_intervals + self.policy.chunk_intervals
        if t1 > self.fault_env.horizon:
            return None
        self._blocked = tuple(self.fault_env.failed_positions(t0))
        return self.fault_env.frame_for(self.current_cfg, t0, t1)

    def _dispatch(self, packed: dict, now: int) -> Tuple[float, float, int]:
        """One batched step + per-lane outcome handling. Returns the
        (latency sum, valid-interval sum, lanes served) telemetry."""
        batch, ready = packed["batch"], packed["ready"]
        frame = self._tick_frame()
        old_states = self._states          # kept for lane rollback: the
        t0 = time.perf_counter()           # tick never donates its carry
        new_states, recs, sums = session_tick(
            old_states, batch, self._tables, self.sim, frame=frame)
        jax.block_until_ready(sums)
        self._dispatch_wall_s.append(time.perf_counter() - t0)
        self.counters["dispatches"] += 1
        self.hw_intervals += self.policy.chunk_intervals

        host_sums = {k: np.asarray(v) for k, v in sums.items()}
        keep = np.ones((self.policy.lanes,), bool)
        lat_sum, valid_sum, served = 0.0, 0.0, 0
        for lane in ready:
            sess = self._lanes[lane]
            lane_sums = {k: sums[k][lane] for k in sums}
            failed = any(not np.isfinite(host_sums[k][lane])
                         for k in host_sums)
            if self.step_fault_hook is not None \
                    and self.step_fault_hook(now, sess):
                failed = True
            if failed:
                keep[lane] = False           # roll this lane's carry back
                self.counters["retries"] += 1
                if not sess.fail(now, self.policy):
                    self._free_lane(sess, P.RETRY_EXHAUSTED, now)
                continue
            sess.advance(
                lane_sums, now, self.placement, frame,
                records={k: recs[k][lane] for k in recs}
                if self.policy.keep_records else None,
                keep_records=self.policy.keep_records)
            self.counters["served_chunks"] += 1
            lat_sum += float(host_sums["latency"][lane])
            valid_sum += float(host_sums["valid_intervals"][lane])
            served += 1
            if sess.closed and not sess.pending:
                self._free_lane(sess, P.COMPLETED, now)
        if served:
            self._demand_sample(batch, ready)
        if keep.all():
            self._states = new_states
        else:
            k = jnp.asarray(keep)
            self._states = jax.tree.map(
                lambda nb, ob: jnp.where(
                    k.reshape((k.shape[0],) + (1,) * (nb.ndim - 1)), nb, ob),
                new_states, old_states)
        return lat_sum, valid_sum, served

    def _demand_sample(self, batch: dict, ready: List[int]) -> None:
        """Mean served-lane demand: the clean chunk re-placement candidates
        are scored on (lane chunks never carry fault keys — faults attach
        at the tick level, so no strip is needed)."""
        idx = np.asarray(ready)
        self._last_demand = {
            "ext_load": batch["ext_load"][idx].mean(axis=0),
            "mem_load": batch["mem_load"][idx].mean(axis=0),
            "int_load": batch["int_load"][idx].mean(axis=0),
            "ext_frac": float(batch["ext_frac"][idx].mean()),
            "t_mask": batch["t_mask"][idx].max(axis=0),
        }

    def _observe(self, lat_sum: float, valid_sum: float,
                 served_lanes: int) -> dict:
        """Feed the tick's mean latency to the detector; heal on fire."""
        if self.detector is None or served_lanes == 0 or valid_sum <= 0:
            return {"latency": None, "baseline": None, "breach": False,
                    "healed": None}
        det = self.detector.update(lat_sum / valid_sum)
        self._in_band.append(not det["breach"])
        healed = self._heal() if det["fire"] and self._last_demand is not None \
            else None
        return dict(det, healed=healed)

    def _heal(self) -> dict:
        """One live re-placement swapped into every lane (the server-wide
        analogue of ResilienceRuntime._heal)."""
        plan = plan_replacement(
            self._last_demand, self.sim, self.placement, self._blocked,
            self.resilience, incumbent=self._incumbent,
            seed_offset=self.replacements)
        self._tables = selection_tables_jax(
            self.sim.cfg.with_placement(plan["new_placement"]))
        self.placement = plan["new_placement"]
        self._incumbent = plan["incumbent_placement"]
        self.total_pcm_nj += plan["pcm_nj"]
        self.total_stall_cycles += plan["stall_cycles"]
        self.replacements += 1
        self.counters["heals"] += 1
        return {k: plan[k] for k in
                ("old_placement", "new_placement", "blocked_positions",
                 "search_best_score", "moved_gateways", "pcm_nj",
                 "stall_cycles")}


def replay_standalone(sim: SimConfig, sess: ServeSession) -> dict:
    """Re-run a served session through a standalone `SimSession`,
    bit-exactly: same chunks, same placements, same shared fault frames,
    in served order. Returns the standalone whole-stream summary — the
    acceptance-criterion check that continuous batching is free
    (tests/test_serve.py and bench_serve.py compare against
    `sess.summary()`)."""
    from repro.core.faults import attach_faults

    if not sess.served_log:
        raise ValueError(f"session {sess.id} served nothing to replay")
    ref = SimSession.init(sim)
    if sess.placement_at_admit is not None \
            and tuple(sess.placement_at_admit) != tuple(ref.placement):
        ref.swap_placement(sess.placement_at_admit)
    for entry in sess.served_log:
        if tuple(entry["placement"]) != tuple(ref.placement):
            ref.swap_placement(entry["placement"])
        chunk = entry["chunk"]
        if entry["frame"] is not None:
            chunk = attach_faults(chunk, entry["frame"])
        ref.step_chunk(chunk)
    return ref.summary()
