"""Serving: prefill / decode step factories and a batched request engine.

`make_prefill_fn` / `make_decode_fn` return jit-ready functions; the cache
spec builders in launch/specs.py provide matching shardings so decode lowers
on the production mesh (decode_32k / long_500k cells). `Engine` is the
host-side batching loop used by examples/serve_batch.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def make_prefill_fn(model, max_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_decode_fn(model, temperature: float = 0.0):
    def decode(params, tokens, caches, key):
        logits, caches = model.decode_step(params, tokens, caches)
        if temperature > 0:
            nxt = jax.random.categorical(key, logits / temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        return nxt[:, None].astype(jnp.int32), caches, logits
    return decode


@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray                 # [S] int32
    max_new_tokens: int = 32
    out_tokens: Optional[list] = None


class Engine:
    """Minimal continuous-batching engine: pad-to-batch prefill, then lockstep
    decode; finished sequences are swapped out for queued requests."""

    def __init__(self, model, params, batch_size: int, max_len: int,
                 temperature: float = 0.0):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.prefill_fn = jax.jit(make_prefill_fn(model, max_len))
        self.decode_fn = jax.jit(make_decode_fn(model, temperature))

    def run(self, requests: List[Request], key=None) -> List[List[int]]:
        key = key if key is not None else jax.random.PRNGKey(0)
        outputs: List[List[int]] = []
        for i in range(0, len(requests), self.batch):
            chunk = requests[i:i + self.batch]
            outputs.extend(self._run_batch(chunk, key))
        return outputs

    def _run_batch(self, chunk: List[Request], key) -> List[List[int]]:
        b = self.batch
        plen = max(len(r.prompt) for r in chunk)
        toks = jnp.zeros((b, plen), jnp.int32)
        for j, r in enumerate(chunk):
            toks = toks.at[j, plen - len(r.prompt):].set(r.prompt)
        batch = {"tokens": toks}
        cfg = self.model.cfg
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((b, plen, cfg.d_model),
                                        jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (b, cfg.frontend_embeds, cfg.d_model), jnp.bfloat16)
        caches, logits = self.prefill_fn(self.params, batch)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        steps = max(r.max_new_tokens for r in chunk)
        outs = [[] for _ in chunk]
        for t in range(steps):
            for j in range(len(chunk)):
                outs[j].append(int(nxt[j, 0]))
            key, sub = jax.random.split(key)
            nxt, caches, _ = self.decode_fn(self.params, nxt, caches, sub)
        return [o[:r.max_new_tokens] for o, r in zip(outs, chunk)]
