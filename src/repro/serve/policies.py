"""Serving policy: admission, deadlines, retry, and degradation knobs.

One frozen dataclass (`ServerPolicy`) holds every robustness decision the
continuous-batching `SessionServer` makes, so a deployment is one value and
a test can pin exact behavior. The module also fixes the server's two
public vocabularies:

  * admission signals — what `submit()` tells the client (`ACCEPT` /
    `THROTTLE` / `SHED`): throttle is backpressure ("taken, but slow
    down"), shed is a refusal with a taxonomy reason.
  * the rejection/termination taxonomy — every session ends with exactly
    one reason string from `TERMINAL_REASONS`, and every refused
    submission carries one from `REJECT_REASONS`. Nothing ever just
    raises out of the serve loop (pinned by the property tests in
    tests/test_serve.py).

Priority classes are small ints (higher = more important):
`PRIORITY_BATCH` (0) sheds first, `PRIORITY_PREMIUM` (2) sheds last and
may displace queued lower classes when the queue is full.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# --- admission signals (what submit() returns) -----------------------------
ACCEPT = "accept"
THROTTLE = "throttle"
SHED = "shed"
ADMISSION_SIGNALS = (ACCEPT, THROTTLE, SHED)

# --- priority classes ------------------------------------------------------
PRIORITY_BATCH = 0          # best-effort: first to shed, last to admit
PRIORITY_STANDARD = 1
PRIORITY_PREMIUM = 2        # may displace queued lower-priority sessions
PRIORITY_CLASSES = (PRIORITY_BATCH, PRIORITY_STANDARD, PRIORITY_PREMIUM)

# --- the rejection / termination taxonomy ----------------------------------
COMPLETED = "completed"                  # stream fully served and closed
DEADLINE_EXPIRED = "deadline_expired"    # queued or mid-stream past deadline
RETRY_EXHAUSTED = "retry_exhausted"      # transient failures > retry_limit
IDLE_EVICTED = "idle_evicted"            # open stream starved the lane
SHED_QUEUE_FULL = "shed_queue_full"      # bounded queue at capacity
SHED_MEMORY = "shed_memory"              # queued-interval budget exceeded
SHED_PRIORITY = "shed_priority"          # class refused in degraded mode

# Reasons a *session* (admitted, queued, or refused) can terminate with.
TERMINAL_REASONS = (COMPLETED, DEADLINE_EXPIRED, RETRY_EXHAUSTED,
                    IDLE_EVICTED, SHED_QUEUE_FULL, SHED_MEMORY,
                    SHED_PRIORITY)
# Reasons a *submission* can be refused with (shed signal).
REJECT_REASONS = (SHED_QUEUE_FULL, SHED_MEMORY, SHED_PRIORITY)


@dataclasses.dataclass(frozen=True)
class ServerPolicy:
    """Every robustness knob of the `SessionServer`, in one frozen value.

    Shape (fixed for the life of the server — the one compiled executable
    depends on it):
      lanes            concurrent resident sessions B packed per tick.
      chunk_intervals  T intervals each lane advances per dispatch; every
                       session chunk is padded to this length (`t_mask`
                       freeze semantics make the padding exact).

    Admission (bounded queue + backpressure):
      queue_capacity   max queued sessions; beyond it submissions shed
                       (premium may displace a queued lower class).
      throttle_depth   queue depth at which accepted submissions are told
                       THROTTLE instead of ACCEPT (None = capacity // 2).
      max_queued_intervals  memory budget: total un-served intervals the
                       queue may hold; beyond it submissions shed with
                       SHED_MEMORY (None = unbounded by intervals).

    Deadlines / liveness:
      default_deadline_ticks  deadline for requests that set none, in
                       server ticks from submission (None = no deadline).
      idle_evict_ticks an open (streaming) session that has fed no chunk
                       for this many ticks is evicted from its lane.

    Retry (transient step failures):
      retry_limit          failed attempts per chunk before the session
                           terminates RETRY_EXHAUSTED.
      retry_backoff_ticks  base backoff; attempt k parks the lane for
                           base * 2**(k-1) ticks (exponential).

    Graceful degradation (sustained overload):
      degrade_hi / degrade_lo  queue-fill fractions with hysteresis:
                       `degrade_patience` consecutive ticks at or above
                       hi enters degraded mode, the same count at or
                       below lo exits.
      degrade_coalesce in degraded mode each tick dispatches this many
                       chunks back-to-back for resident sessions (same
                       executable, no admissions in between) — the server
                       drains residents faster instead of collapsing.
      degrade_min_priority  while degraded, submissions below this class
                       shed immediately with SHED_PRIORITY.

    keep_records: retain per-interval record arrays on each session
    (memory grows with served intervals — benchmarks/tests only).
    """
    lanes: int = 8
    chunk_intervals: int = 8
    queue_capacity: int = 16
    throttle_depth: Optional[int] = None
    max_queued_intervals: Optional[int] = None
    default_deadline_ticks: Optional[int] = None
    idle_evict_ticks: int = 4
    retry_limit: int = 3
    retry_backoff_ticks: int = 1
    degrade_hi: float = 0.75
    degrade_lo: float = 0.25
    degrade_patience: int = 2
    degrade_coalesce: int = 2
    degrade_min_priority: int = PRIORITY_STANDARD
    keep_records: bool = False

    def __post_init__(self):
        for name, lo in (("lanes", 1), ("chunk_intervals", 1),
                         ("queue_capacity", 0), ("idle_evict_ticks", 1),
                         ("retry_limit", 0), ("retry_backoff_ticks", 1),
                         ("degrade_patience", 1), ("degrade_coalesce", 1)):
            v = getattr(self, name)
            if v < lo:
                raise ValueError(f"ServerPolicy.{name} must be >= {lo}, "
                                 f"got {v}")
        if self.throttle_depth is not None \
                and not 0 <= self.throttle_depth <= self.queue_capacity:
            raise ValueError(
                f"ServerPolicy.throttle_depth must be in "
                f"[0, queue_capacity={self.queue_capacity}], got "
                f"{self.throttle_depth}")
        if self.max_queued_intervals is not None \
                and self.max_queued_intervals < self.chunk_intervals:
            raise ValueError(
                f"ServerPolicy.max_queued_intervals "
                f"({self.max_queued_intervals}) below one chunk "
                f"({self.chunk_intervals}) would shed every submission")
        if not 0.0 <= self.degrade_lo <= self.degrade_hi <= 1.0:
            raise ValueError(
                f"ServerPolicy degradation band needs "
                f"0 <= degrade_lo <= degrade_hi <= 1, got "
                f"lo={self.degrade_lo}, hi={self.degrade_hi}")
        if self.degrade_min_priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"ServerPolicy.degrade_min_priority must be one of "
                f"{PRIORITY_CLASSES}, got {self.degrade_min_priority}")
        if self.default_deadline_ticks is not None \
                and self.default_deadline_ticks < 1:
            raise ValueError(
                f"ServerPolicy.default_deadline_ticks must be >= 1, got "
                f"{self.default_deadline_ticks}")

    @property
    def effective_throttle_depth(self) -> int:
        return self.queue_capacity // 2 if self.throttle_depth is None \
            else self.throttle_depth
