"""Host-side bookkeeping for the continuous-batching session server.

Three small pieces, all pure Python (nothing here touches the device —
the engine owns the packed dispatch):

  * `SessionRequest` — what a client submits: a whole trace (closed
    session), or nothing yet (an open stream fed incrementally with
    `SessionServer.feed`), plus a priority class and an optional
    deadline.
  * `ServeSession` — one admitted-or-queued session's state machine:
    pending padded chunks, accumulated mask-correct sums, retry/backoff
    state, a served log (chunk + placement + fault frame per successful
    step) that lets `replay_standalone` re-run the session bit-exactly
    through a standalone `SimSession`, and a `summary()` that is
    well-formed at EVERY point of the lifecycle — including terminated
    mid-retry or expired before serving anything (valid-intervals-only
    reductions; zero served intervals means zero means, never a raise).
  * `AdmissionQueue` — the bounded priority queue with the backpressure
    and shedding policy: accept / throttle by depth, shed by capacity or
    queued-interval memory budget, premium displacement of queued lower
    classes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.simulator import session_sums_zero, summary_from_sums
from repro.core.traffic import chunk_trace, validate_trace
from repro.serve.policies import (ACCEPT, PRIORITY_CLASSES,
                                  PRIORITY_STANDARD, SHED, SHED_MEMORY,
                                  SHED_QUEUE_FULL, TERMINAL_REASONS,
                                  THROTTLE, ServerPolicy)

_session_counter = itertools.count()


@dataclasses.dataclass
class SessionRequest:
    """A client submission. `trace` None opens a stream (feed chunks later
    with `SessionServer.feed`, end it with `close`); a full trace closes
    the session at submit. `deadline_ticks` is relative to submission."""
    trace: Optional[dict] = None
    priority: int = PRIORITY_STANDARD
    deadline_ticks: Optional[int] = None
    session_id: Optional[str] = None

    def __post_init__(self):
        if self.priority not in PRIORITY_CLASSES:
            raise ValueError(f"priority must be one of {PRIORITY_CLASSES}, "
                             f"got {self.priority}")
        if self.deadline_ticks is not None and self.deadline_ticks < 1:
            raise ValueError(f"deadline_ticks must be >= 1, got "
                             f"{self.deadline_ticks}")


class ServeSession:
    """One session's host-side state; the engine drives the transitions.

    Lifecycle: queued -> running -> terminal, where terminal is exactly
    one reason from `policies.TERMINAL_REASONS`. `sums` accumulates the
    same mask-correct sufficient statistics a standalone `SimSession`
    carries, starting from the additive identity, so `summary()` is
    always well-formed — mid-retry, expired in the queue, or complete.
    """

    def __init__(self, req: SessionRequest, policy: ServerPolicy,
                 n_chiplets: int, now: int):
        self.id = req.session_id or f"s{next(_session_counter)}"
        self.priority = req.priority
        self.submitted_tick = now
        dl = req.deadline_ticks if req.deadline_ticks is not None \
            else policy.default_deadline_ticks
        self.deadline_tick = None if dl is None else now + dl
        self._chunk_t = policy.chunk_intervals
        self._n_chiplets = n_chiplets
        self.pending: List[dict] = []
        self.closed = False
        if req.trace is not None:
            self.feed(req.trace)
            self.closed = True
        # engine-owned state
        self.lane: Optional[int] = None
        self.status = "queued"
        self.termination_reason: Optional[str] = None
        self.placement_at_admit = None
        self.admitted_tick: Optional[int] = None
        self.terminated_tick: Optional[int] = None
        self.sums: Dict[str, object] = session_sums_zero()
        self.retries = 0
        self.backoff_until = now
        self.last_progress_tick = now
        self.served_log: List[dict] = []
        self.records: List[dict] = []

    # -- input side ---------------------------------------------------------
    def feed(self, trace: dict) -> int:
        """Append a trace's intervals as padded fixed-T chunks; returns the
        number of chunks enqueued."""
        if self.closed:
            raise ValueError(f"session {self.id} is closed to new input")
        validate_trace(trace, who=f"session {self.id} trace")
        if trace.get("dest") is not None \
                and np.ndim(np.asarray(trace["dest"])) != 2:
            # Lanes carry ONE [C, C] matrix each; a stacked [K, C, C]
            # batch is a sweep input, not a session.
            raise ValueError(
                f"session {self.id} trace carries a batched destination "
                f"matrix of shape {np.shape(np.asarray(trace['dest']))} — "
                f"a served session needs a single [C, C] matrix")
        c = int(np.shape(trace["ext_load"])[-1])
        if c != self._n_chiplets:
            raise ValueError(
                f"session {self.id} trace has {c} chiplets, the server "
                f"simulates {self._n_chiplets}")
        n = 0
        for ch in chunk_trace(trace, self._chunk_t, pad=True):
            self.pending.append(ch)
            n += 1
        return n

    @property
    def pending_intervals(self) -> int:
        """Un-served valid intervals still queued on this session."""
        return sum(int(np.sum(np.asarray(ch["t_mask"]) > 0))
                   for ch in self.pending)

    @property
    def served_intervals(self) -> int:
        return int(self.sums["valid_intervals"])

    # -- engine transitions -------------------------------------------------
    @property
    def terminal(self) -> bool:
        return self.termination_reason is not None

    def ready(self, now: int) -> bool:
        """Can this resident session dispatch a chunk this tick?"""
        return (not self.terminal and bool(self.pending)
                and now >= self.backoff_until)

    def advance(self, sums, now: int, placement, frame, records=None,
                keep_records: bool = False) -> None:
        """One chunk served successfully: fold its sums, log the replay
        entry, reset the retry ladder."""
        chunk = self.pending.pop(0)
        self.sums = jax.tree.map(lambda a, b: a + b, self.sums, sums)
        self.served_log.append(
            {"chunk": chunk, "placement": placement, "frame": frame})
        if keep_records and records is not None:
            self.records.append(records)
        self.retries = 0
        self.backoff_until = now
        self.last_progress_tick = now

    def fail(self, now: int, policy: ServerPolicy) -> bool:
        """One transient step failure: back off exponentially; returns True
        while retry budget remains (False = the engine must terminate the
        session with RETRY_EXHAUSTED)."""
        self.retries += 1
        if self.retries > policy.retry_limit:
            return False
        self.backoff_until = now + policy.retry_backoff_ticks \
            * 2 ** (self.retries - 1)
        return True

    def terminate(self, reason: str, now: int) -> None:
        if reason not in TERMINAL_REASONS:
            raise ValueError(f"unknown termination reason {reason!r} "
                             f"(taxonomy: {TERMINAL_REASONS})")
        self.termination_reason = reason
        self.status = reason
        self.terminated_tick = now
        self.lane = None

    # -- output side --------------------------------------------------------
    def summary(self) -> dict:
        """Whole-session summary, well-formed at any lifecycle point.

        Valid-intervals-only reductions over whatever was actually served
        (zero served intervals -> zero means), plus the lifecycle
        metadata a client needs to interpret a partial result.
        """
        out = summary_from_sums(self.sums, self._n_chiplets)
        out = {k: float(v) for k, v in out.items()}
        out.update({
            "session_id": self.id,
            "priority": self.priority,
            "status": self.status,
            "termination_reason": self.termination_reason,
            "served_intervals": self.served_intervals,
            "pending_intervals": self.pending_intervals,
            "served_chunks": len(self.served_log),
            "retries": self.retries,
            "submitted_tick": self.submitted_tick,
            "admitted_tick": self.admitted_tick,
            "terminated_tick": self.terminated_tick,
            "deadline_tick": self.deadline_tick,
        })
        return out


class AdmissionQueue:
    """Bounded priority admission queue with the shedding policy.

    Ordering is (priority desc, arrival order) — premium ahead of
    standard ahead of batch, FIFO within a class. `offer` implements the
    full admission decision except the degraded-mode class gate (the
    engine owns mode state): capacity shed with premium displacement,
    queued-interval memory budget, throttle-by-depth backpressure.
    """

    def __init__(self, policy: ServerPolicy):
        self.policy = policy
        self._items: List[Tuple[int, int, ServeSession]] = []
        self._arrival = itertools.count()

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return (s for _, _, s in self._items)

    @property
    def pending_intervals(self) -> int:
        return sum(s.pending_intervals for s in self)

    def _push(self, sess: ServeSession) -> None:
        self._items.append((-sess.priority, next(self._arrival), sess))
        self._items.sort(key=lambda t: t[:2])

    def _shed_lowest(self, below_priority: int) -> Optional[ServeSession]:
        """Remove the lowest-priority, youngest queued session strictly
        below `below_priority` (displacement victim), or None."""
        for i in range(len(self._items) - 1, -1, -1):
            if self._items[i][2].priority < below_priority:
                return self._items.pop(i)[2]
        return None

    def offer(self, sess: ServeSession) -> Tuple[str, str, List[
            Tuple[ServeSession, str]]]:
        """Admission decision for one submission.

        Returns (signal, reason, displaced): signal in ADMISSION_SIGNALS;
        reason is "" for accept/throttle or a REJECT_REASONS entry for
        shed; displaced lists (queued session pushed out, shed reason)
        pairs — the engine terminates each with its reason.
        """
        p = self.policy
        displaced: List[Tuple[ServeSession, str]] = []

        if len(self._items) >= p.queue_capacity:
            victim = self._shed_lowest(sess.priority)
            if victim is None:
                return SHED, SHED_QUEUE_FULL, []
            displaced.append((victim, SHED_QUEUE_FULL))

        if p.max_queued_intervals is not None:
            need = sess.pending_intervals
            while self.pending_intervals + need > p.max_queued_intervals:
                victim = self._shed_lowest(sess.priority)
                if victim is None:
                    for v, _ in displaced:    # undo the capacity eviction
                        self._push(v)
                    return SHED, SHED_MEMORY, []
                displaced.append((victim, SHED_MEMORY))

        self._push(sess)
        signal = THROTTLE if len(self._items) > p.effective_throttle_depth \
            else ACCEPT
        return signal, "", displaced

    def pop_next(self) -> Optional[ServeSession]:
        """Highest-priority, oldest queued session (None if empty)."""
        return self._items.pop(0)[2] if self._items else None

    def remove_expired(self, now: int) -> List[ServeSession]:
        """Extract every queued session whose deadline has passed."""
        out = [s for _, _, s in self._items
               if s.deadline_tick is not None and now >= s.deadline_tick]
        if out:
            dead = set(id(s) for s in out)
            self._items = [it for it in self._items
                           if id(it[2]) not in dead]
        return out
