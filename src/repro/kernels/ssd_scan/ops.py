"""Jit'd wrapper: full chunked SSD scan with the Pallas intra-chunk kernel
plus the jnp inter-chunk recurrence. Drop-in for models.ssm.ssd_chunked."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                       b_in: jax.Array, c_in: jax.Array, chunk: int,
                       initial_state: Optional[jax.Array] = None,
                       interpret: bool | None = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as models.ssm.ssd_chunked ([B,L,H,P] io)."""
    bsz, l, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    assert l % chunk == 0
    nc = l // chunk
    rep = h // g

    xr = x.reshape(bsz, nc, chunk, h, p)
    dtr = jax.nn.softplus(jnp.zeros(())) * 0 + dt  # keep dtype
    dtr = dt.reshape(bsz, nc, chunk, h)
    br = jnp.repeat(b_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cr = jnp.repeat(c_in.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    y_intra, s_chunk = ssd_intra_chunk_pallas(
        xr, dtr, a, br, cr, interpret=interpret)

    da = dtr.astype(jnp.float32) * a.astype(jnp.float32)
    cum = jnp.cumsum(da, axis=2)
    total_decay = jnp.exp(cum[:, :, -1, :])

    def step(state, inp):
        s_c, dec_c = inp
        out_state = state
        new_state = state * dec_c[..., None, None] + s_c
        return new_state, out_state

    init = (jnp.zeros((bsz, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final_state, states_in = jax.lax.scan(
        step, init, (s_chunk.transpose(1, 0, 2, 3, 4),
                     total_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)

    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         cr.astype(jnp.float32), states_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, l, h, p).astype(x.dtype)
    return y, final_state
