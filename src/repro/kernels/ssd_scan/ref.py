"""Pure-jnp oracle for the SSD intra-chunk kernel (mirrors
repro.models.ssm.ssd_chunked's intra-chunk + summary-state math)."""
from __future__ import annotations

import jax.numpy as jnp


def reference_intra_chunk(x, dt, a, b_in, c_in):
    """Same contract as ssd_intra_chunk_pallas (b_in/c_in head-broadcast)."""
    dtf = dt.astype(jnp.float32)
    da = dtf * a.astype(jnp.float32)                  # [B,NC,Q,H]
    cum = jnp.cumsum(da, axis=2)
    seg = jnp.minimum(cum[:, :, :, None, :] - cum[:, :, None, :, :], 0.0)
    q = x.shape[2]
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", c_in.astype(jnp.float32),
                        b_in.astype(jnp.float32))
    w = scores * decay * dtf[:, :, None, :, :]
    y = jnp.einsum("bcqkh,bckhp->bcqhp", w, x.astype(jnp.float32))
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtf
    s = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", decay_end,
                   x.astype(jnp.float32), b_in.astype(jnp.float32))
    return y, s
