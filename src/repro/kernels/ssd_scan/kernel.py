"""Mamba2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch, chunk, head) grid cell, the quadratic-within-chunk
part of the SSD recurrence plus the chunk summary state:

    y[q] = sum_{j<=q} C_q . B_j  * exp(cum[q]-cum[j]) * dt_j * x_j
    S    = sum_j (exp(cum[-1]-cum[j]) * dt_j) * outer(x_j, B_j)

The [Q, Q] decay matrix lives only in VMEM (Q=chunk_len, default 128-256):
HBM traffic is O(Q (P+N)) per cell instead of the O(Q^2 H) the XLA path
materializes — this kernel is the §Perf fix for the SSD memory-term
bottleneck found in the roofline pass. The inter-chunk state scan stays in
jnp (tiny, sequential).

Grid: (B, NC, H) — heads innermost so B/C blocks (shared per group) stay
VMEM-resident across head iterations of one group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, s_ref, *, chunk: int):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)     # [Q, 1] -> [Q]
    dt = dt[:, 0]
    a = a_ref[0].astype(jnp.float32)                # scalar head decay
    b = b_ref[0, 0, :, 0].astype(jnp.float32)       # [Q, N]
    c = c_ref[0, 0, :, 0].astype(jnp.float32)       # [Q, N]

    da = dt * a                                     # [Q]
    cum = jnp.cumsum(da)                            # [Q]

    # Intra-chunk: scores [Q, Q] = (C B^T) o decay o dt_j, lower-triangular.
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = jnp.minimum(cum[:, None] - cum[None, :], 0.0)  # [Q, Q]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(iq >= ik, scores * jnp.exp(seg) * dt[None, :], 0.0)
    y_ref[0, 0, :, 0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # Chunk summary state: S [P, N] = sum_j w_j * outer(x_j, B_j).
    decay_end = jnp.exp(cum[-1] - cum) * dt         # [Q]
    xw = x * decay_end[:, None]                     # [Q, P]
    s_ref[0, 0, 0] = jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)


def ssd_intra_chunk_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                           b_in: jax.Array, c_in: jax.Array,
                           *, interpret: bool | None = None):
    """Per-chunk SSD compute.

    Args:
      x:    [B, NC, Q, H, P]
      dt:   [B, NC, Q, H]   (already softplus'd)
      a:    [H]             (negative decay rates)
      b_in: [B, NC, Q, H, N] (groups pre-broadcast to heads)
      c_in: [B, NC, Q, H, N]

    Returns:
      y_intra: [B, NC, Q, H, P] f32
      s_chunk: [B, NC, H, P, N] f32
    """
    interpret = resolve_interpret(interpret)
    bsz, nc, q, h, p = x.shape
    n = b_in.shape[-1]

    kernel = functools.partial(_ssd_kernel, chunk=q)
    # layout: head-major blocks; dt gets a trailing singleton for 2D blocks
    dt_e = dt[..., None]

    y, s = pl.pallas_call(
        kernel,
        grid=(bsz, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1, 1),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1,), lambda bi, ci, hi: (hi,)),
            pl.BlockSpec((1, 1, q, 1, n),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1, n),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt_e, a, b_in, c_in)
    return y, s
