"""Jit'd wrapper: padding + layout adaptation for the flash kernel.

`flash_attention` accepts the model's [B, S, H, d] layout, pads S to the
block grid and d to the 128-lane MXU width, runs the Pallas kernel
(interpret=None -> compiled on TPU, interpret mode elsewhere), and unpads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 256, interpret: bool | None = None
                    ) -> jax.Array:
    """q,k,v: [B, S, H, d] (kv repeated to H heads). Returns [B, S, H, d]."""
    b, s, h, d = q.shape
    # layout: [B, H, S, d]
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))

    pad_s = (-s) % block_q
    pad_skv = (-k.shape[1]) % block_kv
    pad_d = (-d) % 128
    if pad_s or pad_d:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
    if pad_skv or pad_d:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_skv), (0, pad_d)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_skv), (0, pad_d)))
    # padded kv rows would attend as zeros => exp(0 - m); mask them by
    # relying on causal masking (pad rows are beyond every real q position)
    # for causal=True; for bidirectional, bias pad keys to -inf via a k of
    # NEG_INF-inducing zero query dot — handled by masking in kernel through
    # positions, so for causal=False we require pad_skv == 0.
    if not causal:
        assert pad_skv == 0, "bidirectional path requires S % block_kv == 0"

    out = flash_attention_pallas(qt, kt, vt, causal=causal,
                                 block_q=block_q, block_kv=block_kv,
                                 scale=1.0 / (d ** 0.5),
                                 interpret=interpret)
    out = out[:, :, :s, :d]
    return out.transpose(0, 2, 1, 3)
