"""FlashAttention Pallas TPU kernel.

Schedule: grid (batch, heads, q_blocks, kv_blocks) — TPU grids execute
sequentially with the last dimension innermost, so the running-softmax
state (m, l, acc) lives in VMEM scratch that persists across the kv_block
iterations of one q_block. Block shapes are BlockSpec-tiled into VMEM;
matmul dims are kept multiples of the 128-wide MXU tile by construction
(block_q/block_kv default 128/256, head_dim padded by the wrapper).

Causal masking compares absolute positions derived from program_ids, and
whole kv-blocks strictly above the diagonal are skipped via @pl.when.

Validated in interpret mode against ref.reference_attention (also the jnp
path used by the models at trace time — kernels/ops.py `flash_attention`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scratch, l_scratch, acc_scratch,
                  *, scale: float, causal: bool,
                  block_q: int, block_kv: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bkv, d]
        v = v_ref[0, 0].astype(jnp.float32)            # [bkv, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # [bq, bkv]
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            k_pos = kj * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scratch[...]                         # [bq, 1]
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)                 # [bq, 1]
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scratch[...] = (acc_scratch[...] * alpha
                            + jax.lax.dot_general(
                                p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    if causal:
        # Skip kv blocks entirely above the causal diagonal.
        @pl.when(kj * block_kv <= qi * block_q + block_q - 1)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        o_ref[0, 0] = (acc_scratch[...]
                       / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True,
                           block_q: int = 128, block_kv: int = 256,
                           scale: float | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """q,k,v: [B, H, S, d] (kv already repeated to H). Returns [B, H, S, d].

    S must divide by the block sizes (the ops.py wrapper pads). `scale`
    defaults to 1/sqrt(d) of the *given* d — the wrapper passes the
    pre-padding head dim.
    """
    interpret = resolve_interpret(interpret)
    b, h, s_q, d = q.shape
    s_kv = k.shape[2]
    assert s_q % block_q == 0 and s_kv % block_kv == 0, (s_q, s_kv)
    nq, nkv = s_q // block_q, s_kv // block_kv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, n_kv_blocks=nkv)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, kj: (bi, hi, kj, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, qi, kj: (bi, hi, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
