"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True) -> jax.Array:
    """Naive softmax attention. q,k,v: [B, H, S, d] -> [B, H, S, d]."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        s_q, s_kv = s.shape[-2:]
        mask = (jnp.arange(s_q)[:, None] >= jnp.arange(s_kv)[None, :])
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
