"""Pure-jnp oracle for the NoC flit kernel (lax.scan over cycles)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_noc_run(arrivals: jax.Array, next_mat: jax.Array,
                      drain_rate: jax.Array, buf_cap: jax.Array,
                      *, valid_mask: jax.Array | None = None,
                      valid_mask_t: jax.Array | None = None,
                      t_mask: jax.Array | None = None,
                      link_rate: float = 1.0):
    """Same contract as noc_run_pallas (dead-lane valid_mask + frozen-cycle
    t_mask + time-varying valid_mask_t [T, R] for mid-run lane faults: a
    masked cycle leaves occupancy/residency/drain untouched; a lane whose
    validity row drops to 0 is dead for exactly those cycles)."""
    t, r = arrivals.shape
    nmat = next_mat.astype(jnp.float32)
    is_router = jnp.sign(jnp.sum(nmat, axis=1))
    drain = drain_rate.astype(jnp.float32)
    buf = buf_cap.astype(jnp.float32)
    mask = jnp.ones((r,), jnp.float32) if valid_mask is None \
        else valid_mask.astype(jnp.float32)
    maskt = jnp.broadcast_to(mask[None, :], (t, r)) if valid_mask_t is None \
        else valid_mask_t.astype(jnp.float32) * mask[None, :]
    tmask = jnp.ones((t,), jnp.float32) if t_mask is None \
        else t_mask.astype(jnp.float32)

    def cycle(carry, x):
        occ0, resid, drained = carry
        arr, tm, mask = x
        occ = (occ0 + arr.astype(jnp.float32)) * mask
        send = jnp.minimum(occ, link_rate) * is_router
        inflow_want = send @ nmat
        space = jnp.maximum(buf - occ, 0.0)
        scale_dst = jnp.where(inflow_want > 0.0,
                              jnp.minimum(1.0, space / jnp.maximum(
                                  inflow_want, 1e-9)), 0.0)
        scale_src = nmat @ scale_dst
        moved = send * scale_src
        inflow = moved @ nmat
        # Flits routed into a dead lane vanish at the broken link (kernel
        # twin does the same); x 1.0 exactly on clean paths.
        occ = occ - moved + inflow * mask
        sunk = jnp.minimum(occ, drain)
        occ = occ - sunk
        return (tm * occ + (1.0 - tm) * occ0,
                resid + tm * occ, drained + tm * sunk), None

    zeros = jnp.zeros((r,), jnp.float32)
    (occ, resid, drained), _ = jax.lax.scan(
        cycle, (zeros, zeros, zeros), (arrivals, tmask, maskt))
    return resid, occ, drained
