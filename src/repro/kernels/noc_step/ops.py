"""Wrapper: build routing topology from selection tables + run the flit sim.

`simulate_residency(app_load, g_active, wavelengths)` produces the Fig. 13
per-router residency map for one chiplet under a given gateway activation —
used by benchmarks/fig13_residency.py for both ReSiPI (g=2..4, W=4) and
PROWAVES (g=1, W=16, port-limited drain).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.constants import NETWORK, NetworkConfig
from repro.core.selection import (build_selection_tables,
                                  resolve_gateway_positions, _router_coords)
from repro.kernels.noc_step.kernel import noc_run_pallas

# Deterministic next-hop preference order for explicit-coords layouts: the
# four grid steps first (x before y, matching XY routing's dimension order),
# then the two hex anti-diagonal steps. On a derived mesh the hop-greedy
# walk under this order reproduces XY routing exactly (x-distance strictly
# drops while it can, then y) — pinned in tests/test_topology.py.
_NEXT_HOP_PREFERENCE = ((1, 0), (-1, 0), (0, 1), (0, -1), (1, -1), (-1, 1))


def build_topology(g_active: int, wavelengths: int,
                   cfg: NetworkConfig = NETWORK
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(next_mat [R+g, R+g], drain [R+g], buf [R+g], gw_router_idx [g]).

    Mesh routers 0..R-1 route flits via XY toward their assigned gateway
    (Fig. 8 balanced partition); a gateway sink node is appended per active
    gateway. Sink drain = min(optical serialization, electronic port) rate.
    Placement-aware: `cfg.gateway_positions` (or the default edge scheme)
    decides both the balanced partition and where the sinks sit.
    Explicit-coords layouts route hop-greedily over the coord_model
    adjacency (first `_NEXT_HOP_PREFERENCE` neighbor that strictly reduces
    the BFS hop distance — deterministic, loop-free, XY-equivalent on
    meshes).
    """
    tables = build_selection_tables(cfg)
    assign = tables.src_map[g_active - 1]            # [R] -> gateway id
    routers = _router_coords(cfg)
    gw_pos = resolve_gateway_positions(cfg)[:g_active]
    r = len(routers)
    n = r + g_active
    next_mat = np.zeros((n, n), np.float32)

    def rid(x, y):
        return x * cfg.mesh_y + y

    if cfg.coords is None:
        for i, (x, y) in enumerate(routers):
            gx, gy = gw_pos[assign[i]]
            if x == gx and y == gy:
                next_mat[i, r + assign[i]] = 1.0     # eject into gateway
            elif x != gx:                             # XY: x first
                next_mat[i, rid(x + np.sign(gx - x), y)] = 1.0
            else:
                next_mat[i, rid(x, y + np.sign(gy - y))] = 1.0
    else:
        idx_lut = topology.router_index_lut(cfg)
        hm = topology.hop_matrix(cfg)
        xmax, ymax = idx_lut.shape
        gw_rid = idx_lut[gw_pos[:, 0], gw_pos[:, 1]]
        offsets = [o for o in _NEXT_HOP_PREFERENCE
                   if o in topology.NEIGHBOR_OFFSETS[cfg.coord_model]]
        for i, (x, y) in enumerate(routers):
            tgt = int(gw_rid[assign[i]])
            if i == tgt:
                next_mat[i, r + assign[i]] = 1.0     # eject into gateway
                continue
            for dx, dy in offsets:
                nx, ny = x + dx, y + dy
                if not (0 <= nx < xmax and 0 <= ny < ymax):
                    continue
                j = int(idx_lut[nx, ny])
                if j >= 0 and hm[j, tgt] < hm[i, tgt]:
                    next_mat[i, j] = 1.0
                    break
            else:                 # pragma: no cover - hop_matrix is exact
                raise AssertionError("no hop-reducing neighbor found")

    # Gateway sink service: optical lanes vs the 1-flit/cycle electronic
    # port — the min is what the chiplet actually sustains (§3.1 insight).
    optical = wavelengths * cfg.link_gbps_per_wavelength / (
        cfg.flit_bits * cfg.noc_freq_ghz)
    drain = np.zeros((n,), np.float32)
    drain[r:] = min(optical, 1.0)
    buf = np.full((n,), float(cfg.router_buffer_flits), np.float32)
    buf[r:] = float(cfg.gateway_buffer_flits)
    if cfg.coords is None:
        gw_idx = np.array([rid(*gw_pos[k]) for k in range(g_active)])
    else:
        gw_idx = np.array([int(topology.router_index_lut(cfg)[x, y])
                           for x, y in gw_pos[:g_active]])
    return next_mat, drain, buf, gw_idx


def build_topology_padded(g_active: int, wavelengths: int,
                          cfg: NetworkConfig = NETWORK, *, pad_to: int
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
    """`build_topology` padded to `pad_to` nodes with a lane-validity mask.

    Padded node lanes get zero routing rows/columns, zero drain/buffers and
    a zero validity mask — with `noc_run_pallas(..., valid_mask=mask)` they
    are dead lanes, so one kernel shape serves every (mesh, g) topology in
    a batch. Returns (next_mat [P, P], drain [P], buf [P], valid_mask [P]).
    """
    next_mat, drain, buf, _ = build_topology(g_active, wavelengths, cfg)
    n = next_mat.shape[0]
    if pad_to < n:
        raise ValueError(f"pad_to {pad_to} < topology nodes {n}")
    p = pad_to - n
    next_mat = np.pad(next_mat, ((0, p), (0, p)))
    drain = np.pad(drain, (0, p))
    buf = np.pad(buf, (0, p))
    mask = np.zeros((pad_to,), np.float32)
    mask[:n] = 1.0
    return next_mat, drain, buf, mask


def simulate_residency(ext_load: float, g_active: int, wavelengths: int,
                       cycles: int = 4096, seed: int = 0,
                       cfg: NetworkConfig = NETWORK,
                       active_cycles: int | None = None,
                       interpret: bool | None = None):
    """Returns (mean residency per router [4,4], drained flits).

    ext_load: chiplet-level inter-chiplet packet rate (pkts/cycle); packets
    arrive as `packet_flits`-sized bursts Poisson-thinned over routers.
    active_cycles: run only the first `active_cycles` of the window (the
    rest are t_mask-frozen) — lets mixed-duration runs share one kernel
    shape, mirroring the epoch engine's ragged-T batching. `cycles` no
    longer needs to be a multiple of the kernel time-chunk; the wrapper
    pads the tail with masked cycles.
    """
    r = cfg.routers_per_chiplet
    next_mat, drain, buf, _ = build_topology(g_active, wavelengths, cfg)
    n = next_mat.shape[0]
    key = jax.random.PRNGKey(seed)
    per_router = ext_load / r
    arr = (jax.random.uniform(key, (cycles, r)) <
           per_router).astype(jnp.float32) * cfg.packet_flits
    arrivals = jnp.concatenate(
        [arr, jnp.zeros((cycles, n - r), jnp.float32)], axis=1)
    if active_cycles is None:
        active_cycles = cycles
    if not 0 < active_cycles <= cycles:
        raise ValueError(f"active_cycles must be in (0, {cycles}], "
                         f"got {active_cycles}")
    t_mask = (jnp.arange(cycles) < active_cycles).astype(jnp.float32)
    resid, occ, drained = noc_run_pallas(
        arrivals, jnp.asarray(next_mat), jnp.asarray(drain),
        jnp.asarray(buf), valid_mask=jnp.ones((n,), jnp.float32),
        t_mask=t_mask, interpret=interpret)
    mean_resid = resid[:r] / active_cycles
    if cfg.coords is not None:
        # Explicit layouts have no dense grid to reshape into: return the
        # flat [R] residency in router order (topology.router_coords rows).
        return np.asarray(mean_resid), float(jnp.sum(drained))
    return (np.asarray(mean_resid).reshape(cfg.mesh_x, cfg.mesh_y),
            float(jnp.sum(drained)))
