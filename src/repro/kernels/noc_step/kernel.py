"""Flit-level NoC router simulation Pallas kernel (Fig. 13 residency maps).

Fluid-flow flit model of one chiplet's mesh: per cycle, every router
forwards up to `link_rate` flits toward its gateway along a static next-hop
map (XY routing, selection tables from repro.core.selection), subject to
destination buffer space (backpressure, proportional sharing on contention);
gateway sinks drain at their optical-port service rate. The per-cycle update
is matmul-structured (one-hot next-hop matrix) so the inner loop runs on the
MXU; occupancy state lives in VMEM scratch across a whole time-chunk, and
the residency integral (sum of occupancy over cycles — the Fig. 13 metric)
accumulates across grid steps.

Grid: (T // t_chunk,). Inputs: arrivals [T, R] blocked per chunk. The
occupancy/residency state persists in scratch across sequential grid steps.

Validated in interpret mode against ref.reference_noc_run (lax.scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

LANES = 128   # TPU lane width: the router axis pads to this for compilation


def _noc_kernel(arrivals_ref, tmask_ref, next_mat_ref, drain_ref, buf_ref,
                mask_ref, resid_ref, occ_final_ref, drained_ref,
                occ_scratch, resid_scratch, drained_scratch,
                *, t_chunk: int, link_rate: float, n_steps: int,
                tv_mask: bool = False):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        occ_scratch[...] = jnp.zeros_like(occ_scratch)
        resid_scratch[...] = jnp.zeros_like(resid_scratch)
        drained_scratch[...] = jnp.zeros_like(drained_scratch)

    nmat = next_mat_ref[...].astype(jnp.float32)      # [R, R] one-hot
    drain = drain_ref[...].astype(jnp.float32)        # [1, R] sink rates
    buf = buf_ref[...].astype(jnp.float32)            # [1, R] capacities
    # Static path: [1, R] lane validity, read once. Time-varying path
    # (tv_mask, the fault-injection contract): the ref holds this chunk's
    # [t_chunk, R] rows and each cycle reads its own row.
    mask_static = None if tv_mask else mask_ref[...].astype(jnp.float32)

    def cycle(t, carry):
        occ0, resid, drained = carry
        arr = arrivals_ref[t, :][None, :].astype(jnp.float32)   # [1, R]
        # Per-cycle time-validity scalar (SMEM): a masked cycle freezes
        # the whole network state, so time-padded batches match their
        # unpadded originals exactly.
        tm = tmask_ref[0, t].astype(jnp.float32)
        # Dead-lane enforcement: invalid (padded or faulted-this-cycle)
        # lanes can never hold or emit flits, whatever the caller put in
        # their arrival/buffer slots.
        mask = mask_ref[t, :][None, :].astype(jnp.float32) if tv_mask \
            else mask_static
        occ = (occ0 + arr) * mask
        send = jnp.minimum(occ, link_rate) * jnp.sign(
            jnp.sum(nmat, axis=1))[None, :]                     # routers only
        # desired inflow at each destination: send @ nmat  ([1,R]@[R,R])
        inflow_want = jax.lax.dot_general(
            send, nmat, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                 # [1, R]
        space = jnp.maximum(buf - occ, 0.0)
        scale_dst = jnp.where(inflow_want > 0.0,
                              jnp.minimum(1.0, space / jnp.maximum(
                                  inflow_want, 1e-9)), 0.0)     # [1, R]
        # per-source allowed send = send * scale[next(source)]
        scale_src = jax.lax.dot_general(
            scale_dst, nmat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                 # [1, R]
        moved = send * scale_src
        inflow = jax.lax.dot_general(
            moved, nmat, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # Flits routed INTO a dead lane are lost at the broken link (the
        # sender already moved them out); on clean paths nothing routes
        # into a padded lane, so this multiply is exactly x 1.0 there.
        occ = occ - moved + inflow * mask
        sunk = jnp.minimum(occ, drain)
        occ = occ - sunk
        return (tm * occ + (1.0 - tm) * occ0,
                resid + tm * occ, drained + tm * sunk)

    occ, resid, drained = jax.lax.fori_loop(
        0, t_chunk, cycle,
        (occ_scratch[...], resid_scratch[...], drained_scratch[...]))
    occ_scratch[...] = occ
    resid_scratch[...] = resid
    drained_scratch[...] = drained

    @pl.when(step == n_steps - 1)
    def _emit():
        resid_ref[...] = resid_scratch[...]
        occ_final_ref[...] = occ_scratch[...]
        drained_ref[...] = drained_scratch[...]


def noc_run_pallas(arrivals: jax.Array, next_mat: jax.Array,
                   drain_rate: jax.Array, buf_cap: jax.Array,
                   *, valid_mask: jax.Array | None = None,
                   valid_mask_t: jax.Array | None = None,
                   t_mask: jax.Array | None = None,
                   t_chunk: int = 256, link_rate: float = 1.0,
                   interpret: bool | None = None,
                   pad_lanes: bool | None = None):
    """Run T cycles of the flit model.

    Args:
      arrivals: [T, R] flits injected per cycle per node.
      next_mat: [R, R] one-hot routing matrix (rows: source; sinks all-zero).
      drain_rate: [R] flits/cycle sunk at gateway nodes (0 elsewhere).
      buf_cap: [R] buffer capacity in flits.
      valid_mask: [R] 1/0 lane-validity mask (None = all valid). Invalid
        lanes are DEAD: occupancy is forced to zero every cycle, so they
        never send, receive, or accumulate residency even when a padded
        batch layout leaves garbage in their arrival/buffer slots. This is
        the topology-batching contract — padded router lanes are dead
        lanes, not zero-traffic routers.
      valid_mask_t: [T, R] 1/0 TIME-VARYING lane-validity mask (None =
        static lanes only). Row t ANDs with `valid_mask` for cycle t: a
        lane whose row goes to 0 mid-run (a fault firing) drops its flits
        and is dead — zero send/hold/residency — for exactly those cycles,
        then revives empty. An all-ones mask takes the same code path but
        multiplies by 1.0, so "fault masked at t == T" matches the static
        fault-free run bit-for-bit (the fault-parity smoke contract).
      t_mask: [T] 1/0 cycle-validity mask (None = all valid). Masked
        cycles FREEZE the network: no arrivals, no movement, no drain, no
        residency accumulation — so mixed-length cycle batches can pad the
        time axis and still match their unpadded originals exactly (the
        ragged-T contract of the epoch engine, at flit granularity). When
        T is not a multiple of `t_chunk`, the wrapper pads the tail with
        masked cycles automatically.
      interpret: None = backend-aware (compiled on TPU), or explicit bool.
      pad_lanes: pad the router axis up to the 128-lane boundary. Defaults
        to on whenever the kernel compiles (Mosaic requires lane-aligned
        blocks); lane-pad nodes extend the validity mask with zeros.

    Returns (residency_integral [R], final_occupancy [R], drained [R]).
    """
    interpret = resolve_interpret(interpret)
    if pad_lanes is None:
        pad_lanes = not interpret
    t, r_in = arrivals.shape
    if valid_mask is None:
        valid_mask = jnp.ones((r_in,), jnp.float32)
    valid_mask = valid_mask.astype(jnp.float32)
    if t_mask is None:
        t_mask = jnp.ones((t,), jnp.float32)
    t_mask = t_mask.astype(jnp.float32)
    tv = valid_mask_t is not None
    if tv:
        if valid_mask_t.shape != (t, r_in):
            raise ValueError(
                f"valid_mask_t must be [T, R] = {(t, r_in)}, got "
                f"{valid_mask_t.shape}")
        # The static lane mask ANDs in here; the kernel sees ONE combined
        # per-cycle mask plane.
        mask_in = valid_mask_t.astype(jnp.float32) * valid_mask[None, :]
    t_pad = (-t) % t_chunk
    if t_pad:       # tail cycles arrive masked-out: frozen, zero residency
        arrivals = jnp.pad(arrivals, ((0, t_pad), (0, 0)))
        t_mask = jnp.pad(t_mask, (0, t_pad))
        if tv:
            mask_in = jnp.pad(mask_in, ((0, t_pad), (0, 0)))
        t += t_pad
    pad = (-r_in) % LANES if pad_lanes else 0
    if pad:
        arrivals = jnp.pad(arrivals, ((0, 0), (0, pad)))
        next_mat = jnp.pad(next_mat, ((0, pad), (0, pad)))
        drain_rate = jnp.pad(drain_rate, (0, pad))
        buf_cap = jnp.pad(buf_cap, (0, pad))
        valid_mask = jnp.pad(valid_mask, (0, pad))
        if tv:
            mask_in = jnp.pad(mask_in, ((0, 0), (0, pad)))
    r = r_in + pad
    n_steps = t // t_chunk
    if not tv:
        mask_in = valid_mask[None, :]
    kernel = functools.partial(_noc_kernel, t_chunk=t_chunk,
                               link_rate=link_rate, n_steps=n_steps,
                               tv_mask=tv)
    mask_spec = pl.BlockSpec((t_chunk, r), lambda i: (i, 0)) if tv \
        else pl.BlockSpec((1, r), lambda i: (0, 0))
    resid, occ, drained = pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((t_chunk, r), lambda i: (i, 0)),
            # per-cycle validity scalars ride in SMEM, one t_chunk row per
            # grid step — R times smaller than materializing a [T, R] mask
            pl.BlockSpec((1, t_chunk), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            mask_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((1, r), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((1, r), jnp.float32)] * 3,
        interpret=interpret,
    )(arrivals, t_mask.reshape(n_steps, t_chunk), next_mat,
      drain_rate[None, :], buf_cap[None, :], mask_in)
    return resid[0, :r_in], occ[0, :r_in], drained[0, :r_in]
