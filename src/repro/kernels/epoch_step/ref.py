"""Reference implementation: the XLA lax.scan interval loop, verbatim.

The oracle the fused kernel is validated against (1e-6 in interpret mode):
exactly `simulator.make_step` scanned over the trace, i.e. what every entry
point runs when `SimConfig.epoch_kernel` is off. Kept as a thin named
function so parity tests and benchmarks compare the two engines through one
symmetric interface.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def epoch_run_reference(state, xs, sim, tables: dict, *,
                        dest: Optional[jax.Array] = None,
                        faulted: bool = False) -> Tuple[object, dict]:
    """lax.scan over make_step — the unfused engine, same call contract."""
    from repro.core.simulator import make_step

    step = make_step(sim, tables, None, faulted=faulted, dest=dest)
    return jax.lax.scan(step, state, xs)
