"""Fused epoch-scan Pallas kernel: the whole RESIPI interval loop.

One kernel launch runs T reconfiguration intervals of the Level-1 simulator
(simulator.make_step for Arch.RESIPI / RESIPI_ALL, unpadded topology): the
per-interval queueing metrics (noc.NocModel), the PCM power model
(photonics.interposer_power_mw, "pcm" mode), the Eq. 5-7 gateway controller
and the Eq. 4 kappa-switch reconfiguration energy all execute inside one
`pl.pallas_call`, with the per-chiplet gateway count as the only carried
state (VMEM scratch across grid steps). The XLA `lax.scan` body stays the
parity oracle (ref.py, 1e-6 in interpret mode).

Grid: (T // t_chunk,). Per-chiplet arrays ride in VMEM lane-padded to 128
(compiled mode); per-interval scalars (mem load, t_mask, loss drift) ride in
SMEM rows like noc_step's cycle masks. Runtime sweepable knobs (l_m,
max/min_gateways, buffer_sat, wavelengths) arrive as a small SMEM params
vector because `sweep` may trace them.

Padded-lane contract: a lane-padded chiplet enters with g=1 and zero load —
the controller can never raise it (load 0 <= l_m) nor lower it (t_n(1) = 0),
so it stays at g=1 forever, and every mean / chain-sum / switch-count masks
it out via the lane-validity vector. Time-padded intervals freeze the g
carry and record zeros, exactly like the scan body's t_valid freeze.

The kappa chain (photonics.kappa_schedule) is evaluated in closed form: the
chain is chiplet-major, so a slot's upstream-active count is a strictly-
lower-triangular matmul over per-chiplet totals plus a static within-row
prefix; memory-gateway kappas are constant (1/(M-i)) and never switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128   # TPU lane width: the chiplet axis pads to this for compilation

# out_scal column layout (wrapper slices by these indices)
COL_LATENCY = 0
COL_POWER = 1
COL_LASER = 2
COL_RECONFIG = 3
COL_MEAN_INTER = 4
COL_SATURATED = 5
COL_FAILED = 6
N_COLS = 8


def _epoch_kernel(*refs, t_chunk: int, n_steps: int, n_chiplets: int,
                  g_slots: int, mem_gws: int, use_dest: bool, faulted: bool,
                  use_controller: bool, s_cols: int, n_lanes: int,
                  interval: float, burstiness: float, rpc: float,
                  flight: float, feed_links: float, flits: float,
                  packet_bits: float, ser_k: float, mesh_hops: float,
                  mesh_feed: float, laser_mw: float, tia_mw: float,
                  tuning_mw: float, driver_mw: float, controller_mw: float,
                  reconfig_nj: float):
    it = iter(refs)
    ext_ref = next(it)
    intra_ref = next(it)
    mem_ref = next(it)
    tmask_ref = next(it)
    drift_ref = next(it)
    params_ref = next(it)
    srch_ref = next(it)
    gwdb_ref = next(it)
    g0_ref = next(it)
    lmask_ref = next(it)
    dest_ref = next(it) if use_dest else None
    gwok_ref = next(it) if faulted else None
    stuck_ref = next(it) if faulted else None
    scal_ref = next(it)
    g_out_ref = next(it)
    gdes_ref = next(it)
    gwl_ref = next(it)
    gfin_ref = next(it)
    g_scr = next(it)

    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_scr[...] = g0_ref[...].astype(jnp.float32)

    # Runtime (possibly swept) scalars from SMEM.
    lm = params_ref[0, 0]
    maxg = params_ref[0, 1]
    ming = params_ref[0, 2]
    bsat = params_ref[0, 3]
    lam = params_ref[0, 4]

    lmask = lmask_ref[...].astype(jnp.float32)            # [1, P] real lanes
    c_f = float(n_chiplets)
    m_f = float(mem_gws)
    flits_f = jnp.float32(flits)
    dmat = dest_ref[...].astype(jnp.float32) if use_dest else None

    # Strictly-lower-triangular chain-prefix matrix: prefix = tot @ LT sums
    # the per-chiplet active totals of every chiplet EARLIER in the chain.
    rows = jax.lax.broadcasted_iota(jnp.float32, (n_lanes, n_lanes), 0)
    cols = jax.lax.broadcasted_iota(jnp.float32, (n_lanes, n_lanes), 1)
    lt_mat = (rows < cols).astype(jnp.float32)

    # --- queueing closures (op-for-op noc.NocModel) ------------------------
    def md1(rho, service):
        rho_eff = jnp.clip(rho / bsat, 0.0, 0.995)
        return burstiness * rho_eff * service / (2.0 * (1.0 - rho_eff))

    ser = packet_bits / (lam * ser_k)        # serialization_cycles(lam)
    s_eff_gw = jnp.maximum(ser, flits_f)     # port_cycles == packet_flits

    def gateway_lat(load):
        rho = jnp.clip(load * s_eff_gw, 0.0, 1.0)
        return s_eff_gw + md1(rho, s_eff_gw) + flight

    def access_lat(hops, load, burst_scale=None):
        walk = hops * rpc
        rho_link = jnp.clip(load * flits / feed_links, 0.0, 1.0)
        wait = md1(rho_link, flits_f)
        if burst_scale is not None:
            wait = wait * burst_scale
        return walk + wait

    def kappa_of(lit):
        """Per-slot Eq. 4 kappas for a [G]-list of [1, P] lit masks.

        Chain order is chiplet-major (slot index minor), memory gateways
        last; their kappas are the constant 1/(M-i) and never switch, so
        only the C*G chiplet slots are returned.
        """
        lit_m = [l * lmask for l in lit]
        tot = lit_m[0]
        for l in lit_m[1:]:
            tot = tot + l
        gt = jnp.sum(tot) + m_f
        prefix = jax.lax.dot_general(
            tot, lt_mat, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # [1, P]
        run = jnp.zeros_like(tot)
        ks = []
        for s in range(g_slots):
            upstream = prefix + run
            denom = jnp.maximum(gt - upstream, 1.0)
            ks.append(jnp.where(lit_m[s] > 0.0, 1.0 / denom, 0.0))
            run = run + lit_m[s]
        return ks

    def interval_body(t, g):
        ext = ext_ref[t, :][None, :].astype(jnp.float32)        # [1, P]
        intra = intra_ref[t, :][None, :].astype(jnp.float32)    # [1, P]
        mem = mem_ref[0, t].astype(jnp.float32)
        tm = tmask_ref[0, t].astype(jnp.float32)
        drift = drift_ref[0, t].astype(jnp.float32)

        # Desired / usable / lit slot masks per static slot index.
        des = [(jnp.float32(s) < g).astype(jnp.float32)
               for s in range(g_slots)]
        if faulted:
            ok = [pl.load(gwok_ref, (pl.dslice(s, 1), pl.dslice(t, 1),
                                     slice(None)))
                  .reshape(1, n_lanes).astype(jnp.float32)
                  for s in range(g_slots)]
            st = [pl.load(stuck_ref, (pl.dslice(s, 1), pl.dslice(t, 1),
                                      slice(None)))
                  .reshape(1, n_lanes).astype(jnp.float32)
                  for s in range(g_slots)]
            usable = [d * o for d, o in zip(des, ok)]
            lit = [jnp.maximum(u, s_ * o)
                   for u, s_, o in zip(usable, st, ok)]
            g_eff = usable[0]
            for u in usable[1:]:
                g_eff = g_eff + u
        else:
            usable = des
            lit = des
            g_eff = g

        # --- _interval_metrics -----------------------------------------
        g_eff_f = jnp.maximum(g_eff, 1.0)
        gw_load = ext / g_eff_f
        mem_gw = mem / m_f

        lev = jnp.maximum(g_eff, 1.0) - 1.0      # activation level index
        src = jnp.zeros_like(g)
        gdb = jnp.zeros_like(g)
        for s in range(g_slots):
            sel = (lev == jnp.float32(s)).astype(jnp.float32)
            src = src + srch_ref[0, s] * sel
            gdb = gdb + gwdb_ref[0, s] * sel
        mean_src = jnp.sum(src * lmask) / c_f
        access_db = jnp.sum(gdb * lmask) / c_f + drift

        if use_dest:
            # recv_j = sum_i ext_i * dest_ij and the fan-in concentration
            # phi_j = sum_i (ext_i * dest_ij)^2 / recv_j^2, both as row-vec
            # matmuls over the destination matrix (no [P, P] materialization
            # or transposes; the squared weight factors elementwise).
            recv = jax.lax.dot_general(
                ext, dmat, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)           # [1, P]
            phi = (jax.lax.dot_general(
                ext * ext, dmat * dmat, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
                   / jnp.maximum(recv * recv, 1e-12))
            burst_scale = (1.0 + (burstiness - 1.0) * phi) / burstiness
            dst_gw = recv / g_eff_f
            dst_leg = access_lat(src, dst_gw, burst_scale)    # [1, P]
            inter = (access_lat(src, gw_load) + gateway_lat(gw_load)
                     + jax.lax.dot_general(
                         dst_leg, dmat, (((1,), (1,)), ((), ())),
                         preferred_element_type=jnp.float32))
        else:
            recv = None
            inter = (access_lat(src, gw_load) + gateway_lat(gw_load)
                     + access_lat(mean_src * jnp.ones_like(src), gw_load))
        mem_lat = (access_lat(mean_src, mem_gw) + gateway_lat(mem_gw)
                   + access_lat(1.0, mem_gw))
        link_load = intra * flits / mesh_feed
        intra_lat = (mesh_hops * rpc + flits
                     + md1(jnp.clip(link_load, 0.0, 1.0), flits_f))

        tot_ext = jnp.sum(ext) + 1e-9
        tot_int = jnp.sum(intra) + 1e-9
        tot_mem = mem + 1e-9
        lat = (jnp.sum(inter * ext) + jnp.sum(intra_lat * intra)
               + mem_lat * tot_mem) / (tot_ext + tot_int + tot_mem)
        minter = jnp.sum(inter * ext) / tot_ext
        sat = jnp.max((gw_load * s_eff_gw > bsat).astype(jnp.float32))

        # --- power (pcm mode) ------------------------------------------
        n_lit = jnp.float32(0.0)
        for l in lit:
            n_lit = n_lit + jnp.sum(l * lmask)
        lit_w = (n_lit + m_f) * lam
        laser = lit_w * laser_mw * (10.0 ** (access_db / 10.0))
        tia = lit_w * tia_mw
        tuning = (lit_w + lit_w) * tuning_mw
        driver = lit_w * driver_mw
        total = laser + tia + tuning + driver + controller_mw

        # --- controller + reconfiguration energy -----------------------
        if use_controller:
            if use_dest:
                pressure = jnp.maximum(ext, recv)
            else:
                pressure = ext
            packets = pressure * interval
            if faulted:
                packets = packets * (g / g_eff_f)
            g1 = jnp.maximum(g, 1.0)
            load = packets / (interval * g1)
            inc = (load > lm) & (g < maxg)
            dec = (load < lm * (1.0 - 1.0 / g1)) & (g > ming)
            g_new = jnp.where(inc, g + 1.0, jnp.where(dec, g - 1.0, g))

            des_new = [(jnp.float32(s) < g_new).astype(jnp.float32)
                       for s in range(g_slots)]
            if faulted:
                lit_new = [jnp.maximum(d * o, s_ * o)
                           for d, s_, o in zip(des_new, st, ok)]
            else:
                lit_new = des_new
            k_old = kappa_of(lit)
            k_new = kappa_of(lit_new)
            switched = jnp.float32(0.0)
            for ko, kn in zip(k_old, k_new):
                switched = switched + jnp.sum(
                    (jnp.abs(kn - ko) > 1e-6).astype(jnp.float32) * lmask)
            reconf = switched * reconfig_nj
        else:
            g_new = g
            reconf = jnp.float32(0.0)

        if faulted:
            failed = jnp.float32(0.0)
            for d, o in zip(des, ok):
                failed = failed + jnp.sum(
                    d * (o < 0.5).astype(jnp.float32) * lmask)
        else:
            failed = jnp.float32(0.0)

        # --- per-interval records (t_valid-masked like the scan body) ---
        lane = jax.lax.broadcasted_iota(jnp.float32, (1, s_cols), 1)
        vals = (lat * tm, total * tm, laser * tm, reconf * tm, minter * tm,
                sat * tm, failed * tm)
        row = jnp.zeros((1, s_cols), jnp.float32)
        for k, v in enumerate(vals):
            row = row + v * (lane == jnp.float32(k)).astype(jnp.float32)
        pl.store(scal_ref, (pl.dslice(t, 1), slice(None)), row)
        pl.store(g_out_ref, (pl.dslice(t, 1), slice(None)), g_eff * tm)
        pl.store(gdes_ref, (pl.dslice(t, 1), slice(None)), g * tm)
        pl.store(gwl_ref, (pl.dslice(t, 1), slice(None)), gw_load * tm)

        # Masked intervals freeze the controller carry.
        return tm * g_new + (1.0 - tm) * g

    g_final = jax.lax.fori_loop(0, t_chunk, interval_body, g_scr[...])
    g_scr[...] = g_final

    @pl.when(step == n_steps - 1)
    def _emit():
        gfin_ref[...] = g_scr[...]
