"""Wrapper for the fused epoch-scan kernel: lax.scan contract in, out.

`epoch_run_pallas(state, xs, sim, tables, ...)` is a drop-in replacement for
``jax.lax.scan(make_step(...), state, xs)`` on the configurations the kernel
supports (Arch.RESIPI / RESIPI_ALL, unpadded topology, optional destination
matrix, optional fault frames). It pads the time axis to the chunk size and
the chiplet axis to the TPU lane width (compiled mode), launches ONE
`pl.pallas_call` for the whole trace, and reassembles the exact record dict
and final SimState the scan body would have produced (1e-6 parity pinned in
tests/test_epoch_kernel.py, t_mask freezing and fault frames included).

Used by simulator._scan_trace when `SimConfig.epoch_kernel` is set; every
other path — and the parity oracle (ref.py) — keeps the lax.scan body.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import PHOTONIC_POWER
from repro.core.gateway_controller import ControllerState
from repro.core import topology
from repro.core.noc import uniform_mesh_mean_hops
from repro.kernels import resolve_interpret
from repro.kernels.epoch_step.kernel import (COL_FAILED, COL_LASER,
                                             COL_LATENCY, COL_MEAN_INTER,
                                             COL_POWER, COL_RECONFIG,
                                             COL_SATURATED, LANES, N_COLS,
                                             _epoch_kernel)


def epoch_run_pallas(state, xs, sim, tables: dict, *,
                     dest: Optional[jax.Array] = None, faulted: bool = False,
                     interpret: bool | None = None,
                     t_chunk: int | None = None) -> Tuple[object, dict]:
    """Run T intervals fused; returns (final SimState, records) like scan.

    Args:
      state: SimState carry (simulator._initial_state or a session carry).
      xs: the scan xs tuple — (ext [T, C], mem [T], intra [T, C], ext_frac
        [T], t_mask [T]) plus (gw_ok [T, C, G], stuck_on [T, C, G],
        drift_db [T]) when `faulted` — with loads already t_mask-multiplied
        (the _simulate_impl contract).
      sim: SimConfig; may carry traced sweep overrides in l_m, max/min
        gateways, buffer_sat, wavelengths (they ride the SMEM params row).
      tables: selection tables (src_hops / gw_loss_db per level).
      dest: optional [C, C] row-stochastic destination matrix.
      interpret: None = backend-aware (compiled on TPU), explicit bool to
        force; interpret mode skips lane padding like noc_step.
      t_chunk: intervals per grid step (default min(T, 128)).
    """
    from repro.core.simulator import Arch, SimState, _activity_mask

    if sim.arch not in (Arch.RESIPI, Arch.RESIPI_ALL):
        raise ValueError(f"epoch_step kernel supports RESIPI/RESIPI_ALL, "
                         f"got {sim.arch}")
    cfg = sim.cfg
    g_slots = cfg.max_gateways_per_chiplet
    mem_gws = cfg.memory_gateways
    if mem_gws < 1:
        raise ValueError("epoch_step kernel needs >= 1 memory gateway "
                         "(the kappa chain's constant tail)")

    ext, mem, intra, _ext_frac, t_mask = xs[:5]
    if faulted:
        gw_ok, stuck_on, drift = (jnp.asarray(a, jnp.float32)
                                  for a in xs[5:8])
    else:
        gw_ok = stuck_on = None
        drift = jnp.zeros(jnp.shape(mem), jnp.float32)
    ext = jnp.asarray(ext, jnp.float32)
    intra = jnp.asarray(intra, jnp.float32)
    mem = jnp.asarray(mem, jnp.float32)
    t_mask = jnp.asarray(t_mask, jnp.float32)
    t, c = ext.shape
    if t < 1:
        raise ValueError("epoch_step kernel needs at least one interval")

    interpret = resolve_interpret(interpret)
    pad_lanes = not interpret
    if t_chunk is None:
        t_chunk = min(t, 128)

    # --- time padding: masked tail intervals (frozen, zero records) -------
    t_pad = (-t) % t_chunk
    if t_pad:
        ext = jnp.pad(ext, ((0, t_pad), (0, 0)))
        intra = jnp.pad(intra, ((0, t_pad), (0, 0)))
        mem = jnp.pad(mem, (0, t_pad))
        drift = jnp.pad(drift, (0, t_pad))
        t_mask_p = jnp.pad(t_mask, (0, t_pad))
    else:
        t_mask_p = t_mask
    t_full = t + t_pad
    n_steps = t_full // t_chunk

    # --- lane padding: padded chiplets enter at g=1, zero load, masked ----
    pad = (-c) % LANES if pad_lanes else 0
    p = c + pad
    g0 = state.ctl.g.astype(jnp.float32)
    lmask = jnp.ones((c,), jnp.float32)
    if pad:
        ext = jnp.pad(ext, ((0, 0), (0, pad)))
        intra = jnp.pad(intra, ((0, 0), (0, pad)))
        g0 = jnp.pad(g0, (0, pad), constant_values=1.0)
        lmask = jnp.pad(lmask, (0, pad))
    use_dest = dest is not None
    if use_dest:
        dmat = jnp.asarray(dest, jnp.float32)
        if pad:
            dmat = jnp.pad(dmat, ((0, pad), (0, pad)))

    # Fault frames: [T, C, G] -> [G, T, P], padded lanes/intervals healthy
    # (gw_ok=1, stuck_on=0) so they behave exactly like clean padded lanes.
    if faulted:
        ok_k = jnp.transpose(gw_ok, (2, 0, 1))
        st_k = jnp.transpose(stuck_on, (2, 0, 1))
        if t_pad:
            ok_k = jnp.pad(ok_k, ((0, 0), (0, t_pad), (0, 0)),
                           constant_values=1.0)
            st_k = jnp.pad(st_k, ((0, 0), (0, t_pad), (0, 0)))
        if pad:
            ok_k = jnp.pad(ok_k, ((0, 0), (0, 0), (0, pad)),
                           constant_values=1.0)
            st_k = jnp.pad(st_k, ((0, 0), (0, 0), (0, pad)))

    # Runtime (possibly traced via sweep overrides) scalar knobs.
    params = jnp.stack([
        jnp.asarray(sim.ctl.l_m, jnp.float32),
        jnp.asarray(sim.ctl.max_gateways, jnp.float32),
        jnp.asarray(sim.ctl.min_gateways, jnp.float32),
        jnp.asarray(sim.noc.buffer_sat, jnp.float32),
        jnp.asarray(sim.wavelengths, jnp.float32),
    ])[None, :]
    srch = jnp.asarray(tables["src_hops"], jnp.float32)[None, :]
    gwdb = jnp.asarray(tables["gw_loss_db"], jnp.float32)[None, :]

    s_cols = LANES if pad_lanes else N_COLS
    noc = sim.noc
    pwr = PHOTONIC_POWER
    kernel = functools.partial(
        _epoch_kernel,
        t_chunk=t_chunk, n_steps=n_steps, n_chiplets=c, g_slots=g_slots,
        mem_gws=mem_gws, use_dest=use_dest, faulted=faulted,
        use_controller=sim.arch == Arch.RESIPI, s_cols=s_cols, n_lanes=p,
        interval=float(cfg.reconfig_interval_cycles),
        burstiness=float(noc.burstiness),
        rpc=float(noc.router_pipeline_cycles),
        flight=float(noc.photonic_flight_cycles),
        feed_links=float(noc.feed_links),
        flits=float(cfg.packet_flits),
        packet_bits=float(cfg.packet_bits),
        ser_k=float(cfg.link_gbps_per_wavelength / cfg.noc_freq_ghz),
        mesh_hops=float(uniform_mesh_mean_hops(cfg)),
        mesh_feed=2.0 * topology.feed_width(cfg),
        laser_mw=float(pwr.laser_mw_per_wavelength),
        tia_mw=float(pwr.tia_mw),
        tuning_mw=float(pwr.tuning_mw_per_mr),
        driver_mw=float(pwr.driver_mw),
        controller_mw=float((pwr.controller_lgc_uw * cfg.n_chiplets
                             + pwr.controller_inc_uw) / 1000.0),
        reconfig_nj=float(pwr.pcmc_reconfig_nj))

    row_spec = functools.partial(pl.BlockSpec, (1, t_chunk),
                                 lambda i: (i, 0),
                                 memory_space=pltpu.SMEM)
    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    chunk = lambda width: pl.BlockSpec((t_chunk, width), lambda i: (i, 0))
    in_specs = [
        chunk(p),                                             # ext
        chunk(p),                                             # intra
        row_spec(),                                           # mem
        row_spec(),                                           # t_mask
        row_spec(),                                           # drift_db
        pl.BlockSpec((1, 5), lambda i: (0, 0),
                     memory_space=pltpu.SMEM),                # params
        pl.BlockSpec((1, g_slots), lambda i: (0, 0),
                     memory_space=pltpu.SMEM),                # src_hops
        pl.BlockSpec((1, g_slots), lambda i: (0, 0),
                     memory_space=pltpu.SMEM),                # gw_loss_db
        whole((1, p)),                                        # g0
        whole((1, p)),                                        # lane mask
    ]
    inputs = [ext, intra, mem.reshape(n_steps, t_chunk),
              t_mask_p.reshape(n_steps, t_chunk),
              drift.reshape(n_steps, t_chunk), params, srch, gwdb,
              g0[None, :], lmask[None, :]]
    if use_dest:
        in_specs.append(whole((p, p)))
        inputs.append(dmat)
    if faulted:
        fault_spec = pl.BlockSpec((g_slots, t_chunk, p), lambda i: (0, i, 0))
        in_specs += [fault_spec, fault_spec]
        inputs += [ok_k, st_k]

    scal, out_g, out_gdes, out_gwl, out_gfin = pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=in_specs,
        out_specs=[
            chunk(s_cols), chunk(p), chunk(p), chunk(p), whole((1, p)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_full, s_cols), jnp.float32),
            jax.ShapeDtypeStruct((t_full, p), jnp.float32),
            jax.ShapeDtypeStruct((t_full, p), jnp.float32),
            jax.ShapeDtypeStruct((t_full, p), jnp.float32),
            jax.ShapeDtypeStruct((1, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, p), jnp.float32)],
        interpret=interpret,
    )(*inputs)

    # --- records: exactly the scan body's per-interval dict ---------------
    lam_f = jnp.asarray(sim.wavelengths, jnp.float32)
    latency = scal[:t, COL_LATENCY]
    power = scal[:t, COL_POWER]
    recs = {
        "latency": latency,
        "power_mw": power,
        "laser_mw": scal[:t, COL_LASER],
        "energy": power * latency,
        "reconfig_nj": scal[:t, COL_RECONFIG],
        "g": out_g[:t, :c].astype(jnp.int32),
        "wavelengths": lam_f * jnp.ones((t, c), jnp.float32)
                       * t_mask[:, None],
        "gw_load": out_gwl[:t, :c],
        "mean_inter_latency": scal[:t, COL_MEAN_INTER],
        "saturated": scal[:t, COL_SATURATED] > 0.5,
    }
    if faulted:
        recs["g_desired"] = out_gdes[:t, :c].astype(jnp.int32)
        recs["failed_slots"] = scal[:t, COL_FAILED]

    # --- final carry: g trajectory end + derived activity chain -----------
    n_valid = jnp.sum(t_mask)
    any_valid = n_valid > 0
    g_fin = out_gfin[0, :c].astype(jnp.int32)
    if faulted:
        # Activity under the LAST VALID interval's fault frame (the scan
        # body's new_active at that step); all-masked traces keep the old
        # prev_active via the any_valid gate below.
        idx = (t - 1) - jnp.argmax(t_mask[::-1] > 0).astype(jnp.int32)
        ok_l, st_l = gw_ok[idx], stuck_on[idx]                 # [C, G]
        desired = (jnp.arange(g_slots)[None, :]
                   < g_fin[:, None]).astype(jnp.float32)
        lit = jnp.maximum(desired * ok_l, st_l * ok_l)
        mem_on = jnp.ones((mem_gws,), jnp.float32)
        new_prev = jnp.concatenate([lit.reshape(-1), mem_on]) > 0.5
    else:
        new_prev = _activity_mask(g_fin, sim)
    if sim.arch == Arch.RESIPI:
        ctl = ControllerState(
            g=jnp.where(any_valid, g_fin, state.ctl.g),
            packets_seen=jnp.where(any_valid,
                                   jnp.zeros_like(state.ctl.packets_seen),
                                   state.ctl.packets_seen),
            epoch=state.ctl.epoch + n_valid.astype(jnp.int32))
    else:
        ctl = state.ctl
    new_state = SimState(
        ctl=ctl, wavelengths=state.wavelengths,
        prev_active=jnp.where(any_valid, new_prev, state.prev_active))
    return new_state, recs
