"""Pallas TPU kernels for the compute hot-spots.

flash_attention — blockwise softmax attention (prefill path)
ssd_scan        — Mamba2 SSD intra-chunk compute (the roofline memory fix)
noc_step        — flit-level NoC router sim (Fig. 13 residency)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes with
assert_allclose. Kernels run interpret=True on CPU, compiled on TPU.
"""
