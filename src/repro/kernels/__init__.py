"""Pallas TPU kernels for the compute hot-spots.

flash_attention — blockwise softmax attention (prefill path)
ssd_scan        — Mamba2 SSD intra-chunk compute (the roofline memory fix)
noc_step        — flit-level NoC router sim (Fig. 13 residency)
epoch_step      — fused RESIPI interval scan (metrics + power + controller)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes with
assert_allclose.

Backend policy: every kernel entry point takes `interpret=None`, resolved by
`resolve_interpret` — compiled on TPU, interpret mode everywhere else. These
kernels use TPU-specific constructs (`pltpu.VMEM` scratch), so GPU gets the
interpreter too, not a Triton lowering. Pass an explicit bool to force
either path.
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Backend-aware default for the Pallas `interpret` flag.

    None -> compiled on TPU, interpreter elsewhere (CPU has no Mosaic
    lowering; the kernels' pltpu scratch shapes don't lower on GPU).
    Explicit booleans pass through untouched (tests force interpret=True
    for oracle runs).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
