"""Optimizer tests: AdamW reference math, Adafactor state shapes/footprint,
clipping, schedules, guarded step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim


def test_adamw_first_step_matches_reference():
    cfg = optim.AdamWConfig(lr=1e-2, warmup=1, total_steps=100,
                            weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    state = optim.adamw_init(params)
    new_p, new_s, stats = optim.adamw_update(grads, state, params, cfg)
    # step 1: m_hat = g, v_hat = g^2 => update = g/|g| = sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(params["w"]) - 1e-2 * 1.0,
                               rtol=1e-4)


def test_adamw_converges_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, warmup=5, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.adamw_init(params)
    for _ in range(150):
        grads = {"w": params["w"]}        # d/dw of w^2/2
        params, state, _ = optim.adamw_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_adafactor_factored_state_small():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    state = optim.adafactor_init(params)
    assert state["stats"]["w"]["row"].shape == (64,)
    assert state["stats"]["w"]["col"].shape == (32,)
    assert state["stats"]["b"]["v"].shape == (7,)
    # factored footprint << adamw footprint
    af = 64 + 32
    adamw = 2 * 64 * 32
    assert af < adamw / 10


def test_adafactor_converges():
    cfg = optim.AdafactorConfig(lr=0.3, warmup=5, total_steps=300)
    params = {"w": jnp.full((8, 4), 3.0)}
    state = optim.adafactor_init(params)
    for _ in range(200):
        grads = {"w": params["w"]}
        params, state, _ = optim.adafactor_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}     # norm 5
    clipped, norm = optim.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)


def test_cosine_schedule_shape():
    lr = optim.cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(jnp.int32(55))) < 1.0


def test_guarded_train_step_skips_nonfinite():
    """The in-jit guard must freeze params on a NaN batch (donation-safe
    SDC protection)."""
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.train.train_step import init_train_state, make_train_step
    cfg = get_smoke_config("stablelm-3b")
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    # simulate silent data corruption: poison one embedding row with NaN
    emb = state["params"]["embed"]["embedding"]
    state["params"]["embed"]["embedding"] = emb.at[0].set(jnp.nan)
    step = jax.jit(make_train_step(model, guard=True))
    bad = {"tokens": jnp.zeros((2, 16), jnp.int32),
           "labels": jnp.ones((2, 16), jnp.int32)}
    w_before = state["params"]["ln_f"]["scale"]
    new_state, metrics = step(state, bad)
    assert int(metrics["skipped"]) == 1
    np.testing.assert_array_equal(
        np.asarray(new_state["params"]["ln_f"]["scale"]),
        np.asarray(w_before))
