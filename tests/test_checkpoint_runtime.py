"""Checkpointing (roundtrip, atomicity, GC) + fault-tolerance machinery."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.runtime.fault_tolerance import (Heartbeat, StepGuard,
                                           StragglerMonitor)
from repro.runtime.elastic import rescale_batch


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save_checkpoint(tree, str(tmp_path), step=10)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(
        jnp.shape(x), jnp.result_type(x)), tree)
    restored = ckpt.restore_checkpoint(like, str(tmp_path))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_latest_and_gc(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tree, str(tmp_path), step=s, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(kept) == 3
    assert kept[-1] == "step_00000005"


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save_checkpoint(_tree(), str(tmp_path), step=1)
    bad_like = {"a": jax.ShapeDtypeStruct((5, 8), jnp.float32),
                "nested": {"b": jax.ShapeDtypeStruct((6,), jnp.int32),
                           "c": jax.ShapeDtypeStruct((), jnp.float32)}}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_checkpoint(bad_like, str(tmp_path))


def test_train_state_roundtrip(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.train.train_step import init_train_state
    model = get_model(get_smoke_config("stablelm-3b"))
    state = init_train_state(model, jax.random.PRNGKey(0))
    ckpt.save_checkpoint(state, str(tmp_path), step=7)
    restored = ckpt.restore_checkpoint(state, str(tmp_path))
    np.testing.assert_allclose(
        np.asarray(restored["params"]["embed"]["embedding"]),
        np.asarray(state["params"]["embed"]["embedding"]))


def test_heartbeat_detects_stall():
    hb = Heartbeat(timeout_factor=3.0)
    assert hb.beat(1.0)
    assert hb.beat(1.1)
    assert not hb.beat(10.0)        # 10x slower => degraded
    assert hb.degraded


def test_step_guard_abort_after_max_skips():
    g = StepGuard(max_skips=2)
    assert g.check(1.0, 1.0)
    assert not g.check(float("nan"), 1.0)
    assert not g.check(1.0, float("inf"))
    with pytest.raises(RuntimeError, match="aborting"):
        g.check(float("nan"), 1.0)


def test_step_guard_grad_spike():
    g = StepGuard(grad_spike_factor=10.0)
    for _ in range(5):
        assert g.check(1.0, 1.0)
    assert not g.check(1.0, 100.0)   # 100x the EWMA


def test_straggler_monitor_lane_narrowing():
    m = StragglerMonitor(n_pods=4, threshold=1.3, escalate_after=2)
    for epoch in range(2):
        for pod in range(4):
            for _ in range(5):
                m.record(pod, 2.0 if pod == 3 else 1.0)
        v = m.epoch_verdict()
        assert v["slow_pods"] == [3]
        assert v["narrow_lanes_for"] == [3]
    assert v["escalate"] == [3]      # persistent => checkpoint/restart


def test_elastic_rescale_preserves_global_batch():
    plan = rescale_batch(global_batch=256, old_dp=32, new_dp=16)
    assert plan["per_replica_batch"] == 16
    assert plan["grad_accum"] == 2
    with pytest.raises(AssertionError):
        rescale_batch(global_batch=256, old_dp=32, new_dp=7)
