"""Sharding rules, data pipeline, and reconfig-runtime tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import reconfig_runtime as lanes
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.configs import get_smoke_config
from repro.sharding.rules import DEFAULT_RULES, Rules
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Mesh stand-in with named axis sizes (no devices needed)."""
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))
        self.size = int(self.devices.size)


def test_spec_resolution_and_dedup():
    rules = Rules(FakeMesh({"data": 16, "model": 16}))
    assert rules.spec("batch", None) == P("data", None)
    assert rules.spec("batch", "heads") == P("data", "model")
    # an axis may be used once per spec: second consumer degrades to None
    assert rules.spec("heads", "ff") == P("model", None)


def test_spec_for_shape_divisibility_guard():
    rules = Rules(FakeMesh({"data": 16, "model": 16}))
    # 24 heads % 16 != 0 -> replicated; 32 % 16 == 0 -> sharded
    assert rules.spec_for_shape((10, 24), None, "heads") == P(None, None)
    assert rules.spec_for_shape((10, 32), None, "heads") == P(None, "model")
    # multi-axis product check: batch -> (pod, data) = 32
    r3 = Rules(FakeMesh({"pod": 2, "data": 16, "model": 16}))
    assert r3.spec_for_shape((256, 4), "batch", None)[0] == ("pod", "data")
    assert r3.spec_for_shape((8, 4), "batch", None)[0] is None


def test_fsdp_rule_active():
    assert DEFAULT_RULES["model_d"] == ("data",)
    assert DEFAULT_RULES["kv_seq"] == ("model",)


def test_data_determinism_and_restart():
    cfg = get_smoke_config("stablelm-3b")
    data = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=32, seed=3))
    a = data.host_slice(step=17)
    b = data.host_slice(step=17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = data.host_slice(step=18)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.real_vocab
    # next-token alignment: labels are tokens shifted by one
    full_a = np.concatenate([a["tokens"], a["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], a["labels"])


def test_data_learnable_structure():
    cfg = get_smoke_config("stablelm-3b")
    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=256,
                                       repeat_p=0.3))
    b = data.host_slice(0)
    rep = np.mean(b["labels"][:, 1:] == b["labels"][:, :-1])
    assert rep > 0.2   # repetition signal present


# ---------------------------------------------------------------------------
# Level-2 lane controller
# ---------------------------------------------------------------------------

def test_lane_controller_widens_and_narrows():
    cfg = lanes.LaneConfig(max_lanes=4, l_m=0.5,
                           lane_bytes_per_step=1e6)
    st_ = lanes.LaneState.init(cfg)
    # heavy traffic: load per lane > l_m at 4 lanes -> stays/widens (capped)
    for _ in range(10):
        st_ = lanes.meter_step(st_, jnp.float32(4e6))
    st_, rec = lanes.epoch_update(st_, cfg)
    assert int(rec["lanes_after"]) == 4
    # light traffic: narrows one step per epoch
    for _ in range(3):
        for _ in range(10):
            st_ = lanes.meter_step(st_, jnp.float32(1e4))
        st_, rec = lanes.epoch_update(st_, cfg)
    assert int(rec["lanes_after"]) == 1


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(2, 12))
def test_chunk_pytree_partition(lanes_n, n_leaves):
    key = jax.random.PRNGKey(n_leaves)
    tree = {f"w{i}": jnp.ones((i + 1, 7)) for i in range(n_leaves)}
    bins = lanes.chunk_pytree(tree, lanes_n)
    assert len(bins) == lanes_n
    total = sum(len(b) for b in bins)
    assert total == n_leaves
    merged = lanes.merge_chunks(bins, tree)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, merged)


def test_laned_psum_single_lane_identity():
    tree = {"g": jnp.arange(6.0)}
    out = lanes.laned_psum(tree, None, 1)       # lanes=1: plain psum path
    # psum with axis None outside pmap is identity-ish; just check structure
    assert set(out) == {"g"}


def test_collective_bytes_estimate():
    tree = {"w": jnp.zeros((1000,), jnp.float32)}
    b = float(lanes.collective_bytes_of(tree, axis_size=2))
    assert b == pytest.approx(2 * 0.5 * 4000)


def test_nearest_compiled_width():
    assert lanes.nearest_compiled_width(3) in (2, 4)
    assert lanes.nearest_compiled_width(1) == 1
    assert lanes.nearest_compiled_width(4) == 4
