"""Padded topology-sweep engine tests.

Covers the topology-polymorphic batching layer: padded-vs-unpadded
equivalence (the masking invariant), single-compile behavior for whole
topology grids, the sharded entry point, padded selection tables, the
dead-lane kernel mask, and eager/compiled parity across architectures.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.constants import NETWORK, NetworkConfig
from repro.core.selection import (build_selection_tables,
                                  build_selection_tables_padded)
from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                  reset_engine_stats, shard_sweep, simulate,
                                  simulate_eager, sweep_topology,
                                  sweep_topology_batch,
                                  topology_point_config)
from repro.kernels.noc_step.kernel import noc_run_pallas
from repro.kernels.noc_step.ops import build_topology, build_topology_padded
from repro.kernels.noc_step.ref import reference_noc_run

SUMMARY_KEYS = ("mean_latency", "mean_power_mw", "mean_energy",
                "mean_gateways", "mean_wavelengths", "saturated_frac",
                "total_reconfig_nj")

GRID_C = [4, 6, 9]
GRID_G = [4, 2, 3]


@pytest.fixture(scope="module")
def wide_trace():
    cfg = NETWORK.with_topology(n_chiplets=max(GRID_C))
    return traffic.generate_trace("dedup", 14, jax.random.PRNGKey(0), cfg)


def _assert_point_matches(out, i, trace, sim_point, rtol=1e-4, atol=1e-4):
    c = sim_point.cfg.n_chiplets
    single = simulate(traffic.slice_trace(trace, c), sim_point)
    for k in SUMMARY_KEYS:
        np.testing.assert_allclose(
            np.asarray(out["summary"][k][i]),
            np.asarray(single["summary"][k]), rtol=rtol, atol=atol,
            err_msg=f"summary[{k}] grid point {i}")
    # per-chiplet records: real columns match, padded columns are zero
    g_pad = np.asarray(out["records"]["g"][i], np.float32)
    g_ref = np.asarray(single["records"]["g"], np.float32)
    np.testing.assert_allclose(g_pad[:, :c], g_ref, err_msg=f"g point {i}")
    assert np.all(g_pad[:, c:] == 0), "padded chiplet lanes lit gateways"
    gl_pad = np.asarray(out["records"]["gw_load"][i])
    assert np.all(gl_pad[:, c:] == 0), "padded chiplet lanes carried load"


@pytest.mark.parametrize("arch", list(Arch))
def test_padded_matches_unpadded_per_arch(wide_trace, arch):
    """sweep_topology == per-topology simulate for every architecture."""
    base = SimConfig().with_arch(arch)
    out = sweep_topology(wide_trace, base, n_chiplets=GRID_C,
                         gateways_per_chiplet=GRID_G)
    for i, (c, g) in enumerate(zip(GRID_C, GRID_G)):
        _assert_point_matches(
            out, i, wide_trace,
            topology_point_config(base, n_chiplets=c,
                                  gateways_per_chiplet=g))


def test_pad_to_actual_size_bit_matches(wide_trace):
    """A grid whose maxima equal one topology = all-ones masks: the padded
    scan must reproduce unpadded `simulate` to tight float tolerance."""
    base = SimConfig().with_arch(Arch.RESIPI)
    out = sweep_topology(wide_trace, base, n_chiplets=[max(GRID_C)])
    _assert_point_matches(
        out, 0, wide_trace,
        topology_point_config(base, n_chiplets=max(GRID_C)),
        rtol=1e-6, atol=1e-6)


def test_mesh_radix_sweep_matches(wide_trace):
    base = SimConfig().with_arch(Arch.RESIPI)
    radii = [4, 6]
    out = sweep_topology(wide_trace, base, n_chiplets=[4, 4],
                         mesh_radix=radii)
    for i, r in enumerate(radii):
        _assert_point_matches(
            out, i, wide_trace,
            topology_point_config(base, n_chiplets=4, mesh_radix=r))


def test_radix_sweep_resets_explicit_base_placement(wide_trace):
    """A mesh_radix grid point must drop the base config's explicit
    placement (with_topology's reset contract), not re-apply stale
    coordinates from the old mesh — parity vs topology_point_config."""
    base = SimConfig().with_arch(Arch.RESIPI)
    base = dataclasses.replace(base, cfg=base.cfg.with_placement(
        ((1, 1), (2, 2), (1, 2), (2, 1))))
    radii = [4, 6]
    out = sweep_topology(wide_trace, base, mesh_radix=radii)
    for i, r in enumerate(radii):
        _assert_point_matches(
            out, i, wide_trace,
            topology_point_config(base, mesh_radix=r))


def test_whole_grid_is_one_compile(wide_trace):
    """The acceptance invariant: K topologies, ONE scan-body trace, and a
    warm re-call (even with different grid values) re-traces nothing."""
    # a config no other test uses, so this test owns its compile
    base = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                               prowaves_rho_lo=0.311)
    reset_engine_stats()
    sweep_topology(wide_trace, base, n_chiplets=GRID_C,
                   gateways_per_chiplet=GRID_G)
    assert engine_stats()["simulate_traces"] == 1
    sweep_topology(wide_trace, base, n_chiplets=GRID_C,
                   gateways_per_chiplet=GRID_G)
    assert engine_stats()["simulate_traces"] == 1
    # same shapes/maxima, different grid point values: still no re-trace
    sweep_topology(wide_trace, base, n_chiplets=[3, 5, 9],
                   gateways_per_chiplet=[2, 1, 4])
    assert engine_stats()["simulate_traces"] == 1


def test_sweep_topology_batch_shape_and_parity(wide_trace):
    cfg = NETWORK.with_topology(n_chiplets=max(GRID_C))
    tr2 = traffic.generate_trace("canneal", 14, jax.random.PRNGKey(5), cfg)
    base = SimConfig().with_arch(Arch.RESIPI)
    out = sweep_topology_batch([wide_trace, tr2], base, n_chiplets=GRID_C,
                               gateways_per_chiplet=GRID_G)
    assert out["summary"]["mean_latency"].shape == (2, len(GRID_C))
    single = sweep_topology(tr2, base, n_chiplets=GRID_C,
                            gateways_per_chiplet=GRID_G)
    np.testing.assert_allclose(
        np.asarray(out["summary"]["mean_latency"][1]),
        np.asarray(single["summary"]["mean_latency"]), rtol=1e-5)


def test_shard_sweep_matches_single_device(wide_trace):
    """On whatever device layout exists, shard_sweep == sweep_topology."""
    base = SimConfig().with_arch(Arch.RESIPI)
    a = shard_sweep(wide_trace, base, n_chiplets=GRID_C)
    b = sweep_topology(wide_trace, base, n_chiplets=GRID_C)
    np.testing.assert_allclose(
        np.asarray(a["summary"]["mean_latency"]),
        np.asarray(b["summary"]["mean_latency"]), rtol=1e-5)


def test_validation_errors(wide_trace):
    base = SimConfig().with_arch(Arch.RESIPI)
    with pytest.raises(ValueError):
        sweep_topology(wide_trace, base)                     # nothing swept
    with pytest.raises(ValueError):
        sweep_topology(wide_trace, base, bogus_field=[1, 2])
    with pytest.raises(ValueError):
        sweep_topology(wide_trace, base, n_chiplets=[4, 8],
                       gateways_per_chiplet=[2])             # length mismatch
    with pytest.raises(ValueError):
        sweep_topology(wide_trace, base, gateways_per_chiplet=[6])
    with pytest.raises(ValueError):                          # trace too narrow
        sweep_topology(wide_trace, base, n_chiplets=[max(GRID_C) + 8])
    with pytest.raises(ValueError):                          # runtime-only
        sweep_topology(wide_trace, base, l_m=jnp.asarray([0.01]))


def test_topology_with_runtime_field_combined(wide_trace):
    """Topology axes zip with runtime SWEEPABLE_FIELDS in one grid."""
    base = SimConfig().with_arch(Arch.RESIPI)
    lms = [0.008, 0.02]
    out = sweep_topology(wide_trace, base, n_chiplets=[4, 9],
                         l_m=jnp.asarray(lms))
    for i, (c, lm) in enumerate(zip([4, 9], lms)):
        point = topology_point_config(base, n_chiplets=c)
        point = dataclasses.replace(
            point, ctl=dataclasses.replace(point.ctl, l_m=lm))
        single = simulate(traffic.slice_trace(wide_trace, c), point)
        np.testing.assert_allclose(
            np.asarray(out["summary"]["mean_latency"][i]),
            np.asarray(single["summary"]["mean_latency"]),
            rtol=1e-4, err_msg=f"point {i}")


# ---------------------------------------------------------------------------
# Padded selection tables
# ---------------------------------------------------------------------------

def test_padded_selection_tables():
    cfgs = tuple(NetworkConfig().with_topology(n_chiplets=c,
                                               gateways_per_chiplet=g,
                                               mesh_radix=r)
                 for c, g, r in [(4, 4, 4), (16, 2, 4), (64, 4, 6)])
    p = build_selection_tables_padded(cfgs)
    g_pad, r_pad = 4, 36
    assert p.src_map.shape == (3, g_pad, r_pad)
    assert p.src_hops.shape == (3, g_pad)
    # validity masks + zero padding
    np.testing.assert_array_equal(p.gw_mask[1], [1, 1, 0, 0])
    assert np.all(p.src_hops[1, 2:] == 0)
    np.testing.assert_array_equal(p.router_mask[0],
                                  [1] * 16 + [0] * 20)
    assert np.all(p.src_map[0, :, 16:] == 0)
    # real slices equal the unpadded per-config tables
    t = build_selection_tables(dataclasses.replace(cfgs[0], n_chiplets=1))
    np.testing.assert_array_equal(p.src_map[0, :, :16], t.src_map)
    np.testing.assert_allclose(p.src_hops[0], t.src_hops)
    # memoized per (cfgs, pad_to)
    assert build_selection_tables_padded(cfgs) is p
    assert build_selection_tables_padded(cfgs, (4, 64)) is not p


def test_padded_tables_reject_too_small_pad():
    with pytest.raises(ValueError):
        build_selection_tables_padded((NetworkConfig(),), (2, 16))


# ---------------------------------------------------------------------------
# Dead-lane kernel mask
# ---------------------------------------------------------------------------

def test_noc_kernel_valid_mask_kills_padded_lanes():
    """Garbage arrivals/buffers in masked lanes must not leak anywhere."""
    nm, drain, buf, mask = build_topology_padded(2, 4, pad_to=32)
    n_real = build_topology(2, 4)[0].shape[0]
    key = jax.random.PRNGKey(7)
    arr = (jax.random.uniform(key, (256, 32)) < 0.05
           ).astype(jnp.float32) * 8              # nonzero in dead lanes too
    buf_garbage = buf.copy()
    buf_garbage[n_real:] = 64.0                   # dead lanes offer space
    rk, ok, dk = noc_run_pallas(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf_garbage),
        valid_mask=jnp.asarray(mask), t_chunk=64, interpret=True,
        pad_lanes=True)
    rr, orr, dr = reference_noc_run(
        arr[:, :n_real], jnp.asarray(nm[:n_real, :n_real]),
        jnp.asarray(drain[:n_real]), jnp.asarray(buf[:n_real]))
    assert np.all(np.asarray(rk[n_real:]) == 0)
    assert np.all(np.asarray(ok[n_real:]) == 0)
    assert np.all(np.asarray(dk[n_real:]) == 0)
    np.testing.assert_allclose(rk[:n_real], rr, atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(dk[:n_real], dr, atol=1e-2, rtol=1e-4)


def test_noc_ref_valid_mask_matches_kernel():
    nm, drain, buf, mask = build_topology_padded(3, 4, pad_to=24)
    arr = (jax.random.uniform(jax.random.PRNGKey(9), (128, 24)) < 0.04
           ).astype(jnp.float32) * 8
    rk, ok, dk = noc_run_pallas(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf),
        valid_mask=jnp.asarray(mask), t_chunk=64, interpret=True)
    rr, orr, dr = reference_noc_run(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf),
        valid_mask=jnp.asarray(mask))
    np.testing.assert_allclose(rk, rr, atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(ok, orr, atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(dk, dr, atol=1e-2, rtol=1e-4)


# ---------------------------------------------------------------------------
# Eager/compiled parity (seed-baseline path stays honest)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(Arch))
def test_simulate_eager_matches_simulate(arch):
    tr = traffic.generate_trace("fluidanimate", 16, jax.random.PRNGKey(3))
    sim = SimConfig().with_arch(arch)
    eager = simulate_eager(tr, sim)["summary"]
    jitted = simulate(tr, sim)["summary"]
    for k in SUMMARY_KEYS:
        np.testing.assert_allclose(
            np.asarray(eager[k]), np.asarray(jitted[k]),
            rtol=1e-5, atol=1e-5, err_msg=f"{arch} summary[{k}]")
