"""Runtime fault-tolerance unit tests: Heartbeat, StepGuard, Straggler.

These are the trainer-side counterparts of the interposer fault model in
tests/test_faults.py: detection is EWMA/threshold-based (like the
ResilienceRuntime), and the first response is *reconfiguration* (narrow
lanes across the slow pod) rather than restart — the paper's PCM
reconfiguration philosophy applied to failure handling.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.reconfig_runtime import (LANE_WIDTHS, LaneConfig,
                                         nearest_compiled_width)
from repro.runtime.fault_tolerance import (Heartbeat, StepGuard,
                                           StragglerMonitor)


# ---------------------------------------------------------------------------
# Heartbeat: EWMA step-time watermark, spike -> degraded
# ---------------------------------------------------------------------------

def test_heartbeat_steady_steps_stay_healthy():
    hb = Heartbeat(timeout_factor=5.0)
    assert all(hb.beat(0.1) for _ in range(50))
    assert not hb.degraded


def test_heartbeat_spike_marks_degraded_and_stays_degraded():
    hb = Heartbeat(timeout_factor=5.0, ewma=0.3)
    for _ in range(10):
        assert hb.beat(0.1)
    assert not hb.beat(1.0)          # 10x the EWMA mean -> degraded
    assert hb.degraded
    # Degradation is sticky: the supervisor must checkpoint/restart, a
    # single later fast step cannot clear it.
    assert not hb.beat(0.1)


def test_heartbeat_first_beat_seeds_the_mean():
    hb = Heartbeat(timeout_factor=2.0)
    assert hb.beat(100.0)            # no baseline yet -> healthy by fiat
    assert hb.beat(150.0)            # 1.5x: under factor
    assert not hb.beat(10_000.0)


def test_heartbeat_ewma_tracks_gradual_slowdown():
    # A slow drift (each step 5% longer) never crosses 5x the EWMA, so the
    # run stays healthy — drift is the StragglerMonitor's job, not the
    # liveness watchdog's.
    hb = Heartbeat(timeout_factor=5.0, ewma=0.3)
    t = 0.1
    for _ in range(60):
        assert hb.beat(t)
        t *= 1.05
    assert not hb.degraded


# ---------------------------------------------------------------------------
# StepGuard: NaN / grad-spike skip-and-continue, bounded by max_skips
# ---------------------------------------------------------------------------

def test_step_guard_accepts_finite_steps():
    g = StepGuard()
    assert all(g.check(1.0, 0.5) for _ in range(20))
    assert g.skips == 0


@pytest.mark.parametrize("loss,gnorm", [
    (float("nan"), 1.0), (float("inf"), 1.0),
    (1.0, float("nan")), (1.0, float("inf"))])
def test_step_guard_skips_non_finite(loss, gnorm):
    g = StepGuard()
    g.check(1.0, 1.0)
    assert g.check(loss, gnorm) is False
    assert g.skips == 1
    # A bad step must not poison the EWMA: the next clean step applies.
    assert g.check(1.0, 1.0) is True


def test_step_guard_skips_grad_spike_but_not_first_step():
    g = StepGuard(grad_spike_factor=50.0)
    assert g.check(1.0, 1e9) is True       # no EWMA yet: no spike reference
    g2 = StepGuard(grad_spike_factor=50.0)
    g2.check(1.0, 1.0)
    assert g2.check(1.0, 100.0) is False   # 100x the EWMA -> skipped
    assert g2.skips == 1


def test_step_guard_bounded_by_max_skips():
    g = StepGuard(max_skips=3)
    g.check(1.0, 1.0)
    for _ in range(3):
        assert g.check(float("nan"), 1.0) is False
    with pytest.raises(RuntimeError, match="bad steps"):
        g.check(float("nan"), 1.0)


def test_step_guard_skip_budget_is_cumulative_not_consecutive():
    # Interleaved good steps do NOT reset the budget — a slow trickle of
    # SDC still aborts eventually.
    g = StepGuard(max_skips=2)
    g.check(1.0, 1.0)
    g.check(float("inf"), 1.0)
    g.check(1.0, 1.0)
    g.check(float("inf"), 1.0)
    g.check(1.0, 1.0)
    with pytest.raises(RuntimeError):
        g.check(float("inf"), 1.0)


# ---------------------------------------------------------------------------
# StragglerMonitor: slow-pod detection -> lane narrowing -> escalation
# ---------------------------------------------------------------------------

def test_straggler_flags_slow_pod_and_names_lanes():
    mon = StragglerMonitor(n_pods=4, threshold=1.3)
    for step in range(8):
        for pod in range(4):
            mon.record(pod, 0.2 if pod != 2 else 0.5)
    v = mon.epoch_verdict()
    assert v["slow_pods"] == [2]
    assert v["narrow_lanes_for"] == [2]
    assert v["escalate"] == []
    np.testing.assert_allclose(v["pod_means"][2], 0.5)


def test_straggler_healthy_fleet_flags_nothing():
    mon = StragglerMonitor(n_pods=3)
    for pod in range(3):
        mon.record(pod, 0.1)
    v = mon.epoch_verdict()
    assert v["slow_pods"] == [] and v["escalate"] == []


def test_straggler_escalates_only_after_persistent_slowness():
    mon = StragglerMonitor(n_pods=2, threshold=1.3, escalate_after=3)
    for epoch in range(3):
        mon.record(0, 0.1)
        mon.record(1, 0.9)
        v = mon.epoch_verdict()
        assert v["slow_pods"] == [1]
        # Reconfiguration-first: lanes narrow every epoch, restart only
        # once the pod has been slow for escalate_after consecutive epochs.
        assert v["escalate"] == ([1] if epoch == 2 else [])


def test_straggler_recovery_resets_the_escalation_clock():
    mon = StragglerMonitor(n_pods=2, escalate_after=2)
    mon.record(0, 0.1)
    mon.record(1, 0.9)
    assert mon.epoch_verdict()["slow_pods"] == [1]
    mon.record(0, 0.1)                      # pod 1 back to fleet speed
    mon.record(1, 0.1)
    assert mon.epoch_verdict()["slow_pods"] == []
    mon.record(0, 0.1)
    mon.record(1, 0.9)
    assert mon.epoch_verdict()["escalate"] == []   # clock restarted


def test_straggler_verdict_drives_lane_narrowing():
    """End-to-end response path: slow pod -> snap to a narrower compiled
    lane width through the ReSiPI controller's pre-compiled table."""
    cfg = LaneConfig()
    mon = StragglerMonitor(n_pods=2, threshold=1.3)
    lanes = cfg.max_lanes
    mon.record(0, 0.1)
    mon.record(1, 0.8)
    v = mon.epoch_verdict()
    if v["narrow_lanes_for"]:
        lanes = nearest_compiled_width(max(cfg.min_lanes, lanes // 2))
    assert lanes in LANE_WIDTHS and lanes < cfg.max_lanes
