"""Model correctness: flash==dense attention, decode==prefill consistency,
loss sanity, remat/scan equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.models import layers as L
from repro.models.params import init_params


def test_flash_equals_dense_attention_path():
    """The model's internal blockwise path must match materialized scores."""
    b, s, h, d = 2, 128, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    dense = L._dense_attend(q, k, v, True, pos, pos)
    flash = L._flash_attend(q, k, v, True, pos, pos, 32, 64)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["stablelm-3b", "phi4-mini-3.8b",
                                  "mamba2-130m", "seamless-m4t-large-v2"])
def test_decode_consistent_with_prefill(arch):
    """prefill(S).logits == prefill(S-1) then decode(token_{S-1}).logits —
    the KV-cache path must agree with the teacher-forced path."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.spec(), jax.random.PRNGKey(0))
    b, s, max_len = 2, 16, 24
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (b, s), 0, cfg.real_vocab)

    def mk(t):
        batch = {"tokens": t}
        if cfg.family == "encdec":
            # encoder input fixed across the two paths
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(7), (b, s, cfg.d_model))
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(
                jax.random.PRNGKey(8), (b, cfg.frontend_embeds,
                                        cfg.d_model))
        return batch

    if cfg.family == "encdec":
        # decoder prefill length varies but encoder frames fixed length s
        _, logits_full = model.prefill(params, mk(toks), max_len)
        caches, _ = model.prefill(
            params, {**mk(toks), "tokens": toks[:, :-1]}, max_len)
    else:
        _, logits_full = model.prefill(params, mk(toks), max_len)
        caches, _ = model.prefill(params, mk(toks[:, :-1]), max_len)
    logits_step, _ = model.decode_step(params, toks[:, -1:], caches)
    np.testing.assert_allclose(np.asarray(logits_step, np.float32),
                               np.asarray(logits_full, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_ssd_decode_matches_chunked_scan():
    """Token-by-token SSM decode must equal the chunked parallel scan."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    b, l, h, p, n = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, l, 1, n)) * 0.5
    cc = jax.random.normal(ks[4], (b, l, 1, n)) * 0.5
    y_par, s_par = ssd_chunked(x, dt, a, bb, cc, chunk=16)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        y_t, state = ssd_decode_step(x[:, t:t+1], dt[:, t:t+1], a,
                                     bb[:, t:t+1], cc[:, t:t+1], state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_par),
                               atol=1e-3, rtol=1e-3)


def test_rope_orthogonality():
    """RoPE preserves norms and relative-position dot products."""
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, d))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = L.rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1),
                               atol=1e-4, rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))
    def dot_at(i, j):
        qi = L.rope(q, jnp.full((1, 1), i), 10000.0)
        kj = L.rope(k, jnp.full((1, 1), j), 10000.0)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)


def test_chunked_ce_matches_direct():
    from repro.models.transformer import chunked_cross_entropy
    b, s, d, v = 2, 24, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    hidden = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v)) * 0.1
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    sl, sw = chunked_cross_entropy({"w": w}, hidden, labels, None, chunk=8)
    logits = (hidden.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)
              ).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    np.testing.assert_allclose(float(sl), float(jnp.sum(lse - gold)),
                               rtol=1e-3)
    assert float(sw) == b * s


def test_vocab_padding_masked_in_ce():
    from repro.models.transformer import chunked_cross_entropy
    b, s, d, v, v_real = 1, 8, 16, 64, 50
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    hidden = jax.random.normal(ks[0], (b, s, d))
    w = jax.random.normal(ks[1], (d, v)) * 0.1
    labels = jax.random.randint(ks[2], (b, s), 0, v_real)
    sl_masked, _ = chunked_cross_entropy({"w": w}, hidden, labels, None,
                                         chunk=8, real_vocab=v_real)
    logits = (hidden.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)
              ).astype(jnp.float32)
    logits = jnp.where(jnp.arange(v) < v_real, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    np.testing.assert_allclose(float(sl_masked),
                               float(jnp.sum(lse - gold)), rtol=1e-3)
