"""Compile-once engine tests: jit caching, batching, sweeps, padded kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.constants import NetworkConfig
from repro.core.gateway_controller import ControllerConfig
from repro.core.selection import build_selection_tables, selection_tables_jax
from repro.core.simulator import (Arch, SimConfig, engine_stats, simulate,
                                  simulate_batch, stack_traces, sweep,
                                  sweep_batch)
from repro.kernels.noc_step.kernel import noc_run_pallas
from repro.kernels.noc_step.ops import build_topology
from repro.kernels.noc_step.ref import reference_noc_run


@pytest.fixture(scope="module")
def traces():
    apps = ["dedup", "canneal", "facesim"]
    return [traffic.generate_trace(a, 24, jax.random.PRNGKey(i))
            for i, a in enumerate(apps)]


def test_simulate_batch_matches_individual(traces):
    sim = SimConfig().with_arch(Arch.RESIPI)
    batched = simulate_batch(traces, sim)
    for i, tr in enumerate(traces):
        single = simulate(tr, sim)
        for k, v in single["summary"].items():
            np.testing.assert_allclose(
                np.asarray(batched["summary"][k][i]), np.asarray(v),
                rtol=1e-5, atol=1e-5, err_msg=f"summary[{k}] trace {i}")
        for k, v in single["records"].items():
            np.testing.assert_allclose(
                np.asarray(batched["records"][k][i], np.float32),
                np.asarray(v, np.float32),
                rtol=1e-5, atol=1e-5, err_msg=f"records[{k}] trace {i}")


def test_simulate_batch_accepts_stacked_dict(traces):
    sim = SimConfig().with_arch(Arch.PROWAVES)
    a = simulate_batch(traces, sim)["summary"]["mean_latency"]
    b = simulate_batch(stack_traces(traces), sim)["summary"]["mean_latency"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_second_call_does_not_retrace(traces):
    # a config value no other test uses, so the first call must compile
    sim = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                              ctl=ControllerConfig(l_m=0.0107))
    simulate(traces[0], sim)
    before = engine_stats()["simulate_traces"]
    out = simulate(traces[0], sim)
    jax.block_until_ready(out["summary"]["mean_latency"])
    # an *equal but not identical* config must also hit the cache
    sim2 = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                               ctl=ControllerConfig(l_m=0.0107))
    assert sim2 is not sim
    simulate(traces[0], sim2)
    assert engine_stats()["simulate_traces"] == before


def test_selection_tables_built_once_per_config():
    cfg1 = NetworkConfig()
    cfg2 = dataclasses.replace(NetworkConfig())
    assert cfg2 is not cfg1
    t1 = build_selection_tables(cfg1)
    t2 = build_selection_tables(cfg2)
    assert t1 is t2                      # memoized on config value
    j1 = selection_tables_jax(cfg1)
    j2 = selection_tables_jax(cfg2)
    assert j1 is j2                      # device tables shared too
    # a genuinely different topology gets its own tables
    big = dataclasses.replace(NetworkConfig(), mesh_x=6, mesh_y=6)
    t3 = build_selection_tables(big)
    assert t3 is not t1
    assert t3.src_map.shape[1] == 36


def test_sweep_matches_individual_configs(traces):
    tr = traces[1]
    base = SimConfig().with_arch(Arch.RESIPI)
    lms = [0.005, 0.0152, 0.03]
    swept = sweep(tr, base, l_m=jnp.asarray(lms))["summary"]
    for i, lm in enumerate(lms):
        sim_i = dataclasses.replace(base, ctl=dataclasses.replace(
            base.ctl, l_m=lm))
        single = simulate(tr, sim_i)["summary"]
        for k in ("mean_latency", "mean_power_mw", "mean_gateways"):
            np.testing.assert_allclose(
                np.asarray(swept[k][i]), np.asarray(single[k]),
                rtol=1e-5, atol=1e-5, err_msg=f"{k} @ l_m={lm}")


def test_sweep_multi_field_and_validation(traces):
    tr = traces[0]
    sim = SimConfig().with_arch(Arch.RESIPI)
    out = sweep(tr, sim, l_m=jnp.asarray([0.01, 0.02]),
                buffer_sat=jnp.asarray([0.45, 0.65]))
    assert out["summary"]["mean_latency"].shape == (2,)
    with pytest.raises(ValueError):
        sweep(tr, sim, n_chiplets=jnp.asarray([4, 8]))   # shape-changing
    with pytest.raises(ValueError):
        sweep(tr, sim, l_m=jnp.asarray([0.01, 0.02]),
              buffer_sat=jnp.asarray([0.45]))            # length mismatch
    with pytest.raises(ValueError):
        sweep(tr, sim)                                   # nothing swept


def test_sweep_batch_gateway_grid_matches_fixed_configs(traces):
    """One [N traces x K gateway-counts] call == per-config simulate calls.

    The fig10 DSE path: pinning the controller via runtime max/min gateway
    overrides must equal pinning it statically in ControllerConfig.
    """
    base = SimConfig().with_arch(Arch.RESIPI)
    gs = [1, 3]
    out = sweep_batch(traces, base, max_gateways=jnp.asarray(gs),
                      min_gateways=jnp.asarray(gs))
    for i, tr in enumerate(traces):
        for gi, g in enumerate(gs):
            pinned = dataclasses.replace(base, ctl=ControllerConfig(
                l_m=base.ctl.l_m, max_gateways=g, min_gateways=g))
            single = simulate(tr, pinned)["summary"]
            for k in ("mean_latency", "mean_power_mw", "mean_gateways"):
                np.testing.assert_allclose(
                    np.asarray(out["summary"][k][i, gi]),
                    np.asarray(single[k]), rtol=1e-5, atol=1e-5,
                    err_msg=f"{k} trace {i} g={g}")


def test_sweep_wavelengths_monotone_power(traces):
    """More wavelengths on the static datapath -> more laser power."""
    out = sweep(traces[0], SimConfig().with_arch(Arch.RESIPI_ALL),
                wavelengths=jnp.asarray([2, 4, 8]))
    pw = np.asarray(out["summary"]["mean_power_mw"])
    assert np.all(np.diff(pw) > 0)


def test_noc_padded_path_matches_reference():
    """Lane-padded kernel (the compiled-path layout) == unpadded oracle."""
    nm, drain, buf, _ = build_topology(2, 4)
    n = nm.shape[0]
    arr = (jax.random.uniform(jax.random.PRNGKey(11), (512, n)) <
           0.03).astype(jnp.float32) * 8
    rk, ok, dk = noc_run_pallas(arr, jnp.asarray(nm), jnp.asarray(drain),
                                jnp.asarray(buf), t_chunk=128,
                                interpret=True, pad_lanes=True)
    rr, orr, dr = reference_noc_run(arr, jnp.asarray(nm), jnp.asarray(drain),
                                    jnp.asarray(buf))
    assert rk.shape == (n,)
    np.testing.assert_allclose(rk, rr, atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(ok, orr, atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(dk, dr, atol=1e-2, rtol=1e-4)
