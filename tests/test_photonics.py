"""Photonic device model tests: Eqs. 1-4, power gating, non-volatility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import photonics
from repro.core.constants import PHOTONIC_POWER


def test_pcmc_coupling_ratio_eq1():
    k = photonics.pcmc_coupling_ratio(jnp.float32(2.0), jnp.float32(4.0))
    assert float(k) == pytest.approx(0.5)
    # clipped to physical range
    assert float(photonics.pcmc_coupling_ratio(
        jnp.float32(9.0), jnp.float32(3.0))) == 1.0


def test_pcmc_split_eqs2_3():
    pc, pb = photonics.pcmc_split(jnp.float32(10.0), jnp.float32(0.3))
    assert float(pc) == pytest.approx(3.0)
    assert float(pb) == pytest.approx(7.0)


def test_pcmc_split_three_states_fig5():
    # crystalline: all to Bar; amorphous: all to Cross; partial: split
    pc, pb = photonics.pcmc_split(jnp.float32(1.0), jnp.float32(0.0))
    assert float(pc) == 0.0 and float(pb) == 1.0
    pc, pb = photonics.pcmc_split(jnp.float32(1.0), jnp.float32(1.0))
    assert float(pc) == 1.0 and float(pb) == 0.0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.booleans(), min_size=2, max_size=18))
def test_equal_power_share_eq4(active_list):
    """Eq. 4's defining property: every active gateway receives P/GT and
    idle gateways receive zero, for ANY activity pattern."""
    active = jnp.asarray(active_list, bool)
    p_in = jnp.float32(120.0)
    recv = photonics.power_division(active, p_in)
    gt = int(np.sum(active_list))
    if gt == 0:
        np.testing.assert_allclose(np.asarray(recv), 0.0, atol=1e-4)
        return
    expect = 120.0 / gt
    for i, a in enumerate(active_list):
        if a:
            assert float(recv[i]) == pytest.approx(expect, rel=1e-4)
        else:
            assert float(recv[i]) == pytest.approx(0.0, abs=1e-4)


def test_kappa_schedule_matches_paper_index_form():
    # all active: kappa_i = 1/(GT - i), i = # active upstream
    active = jnp.ones((6,), bool)
    kappa = photonics.kappa_schedule(active)
    np.testing.assert_allclose(
        np.asarray(kappa), [1 / 6, 1 / 5, 1 / 4, 1 / 3, 1 / 2],
        rtol=1e-6)


def test_reconfig_energy_nonvolatile():
    """PCM retains state at zero power: unchanged activity = zero energy."""
    a = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    assert float(photonics.reconfig_energy_nj(a, a)) == 0.0
    b = jnp.asarray([1, 1, 1, 1, 0, 1], bool)
    assert float(photonics.reconfig_energy_nj(a, b)) > 0.0


def test_power_modes_ordering():
    """PCM gating at low activity must beat the wdm design with all
    gateways lit, and laser power must scale with the loss budget."""
    n = 18
    low = jnp.zeros((n,), bool).at[:6].set(True)
    pcm = photonics.interposer_power_mw(low, 4.0, n_gateways=n, mode="pcm")
    wdm = photonics.interposer_power_mw(jnp.ones((6,), bool),
                                        jnp.full((6,), 16.0),
                                        n_gateways=6, mode="wdm")
    assert float(pcm["total_mw"]) < float(wdm["total_mw"])
    lossless = photonics.interposer_power_mw(low, 4.0, n_gateways=n,
                                             mode="pcm", loss_db=0.0)
    lossy = photonics.interposer_power_mw(low, 4.0, n_gateways=n,
                                          mode="pcm", loss_db=1.8)
    assert float(lossy["laser_mw"]) == pytest.approx(
        float(lossless["laser_mw"]) * 10 ** 0.18, rel=1e-5)


def test_interposer_geometry_counts():
    g = photonics.InterposerGeometry(n_gateways=6, wavelengths=4)
    assert g.mrgs == 6
    assert g.pcmcs == 5
    assert g.modulators_per_mrg == 4
    assert g.filters_per_mrg == 20       # (N-1) rows x W
    assert g.total_mrs == 6 * 24
