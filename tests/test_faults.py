"""Fault-injection invariants (the robustness PR's tentpole contracts).

Pinned here, mirroring the PR 2/4 masking invariants:

  * never-fire parity — a fault frame whose windows never intersect the
    simulated horizon matches fault-free `simulate` at 1e-6, per arch;
  * dead-slot equivalence — hard-failing slots is *provably* identical to
    never having them: pinned g=4 with slots 2,3 failed on every chiplet
    equals pinned g=2 fault-free in every latency/power/energy reduction;
  * the fault grid is an ordinary sweep axis (vmap parity, one executable);
  * chunk alignment — fault events ride the trace transforms, so a
    streamed faulted session bit-matches the one-shot faulted scan;
  * the noc_step kernel's time-varying valid_mask path matches its lax.scan
    oracle and degrades to the static path bit-for-bit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults, traffic
from repro.core.simulator import (Arch, SimConfig, SimSession, engine_stats,
                                  reset_engine_stats, simulate,
                                  simulate_batch, stack_traces, sweep_faults)

T = 12


def _trace(seed=0, t=T):
    return traffic.generate_trace("dedup", t, jax.random.PRNGKey(seed))


def _close(a, b, rtol=1e-6, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, **kw)


# ---------------------------------------------------------------------------
# Never-fire parity + dead-slot equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(Arch))
def test_never_firing_frame_matches_fault_free(arch):
    sim = SimConfig().with_arch(arch)
    tr = _trace()
    clean = simulate(tr, sim)
    frame = faults.compile_faults(
        [faults.GatewayFault(start=T, chiplet=0, slot=0),
         faults.PcmStuckCell(start=T, chiplet=1, slot=1, mode="on"),
         faults.LossDrift(start=T, db_per_interval=1.0)], sim.cfg, T)
    faulted = simulate(faults.attach_faults(tr, frame), sim)
    for k in clean["summary"]:
        _close(faulted["summary"][k], clean["summary"][k],
               err_msg=f"{arch}: never-firing frame diverged on {k}")
    for k in ("latency", "power_mw", "g"):
        _close(faulted["records"][k], clean["records"][k],
               err_msg=f"{arch}: never-firing frame diverged on records"
                       f"[{k}]")


def test_dead_slots_equal_smaller_network():
    """Hard-failed slots contribute zero to EVERY reduction: pinned g=4
    with slots 2,3 dead on all chiplets == pinned g=2 fault-free."""
    from benchmarks.common import fixed_gateway_config

    tr = _trace(1)
    big = fixed_gateway_config(4)
    frame = faults.compile_faults(
        [faults.GatewayFault(start=0, chiplet=c, slot=s)
         for c in range(big.cfg.n_chiplets) for s in (2, 3)], big.cfg, T)
    hurt = simulate(faults.attach_faults(tr, frame), big)
    small = simulate(tr, fixed_gateway_config(2))
    for k in ("mean_latency", "mean_power_mw", "mean_energy",
              "total_reconfig_nj"):
        _close(hurt["summary"][k], small["summary"][k],
               err_msg=f"dead slots leaked into {k}")
    # The records expose both views: effective g collapses to the
    # survivors, the controller's desire stays at 4.
    assert np.all(np.asarray(hurt["records"]["g"]) == 2)
    assert np.all(np.asarray(hurt["records"]["g_desired"]) == 4)
    assert np.all(np.asarray(hurt["records"]["failed_slots"])
                  == 2 * big.cfg.n_chiplets)


def test_stuck_on_is_power_only():
    from benchmarks.common import fixed_gateway_config

    sim = fixed_gateway_config(2)
    tr = _trace(2)
    clean = simulate(tr, sim)["summary"]
    frame = faults.compile_faults(
        [faults.PcmStuckCell(start=0, chiplet=0, slot=3, mode="on")],
        sim.cfg, T)
    stuck = simulate(faults.attach_faults(tr, frame), sim)["summary"]
    _close(stuck["mean_latency"], clean["mean_latency"])
    _close(stuck["mean_gateways"], clean["mean_gateways"])
    assert float(stuck["mean_power_mw"]) > float(clean["mean_power_mw"])


def test_loss_drift_costs_power_monotonically():
    sim = SimConfig()
    tr = _trace(3)
    clean = float(simulate(tr, sim)["summary"]["mean_power_mw"])
    drifted = simulate(faults.attach_faults(tr, faults.compile_faults(
        [faults.LossDrift(start=0, db_per_interval=0.2, max_db=3.0)],
        sim.cfg, T)), sim)
    assert float(drifted["summary"]["mean_power_mw"]) > clean
    # Every interval pays for the extra loss (the laser-power term also
    # scales with traffic, so the per-interval delta is positive but not
    # strictly monotone), and a steeper ramp costs strictly more overall.
    delta = (np.asarray(drifted["records"]["power_mw"])
             - np.asarray(simulate(tr, sim)["records"]["power_mw"]))
    assert np.all(delta > 0.0), delta
    steeper = simulate(faults.attach_faults(tr, faults.compile_faults(
        [faults.LossDrift(start=0, db_per_interval=0.5, max_db=6.0)],
        sim.cfg, T)), sim)
    assert (float(steeper["summary"]["mean_power_mw"])
            > float(drifted["summary"]["mean_power_mw"]))


def test_link_flap_deterministic_and_kills_chiplet():
    sim = SimConfig()
    spec = faults.LinkFlap(start=0, chiplet=1, p_down=1.0, p_up=0.0)
    f1 = faults.compile_faults([spec], sim.cfg, T, seed=7)
    f2 = faults.compile_faults([spec], sim.cfg, T, seed=7)
    np.testing.assert_array_equal(f1["gw_ok"], f2["gw_ok"])
    # p_down=1, p_up=0: down from the first interval, whole chiplet dead.
    assert np.all(f1["gw_ok"][:, 1, :] == 0.0)
    assert np.all(f1["gw_ok"][:, 0, :] == 1.0)
    # A different seed draws a different chain for stochastic parameters.
    spec2 = faults.LinkFlap(start=0, chiplet=1, p_down=0.5, p_up=0.5)
    a = faults.compile_faults([spec2], sim.cfg, T, seed=0)
    b = faults.compile_faults([spec2], sim.cfg, T, seed=1)
    assert not np.array_equal(a["gw_ok"], b["gw_ok"])


# ---------------------------------------------------------------------------
# Engine integration: sweep axis, batching, streaming, executables
# ---------------------------------------------------------------------------

def test_sweep_faults_matches_one_trace_simulate():
    sim = SimConfig()
    tr = _trace(4)
    frames = [faults.no_faults(sim.cfg, T),
              faults.compile_faults([faults.GatewayFault(start=1, chiplet=0,
                                                         slot=0)],
                                    sim.cfg, T),
              faults.compile_faults([faults.LossDrift(start=2,
                                                      db_per_interval=0.3)],
                                    sim.cfg, T)]
    reset_engine_stats()
    sw = sweep_faults(tr, sim, frames)
    assert engine_stats()["simulate_traces"] == 1
    for i, fr in enumerate(frames):
        one = simulate(faults.attach_faults(tr, fr), sim)["summary"]
        for k in ("mean_latency", "mean_power_mw", "mean_energy"):
            _close(sw["summary"][k][i], one[k],
                   err_msg=f"fault lane {i} diverged on {k}")


def test_sweep_faults_zips_with_runtime_grids():
    sim = SimConfig()
    tr = _trace(4)
    frames = [faults.no_faults(sim.cfg, T)] * 2
    out = sweep_faults(tr, sim, frames, l_m=jnp.asarray([0.01, 0.03]))
    assert np.asarray(out["summary"]["mean_latency"]).shape == (2,)
    with pytest.raises(ValueError, match="lane-for-lane"):
        sweep_faults(tr, sim, frames, l_m=jnp.asarray([0.01, 0.02, 0.03]))


def test_sweep_faults_rejects_attached_trace_and_bad_horizon():
    sim = SimConfig()
    tr = _trace(4)
    fr = faults.no_faults(sim.cfg, T)
    with pytest.raises(ValueError, match="clean"):
        sweep_faults(faults.attach_faults(tr, fr), sim, [fr])
    with pytest.raises(ValueError, match="intervals"):
        sweep_faults(tr, sim, [faults.no_faults(sim.cfg, T + 1)])


def test_simulate_batch_with_fault_frames():
    sim = SimConfig()
    trs = [_trace(5), _trace(6)]
    frames = [faults.no_faults(sim.cfg, T),
              faults.compile_faults([faults.GatewayFault(start=0, chiplet=0,
                                                         slot=0)],
                                    sim.cfg, T)]
    batch = [faults.attach_faults(t, f) for t, f in zip(trs, frames)]
    out = simulate_batch(batch, sim)
    for i in range(2):
        _close(out["summary"]["mean_latency"][i],
               simulate(batch[i], sim)["summary"]["mean_latency"])
    with pytest.raises(ValueError, match="uniformly"):
        stack_traces([batch[0], trs[1]])


def test_partial_fault_frame_raises():
    sim = SimConfig()
    tr = dict(_trace(7), gw_ok=np.ones((T, sim.cfg.n_chiplets,
                                        sim.cfg.max_gateways_per_chiplet),
                                       np.float32))
    with pytest.raises(ValueError, match="missing"):
        simulate(tr, sim)


def test_attach_faults_validates():
    sim = SimConfig()
    tr = _trace(8)
    with pytest.raises(ValueError, match="intervals"):
        faults.attach_faults(tr, faults.no_faults(sim.cfg, T + 3))
    with pytest.raises(ValueError, match="missing"):
        faults.attach_faults(tr, {"gw_ok": np.ones((T, 4, 4))})
    attached = faults.attach_faults(tr, faults.no_faults(sim.cfg, T))
    stripped = faults.strip_faults(attached)
    assert set(faults.FAULT_KEYS).isdisjoint(stripped)
    assert set(traffic.TRACE_KEYS) <= set(stripped)


def test_faulted_session_chunks_match_one_shot():
    """pad/chunk/concat carry the fault arrays: streamed == one-shot."""
    sim = SimConfig()
    t_total = 24
    tr = _trace(9, t=t_total)
    frame = faults.compile_faults(
        [faults.GatewayFault(start=5, end=17, chiplet=0, slot=0),
         faults.LossDrift(start=8, db_per_interval=0.1)], sim.cfg, t_total)
    attached = faults.attach_faults(tr, frame)
    one = simulate(attached, sim)

    session = SimSession.init(sim)
    recs = [session.step_chunk(ch)["records"]
            for ch in traffic.chunk_trace(attached, 8)]
    cat = jax.tree.map(lambda *xs: jnp.concatenate(xs), *recs)
    for k in ("latency", "power_mw", "g", "failed_slots"):
        np.testing.assert_array_equal(
            np.asarray(cat[k]), np.asarray(one["records"][k]),
            err_msg=f"streamed faulted records[{k}] diverged")
    _close(session.summary()["mean_latency"],
           one["summary"]["mean_latency"])


def test_swap_placement_is_zero_recompile():
    sim = SimConfig()
    tr = _trace(10)
    session = SimSession.init(sim)
    chunks = list(traffic.chunk_trace(tr, 6))
    session.step_chunk(chunks[0])
    reset_engine_stats()
    session.swap_placement(((0, 0), (3, 3), (0, 3), (3, 0)))
    session.step_chunk(chunks[1])
    assert engine_stats()["simulate_traces"] == 0, \
        "live re-placement re-traced the chunk executable"
    assert session.placement == ((0, 0), (3, 3), (0, 3), (3, 0))


def test_faults_reject_padded_topology_paths():
    from repro.core.simulator import sweep_topology

    sim = SimConfig()
    tr = faults.attach_faults(_trace(11),
                              faults.no_faults(sim.cfg, T))
    with pytest.raises(ValueError, match="topology"):
        sweep_topology(tr, sim, n_chiplets=[4])


# ---------------------------------------------------------------------------
# Spec/compile semantics + the closed-loop environment pieces
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="start"):
        faults.GatewayFault(start=-1)
    with pytest.raises(ValueError, match="end"):
        faults.GatewayFault(start=5, end=3)
    with pytest.raises(ValueError, match="slot"):
        faults.compile_faults([faults.GatewayFault(slot=99)], n_intervals=4)
    with pytest.raises(ValueError, match="chiplet"):
        faults.compile_faults([faults.GatewayFault(chiplet=99)],
                              n_intervals=4)
    with pytest.raises(ValueError, match="mode"):
        faults.PcmStuckCell(mode="sideways")
    with pytest.raises(TypeError, match="FaultSpec"):
        faults.compile_faults(["not a spec"], n_intervals=4)
    assert hash(faults.GatewayFault(start=0)) is not None


def test_position_fault_heals_by_replacement():
    """A position-targeted fault is a no-op once no gateway sits there."""
    cfg = SimConfig().cfg
    placement = faults.normalize_placement(
        faults.resolve_gateway_positions(cfg), cfg)
    spec = faults.GatewayFault(start=0, chiplet=0, position=placement[0])
    biting = faults.compile_faults([spec], cfg, 4)
    assert np.any(biting["gw_ok"] == 0.0)
    moved = [(x, y) for (x, y) in [(1, 1), (2, 2), (1, 2), (2, 1)]]
    healed = faults.compile_faults([spec], cfg.with_placement(tuple(moved)),
                                   4)
    assert np.all(healed["gw_ok"] == 1.0)


def test_fault_injector_chunks_and_status_register():
    sim = SimConfig()
    cfg = sim.cfg
    placement = faults.normalize_placement(
        faults.resolve_gateway_positions(cfg), cfg)
    inj = faults.FaultInjector(
        [faults.GatewayFault(start=8, end=16, chiplet=0,
                             position=placement[0])], 24)
    full = inj.frame_for(cfg, 0, 24)
    for t0 in (0, 8, 16):
        part = inj.frame_for(cfg, t0, t0 + 8)
        np.testing.assert_array_equal(part["gw_ok"],
                                      full["gw_ok"][t0:t0 + 8])
    assert inj.failed_positions(4) == []
    assert inj.failed_positions(8) == [placement[0]]
    assert inj.failed_positions(16) == []
    with pytest.raises(ValueError, match="horizon"):
        inj.frame_for(cfg, 20, 30)
    tr = _trace(12, t=8)
    chunk = inj.inject(tr, cfg, 8)
    assert np.all(np.asarray(chunk["gw_ok"][:, 0, 0]) == 0.0)


def test_fault_injector_frame_cache_is_lru_bounded():
    """The placement-keyed frame cache is LRU with a hard bound: a serving
    loop that heals repeatedly (every heal = a new placement key) cannot
    grow it without bound, the least-recently-USED key is the one evicted,
    and an evicted placement recompiles to an identical frame."""
    cfg = SimConfig().cfg
    inj = faults.FaultInjector(
        [faults.GatewayFault(start=0, chiplet=0, position=(0, 0))], 8,
        cache_size=2)
    base = faults.normalize_placement(
        faults.resolve_gateway_positions(cfg), cfg)
    placements = [base, ((1, 1), (2, 2), (1, 2), (2, 1)),
                  ((0, 0), (3, 3), (0, 3), (3, 0))]
    cfgs = [cfg.with_placement(p) for p in placements]

    first = {k: np.asarray(v)
             for k, v in inj.frame_for(cfgs[0], 0, 8).items()}
    inj.frame_for(cfgs[1], 0, 8)
    assert len(inj._frames) == 2
    inj.frame_for(cfgs[0], 0, 8)          # touch: placements[0] is now MRU
    inj.frame_for(cfgs[2], 0, 8)          # evicts placements[1], not [0]
    assert len(inj._frames) == 2
    keys = list(inj._frames)
    assert faults.normalize_placement(placements[0], cfg) in keys
    assert faults.normalize_placement(placements[1], cfg) not in keys
    # The evicted placement recompiles bit-identically on re-request.
    again = inj.frame_for(cfgs[0], 0, 8)
    for k in first:
        np.testing.assert_array_equal(first[k], np.asarray(again[k]))
    with pytest.raises(ValueError, match="cache_size"):
        faults.FaultInjector([], 8, cache_size=0)


def test_placement_reconfig_cost():
    a = ((1, 0), (2, 3), (0, 2), (3, 1))
    b = ((1, 1), (2, 3), (0, 2), (3, 1))
    zero = faults.placement_reconfig_cost(a, a)
    assert zero == {"moved_gateways": 0, "pcm_nj": 0.0, "stall_cycles": 0}
    one = faults.placement_reconfig_cost(a, b)
    assert one["moved_gateways"] == 2          # site removed + site added
    assert one["pcm_nj"] > 0 and one["stall_cycles"] > 0


# ---------------------------------------------------------------------------
# noc_step kernel: time-varying valid_mask path
# ---------------------------------------------------------------------------

def _noc_problem(t=32, r=9, seed=0):
    rng = np.random.RandomState(seed)
    arr = jnp.asarray(rng.rand(t, r).astype(np.float32) * 0.5)
    nmat = np.zeros((r, r), np.float32)
    for i in range(r - 1):
        nmat[i, i + 1] = 1.0
    drain = np.zeros((r,), np.float32)
    drain[r - 1] = 2.0
    return arr, jnp.asarray(nmat), jnp.asarray(drain), \
        jnp.full((r,), 4.0, jnp.float32)


def test_kernel_tv_mask_all_ones_is_static_bitwise():
    from repro.kernels.noc_step.kernel import noc_run_pallas

    arr, nmat, drain, buf = _noc_problem()
    static = noc_run_pallas(arr, nmat, drain, buf, t_chunk=8,
                            interpret=True)
    tv = noc_run_pallas(arr, nmat, drain, buf,
                        valid_mask_t=jnp.ones(arr.shape, jnp.float32),
                        t_chunk=8, interpret=True)
    for a, b in zip(tv, static):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_tv_mask_matches_reference_and_kills_lane():
    from repro.kernels.noc_step.kernel import noc_run_pallas
    from repro.kernels.noc_step.ref import reference_noc_run

    arr, nmat, drain, buf = _noc_problem()
    tv = np.ones(arr.shape, np.float32)
    tv[10:, 3] = 0.0                        # lane 3 dies mid-run
    tv = jnp.asarray(tv)
    got = noc_run_pallas(arr, nmat, drain, buf, valid_mask_t=tv,
                         t_chunk=8, interpret=True)
    ref = reference_noc_run(arr, nmat, drain, buf, valid_mask_t=tv)
    for a, b in zip(got, ref):
        _close(a, b, atol=1e-6)
    # the dead lane is provably dead: zero final occupancy
    assert float(got[1][3]) == 0.0
    with pytest.raises(ValueError, match="valid_mask_t"):
        noc_run_pallas(arr, nmat, drain, buf,
                       valid_mask_t=jnp.ones((3, 3)), interpret=True)
