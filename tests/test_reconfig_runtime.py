"""reconfig_runtime edge-case coverage: width snapping + pytree chunking.

Satellite coverage for the Level-2 lane runtime: `nearest_compiled_width`
corner cases (lanes=0, exact-width hits, equidistant ties),
`chunk_pytree`/`merge_chunks` round-trips on ragged splits, and the lanes<1
guard.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.reconfig_runtime import (LANE_WIDTHS, chunk_pytree,
                                         laned_psum, merge_chunks,
                                         nearest_compiled_width)


# ---------------------------------------------------------------------------
# nearest_compiled_width
# ---------------------------------------------------------------------------

def test_nearest_width_exact_hits():
    for w in LANE_WIDTHS:
        assert nearest_compiled_width(w) == w


def test_nearest_width_lanes_zero_snaps_to_minimum():
    assert nearest_compiled_width(0) == min(LANE_WIDTHS)


def test_nearest_width_tie_breaks_to_narrower():
    # 3 is equidistant from 2 and 4 — the (abs, width) key picks 2.
    assert nearest_compiled_width(3) == 2
    assert nearest_compiled_width(3, widths=(1, 2, 4, 8)) == 2


def test_nearest_width_above_maximum_clamps():
    assert nearest_compiled_width(100) == max(LANE_WIDTHS)
    assert nearest_compiled_width(5, widths=(2, 8)) == 2  # tie -> narrower


# ---------------------------------------------------------------------------
# chunk_pytree / merge_chunks
# ---------------------------------------------------------------------------

def _tree(sizes):
    return {f"p{i}": jnp.arange(s, dtype=jnp.float32)
            for i, s in enumerate(sizes)}


def test_chunk_pytree_rejects_zero_lanes():
    with pytest.raises(ValueError, match="lanes >= 1"):
        chunk_pytree(_tree([4, 2]), 0)
    with pytest.raises(ValueError, match="lanes >= 1"):
        chunk_pytree(_tree([4]), -1)


def test_chunk_single_lane_round_trip():
    tree = _tree([5, 3, 7])
    bins = chunk_pytree(tree, 1)
    assert len(bins) == 1 and len(bins[0]) == 3
    merged = merge_chunks(bins, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(merged[k]),
                                      np.asarray(tree[k]))


def test_ragged_final_chunk_round_trip():
    # 5 leaves into 3 lanes: the last bins are ragged, every leaf must come
    # back exactly once in its original tree position.
    tree = _tree([11, 7, 5, 3, 2])
    bins = chunk_pytree(tree, 3)
    assert len(bins) == 3
    assert sum(len(b) for b in bins) == 5
    seen = [i for b in bins for i in b]
    assert sorted(seen) == list(range(5)), "leaf dropped or duplicated"
    merged = merge_chunks(bins, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(merged[k]),
                                      np.asarray(tree[k]))


def test_more_lanes_than_leaves_round_trip():
    tree = _tree([4, 2])
    bins = chunk_pytree(tree, 4)
    assert len(bins) == 4
    assert sum(bool(b) for b in bins) == 2      # two empty lanes ride along
    merged = merge_chunks(bins, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(merged[k]),
                                      np.asarray(tree[k]))


def test_chunking_balances_bytes():
    # Largest-first binning: no lane should exceed half the total bytes
    # for this size profile.
    tree = _tree([8, 8, 8, 8])
    bins = chunk_pytree(tree, 2)
    loads = [sum(v.size for v in b.values()) for b in bins]
    assert loads[0] == loads[1] == 16


def test_laned_psum_identity_outside_shard_map():
    tree = _tree([6, 3])
    out = laned_psum(tree, None, 4)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


# ---------------------------------------------------------------------------
# lane_energy_report: cumulative reconfiguration audit trail
# ---------------------------------------------------------------------------

def test_lane_energy_report_cumulative_audit():
    from repro.core.constants import PHOTONIC_POWER
    from repro.core.reconfig_runtime import LaneConfig, lane_energy_report

    hist = jnp.asarray([4, 4, 2, 2, 1, 4, 4], jnp.int32)
    rep = lane_energy_report(hist, LaneConfig())
    # 3 width changes: 4->2, 2->1, 1->4.
    assert float(rep["switch_count"]) == 3.0
    np.testing.assert_array_equal(
        np.asarray(rep["cum_switches"]), [0, 0, 1, 1, 2, 3, 3])
    # Totals are consistent: cum trails end at the scalar aggregates.
    assert float(rep["cum_switches"][-1]) == float(rep["switch_count"])
    np.testing.assert_allclose(
        np.asarray(rep["cum_pcm_nj"]),
        np.asarray(rep["cum_switches"]) * PHOTONIC_POWER.pcmc_reconfig_nj)
    np.testing.assert_allclose(float(rep["cum_pcm_nj"][-1]),
                               float(rep["reconfig_nj"]))


def test_lane_energy_report_constant_schedule_is_free():
    from repro.core.reconfig_runtime import LaneConfig, lane_energy_report

    rep = lane_energy_report(jnp.full((5,), 2, jnp.int32), LaneConfig())
    assert float(rep["switch_count"]) == 0.0
    assert float(rep["reconfig_nj"]) == 0.0
    np.testing.assert_array_equal(np.asarray(rep["cum_pcm_nj"]),
                                  np.zeros(5, np.float32))
