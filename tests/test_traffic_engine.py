"""Workload-polymorphic engine tests: ragged-T batching, workload sweeps,
streaming sessions, and the kernel-level time mask.

The load-bearing invariants of PR 4:

  * time-masking — a tail-padded trace simulates identically to its
    unpadded original for EVERY architecture (1e-6), the time-axis
    analogue of the PR 2 chiplet-masking invariant;
  * one executable — a K-workload sweep / ragged batch is ONE scan-body
    trace, and warm re-calls re-trace nothing;
  * streaming — a chunked `SimSession` run bit-matches one-shot
    `simulate` records and reproduces its summary.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.constants import NETWORK
from repro.core.simulator import (Arch, SimConfig, SimSession, engine_stats,
                                  reset_engine_stats, simulate,
                                  simulate_batch, simulate_stream,
                                  stack_traces, sweep_topology,
                                  sweep_workload, topology_point_config)
from repro.kernels.noc_step.kernel import noc_run_pallas
from repro.kernels.noc_step.ops import build_topology
from repro.kernels.noc_step.ref import reference_noc_run

SUMMARY_KEYS = ("mean_latency", "mean_power_mw", "mean_energy",
                "mean_gateways", "mean_wavelengths", "saturated_frac",
                "total_reconfig_nj")


@pytest.fixture(scope="module")
def ragged_traces():
    apps = [("dedup", 21), ("canneal", 14), ("facesim", 9)]
    return [traffic.generate_trace(a, t, jax.random.PRNGKey(i))
            for i, (a, t) in enumerate(apps)]


_chunks = traffic.chunk_trace


# ---------------------------------------------------------------------------
# Ragged-T batching (the time-masking invariant)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(Arch))
def test_padded_lane_matches_unpadded_per_arch(ragged_traces, arch):
    """Padded lane k == unpadded simulate(trace k) at 1e-6, every arch."""
    sim = SimConfig().with_arch(arch)
    out = simulate_batch(ragged_traces, sim)
    for i, tr in enumerate(ragged_traces):
        single = simulate(tr, sim)
        for k in SUMMARY_KEYS:
            np.testing.assert_allclose(
                np.asarray(out["summary"][k][i]),
                np.asarray(single["summary"][k]), rtol=1e-6, atol=1e-6,
                err_msg=f"{arch} summary[{k}] lane {i}")
        t = tr["ext_load"].shape[0]
        lat = np.asarray(out["records"]["latency"][i])
        np.testing.assert_allclose(
            lat[:t], np.asarray(single["records"]["latency"]),
            rtol=1e-6, atol=1e-6, err_msg=f"{arch} records lane {i}")
        # masked tail intervals record exactly zero everywhere
        for key in ("latency", "power_mw", "energy", "g", "wavelengths",
                    "reconfig_nj"):
            tail = np.asarray(out["records"][key][i], np.float32)[t:]
            assert np.all(tail == 0), \
                f"{arch} records[{key}] lane {i} nonzero past T={t}"
        assert not np.any(np.asarray(out["records"]["saturated"][i])[t:])


def test_ragged_batch_is_one_compile(ragged_traces):
    base = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                               prowaves_rho_lo=0.317)   # test-owned config
    reset_engine_stats()
    simulate_batch(ragged_traces, base)
    assert engine_stats()["simulate_traces"] == 1
    # warm re-call with different ragged lengths but same maxima: no retrace
    alt = [traffic.generate_trace("swaptions", t, jax.random.PRNGKey(9))
           for t in (21, 13, 7)]
    simulate_batch(alt, base)
    assert engine_stats()["simulate_traces"] == 1


def test_stack_traces_error_paths(ragged_traces):
    with pytest.raises(ValueError, match=r"mixed lengths T=\[21, 14, 9\]"):
        stack_traces(ragged_traces)
    with pytest.raises(ValueError, match="pad=True"):
        stack_traces(ragged_traces)
    with pytest.raises(ValueError, match="at least one"):
        stack_traces([])
    with pytest.raises(TypeError, match="trace dict"):
        stack_traces([jnp.zeros((4, 4))])
    wide = traffic.generate_trace(
        "dedup", 9, jax.random.PRNGKey(0), NETWORK.with_topology(n_chiplets=6))
    with pytest.raises(ValueError, match="chiplet counts"):
        stack_traces([ragged_traces[2], wide], pad=True)
    batch = stack_traces(ragged_traces, pad=True)
    assert batch["ext_load"].shape == (3, 21, NETWORK.n_chiplets)
    assert batch["t_mask"].shape == (3, 21)


def test_padded_single_trace_through_simulate():
    """`simulate` itself honors a trace-carried t_mask."""
    sim = SimConfig().with_arch(Arch.RESIPI)
    tr = traffic.generate_trace("dedup", 12, jax.random.PRNGKey(3))
    padded = traffic.pad_trace(tr, 20)
    a = simulate(tr, sim)["summary"]
    b = simulate(padded, sim)["summary"]
    for k in SUMMARY_KEYS:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6, atol=1e-6, err_msg=k)
    assert float(b["valid_intervals"]) == 12.0


def test_ragged_zips_with_topology_sweep():
    """t_mask rides the padded-topology executable too."""
    cfg = NETWORK.with_topology(n_chiplets=9)
    tr = traffic.generate_trace("dedup", 11, jax.random.PRNGKey(2), cfg)
    padded = traffic.pad_trace(tr, 16)
    base = SimConfig().with_arch(Arch.RESIPI)
    out = sweep_topology(padded, base, n_chiplets=[4, 9])
    for i, c in enumerate([4, 9]):
        single = simulate(traffic.slice_trace(tr, c),
                          topology_point_config(base, n_chiplets=c))
        np.testing.assert_allclose(
            np.asarray(out["summary"]["mean_latency"][i]),
            np.asarray(single["summary"]["mean_latency"]),
            rtol=1e-5, atol=1e-5, err_msg=f"topology point {i}")


# ---------------------------------------------------------------------------
# Workload sweeps
# ---------------------------------------------------------------------------

def test_sweep_workload_parity_and_one_compile():
    base = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                               prowaves_rho_lo=0.323)   # test-owned config
    specs = [traffic.ParsecSpec(app="dedup", n_intervals=12),
             traffic.UniformSpec(n_intervals=18),
             traffic.HotspotSpec(n_intervals=15),
             traffic.BurstySpec(n_intervals=10)]
    reset_engine_stats()
    out = sweep_workload(specs, base, seed=5)
    assert engine_stats()["simulate_traces"] == 1
    assert out["summary"]["mean_latency"].shape == (4,)
    keys = jax.random.split(jax.random.PRNGKey(5), len(specs))
    for i, (sp, ky) in enumerate(zip(specs, keys)):
        single = simulate(traffic.generate(sp, ky), base)
        for k in SUMMARY_KEYS:
            np.testing.assert_allclose(
                np.asarray(out["summary"][k][i]),
                np.asarray(single["summary"][k]), rtol=1e-6, atol=1e-6,
                err_msg=f"summary[{k}] workload {sp.name}")
    # warm re-call with fresh seed: same shapes, zero re-traces
    before = engine_stats()["simulate_traces"]
    sweep_workload(specs, base, seed=6)
    assert engine_stats()["simulate_traces"] == before


def test_sweep_workload_accepts_app_names_and_runtime_grids():
    base = SimConfig().with_arch(Arch.RESIPI)
    lms = [0.008, 0.02]
    out = sweep_workload(["dedup", "canneal"], base, seed=1,
                         l_m=jnp.asarray(lms))
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    for i, (app, lm) in enumerate(zip(["dedup", "canneal"], lms)):
        pinned = dataclasses.replace(base, ctl=dataclasses.replace(
            base.ctl, l_m=lm))
        single = simulate(traffic.generate(
            traffic.ParsecSpec(app=app), keys[i]), pinned)
        np.testing.assert_allclose(
            np.asarray(out["summary"]["mean_latency"][i]),
            np.asarray(single["summary"]["mean_latency"]),
            rtol=1e-5, atol=1e-5, err_msg=f"workload {app} l_m={lm}")


def test_sweep_workload_zips_with_topology():
    base = SimConfig().with_arch(Arch.RESIPI)
    specs = [traffic.UniformSpec(n_intervals=8),
             traffic.ParsecSpec(app="dedup", n_intervals=12)]
    cs = [4, 9]
    out = sweep_workload(specs, base, seed=2, n_chiplets=cs)
    gen_cfg = base.cfg.with_topology(n_chiplets=max(cs))
    keys = jax.random.split(jax.random.PRNGKey(2), 2)
    for i, (sp, c) in enumerate(zip(specs, cs)):
        tr = traffic.generate(sp, keys[i], gen_cfg)
        single = simulate(traffic.slice_trace(tr, c),
                          topology_point_config(base, n_chiplets=c))
        np.testing.assert_allclose(
            np.asarray(out["summary"]["mean_latency"][i]),
            np.asarray(single["summary"]["mean_latency"]),
            rtol=1e-5, atol=1e-5, err_msg=f"{sp.name} @ {c} chiplets")


def test_sweep_workload_validation():
    base = SimConfig().with_arch(Arch.RESIPI)
    with pytest.raises(ValueError, match="at least one"):
        sweep_workload([], base)
    with pytest.raises(ValueError, match="length 3 but 2"):
        sweep_workload(["dedup", "canneal"], base,
                       l_m=jnp.asarray([0.01, 0.02, 0.03]))
    with pytest.raises(ValueError, match="non-sweepable"):
        sweep_workload(["dedup"], base, bogus=jnp.asarray([1.0]))
    with pytest.raises(ValueError, match="2 keys for 1"):
        sweep_workload(["dedup"], base,
                       keys=jax.random.split(jax.random.PRNGKey(0), 2))
    # a bare scalar grid value gets a clear message, not a len() TypeError
    with pytest.raises(ValueError, match="1-D grid"):
        sweep_workload(["dedup"], base, l_m=0.015)
    with pytest.raises(ValueError, match="1-D grid"):
        sweep_topology(traffic.generate_trace(
            "dedup", 6, jax.random.PRNGKey(0)), base, n_chiplets=4)


def test_interior_mask_gap_freezes_state():
    """A mask-interior gap resumes exactly where the last valid interval
    left off: the controller must not react to the padded idle epochs
    (the frozen-carry contract, matching the noc_step kernel)."""
    for arch in (Arch.RESIPI, Arch.PROWAVES):
        sim = SimConfig().with_arch(arch)
        a = traffic.generate_trace("blackscholes", 9, jax.random.PRNGKey(0))
        b = traffic.generate_trace("facesim", 8, jax.random.PRNGKey(1))
        gapped = traffic.concat_traces([traffic.pad_trace(a, 14), b])
        plain = traffic.concat_traces([a, b])
        out_g = simulate(gapped, sim)
        out_p = simulate(plain, sim)
        for k in SUMMARY_KEYS:
            np.testing.assert_allclose(
                np.asarray(out_g["summary"][k]),
                np.asarray(out_p["summary"][k]), rtol=1e-6, atol=1e-6,
                err_msg=f"{arch} summary[{k}] with interior mask gap")
        # the b-segment records line up despite the 5 masked gap intervals
        np.testing.assert_allclose(
            np.asarray(out_g["records"]["latency"])[14:],
            np.asarray(out_p["records"]["latency"])[9:],
            rtol=1e-6, atol=1e-6, err_msg=f"{arch} post-gap records")


def test_midstream_padded_chunk_matches_oneshot():
    """Padding a NON-final chunk is exact too: the frozen carry lets a
    stream keep going after a padded chunk."""
    sim = SimConfig().with_arch(Arch.RESIPI)
    tr = traffic.generate_trace("canneal", 16, jax.random.PRNGKey(2))
    chunks = list(traffic.chunk_trace(tr, 8))
    session = SimSession.init(sim)
    session.step_chunk(traffic.pad_trace(chunks[0], 12))   # mid-stream pad
    session.step_chunk(chunks[1])
    one = simulate(tr, sim)["summary"]
    for k in SUMMARY_KEYS:
        np.testing.assert_allclose(
            np.asarray(session.summary()[k]), np.asarray(one[k]),
            rtol=1e-6, atol=1e-6, err_msg=k)
    assert session.intervals_seen == 16


# ---------------------------------------------------------------------------
# Streaming sessions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(Arch))
def test_session_bitmatches_oneshot_per_arch(arch):
    """Chunked records == one-shot records, bitwise, for every arch."""
    sim = SimConfig().with_arch(arch)
    tr = traffic.generate_trace("streamcluster", 24, jax.random.PRNGKey(4))
    one = simulate(tr, sim)
    session = SimSession.init(sim)
    chunk_recs = [session.step_chunk(ch)["records"]
                  for ch in _chunks(tr, 8)]
    cat = jax.tree.map(lambda *xs: jnp.concatenate(xs), *chunk_recs)
    for k in one["records"]:
        np.testing.assert_array_equal(
            np.asarray(cat[k]), np.asarray(one["records"][k]),
            err_msg=f"{arch} records[{k}] diverged across chunk boundary")
    assert session.intervals_seen == 24
    for k in SUMMARY_KEYS:
        np.testing.assert_allclose(
            np.asarray(session.summary()[k]),
            np.asarray(one["summary"][k]), rtol=1e-6, atol=1e-6,
            err_msg=f"{arch} summary[{k}]")


def test_session_steady_chunks_share_one_compile():
    sim = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                              prowaves_rho_lo=0.329)    # test-owned config
    tr = traffic.generate_trace("dedup", 40, jax.random.PRNGKey(6))
    session = SimSession.init(sim)
    reset_engine_stats()
    for ch in _chunks(tr, 10):
        session.step_chunk(ch)
    assert engine_stats()["simulate_traces"] == 1, \
        "equal-shape chunks must share one chunk executable"


def test_session_final_partial_chunk_via_padding():
    """A padded final chunk reuses the steady executable and stays exact."""
    sim = SimConfig().with_arch(Arch.RESIPI)
    tr = traffic.generate_trace("canneal", 22, jax.random.PRNGKey(8))
    one = simulate(tr, sim)["summary"]
    session = SimSession.init(sim)
    for ch in _chunks(tr, 8):                 # 8, 8, then ragged 6
        t = ch["ext_load"].shape[0]
        session.step_chunk(ch if t == 8 else traffic.pad_trace(ch, 8))
    for k in SUMMARY_KEYS:
        np.testing.assert_allclose(
            np.asarray(session.summary()[k]), np.asarray(one[k]),
            rtol=1e-6, atol=1e-6, err_msg=k)
    assert session.intervals_seen == 22


def test_simulate_stream_and_errors():
    sim = SimConfig().with_arch(Arch.RESIPI)
    tr = traffic.generate_trace("dedup", 16, jax.random.PRNGKey(1))
    out = simulate_stream(_chunks(tr, 4), sim)
    assert out["chunks"] == 4
    np.testing.assert_allclose(
        np.asarray(out["summary"]["mean_latency"]),
        np.asarray(simulate(tr, sim)["summary"]["mean_latency"]),
        rtol=1e-6)
    with pytest.raises(ValueError, match="empty chunk iterable"):
        simulate_stream([], sim)
    session = SimSession.init(sim)
    with pytest.raises(ValueError, match="before any step_chunk"):
        session.summary()
    with pytest.raises(ValueError, match="unbatched"):
        session.step_chunk(stack_traces([tr]))


# ---------------------------------------------------------------------------
# Kernel-level time mask
# ---------------------------------------------------------------------------

def test_noc_kernel_t_mask_freezes_tail():
    """Masked tail cycles == a shorter run, and they add zero residency."""
    nm, drain, buf, _ = build_topology(2, 4)
    n = nm.shape[0]
    arr = (jax.random.uniform(jax.random.PRNGKey(5), (192, n)) < 0.04
           ).astype(jnp.float32) * 8
    tm = (jnp.arange(192) < 100).astype(jnp.float32)
    rk, ok, dk = noc_run_pallas(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf),
        t_mask=tm, t_chunk=64, interpret=True)
    rr, orr, dr = reference_noc_run(
        arr[:100], jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf))
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ok), np.asarray(orr),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                               rtol=1e-5, atol=1e-4)
    # ref with the same mask agrees with the kernel
    r2, o2, d2 = reference_noc_run(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf),
        t_mask=tm)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(r2),
                               rtol=1e-5, atol=1e-4)


def test_noc_kernel_auto_time_padding():
    """T no longer needs to divide t_chunk: the tail pads as dead cycles."""
    nm, drain, buf, _ = build_topology(3, 4)
    n = nm.shape[0]
    arr = (jax.random.uniform(jax.random.PRNGKey(9), (100, n)) < 0.05
           ).astype(jnp.float32) * 8
    rk, ok, dk = noc_run_pallas(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf),
        t_chunk=64, interpret=True)
    rr, orr, dr = reference_noc_run(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf))
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                               rtol=1e-5, atol=1e-4)
