"""End-to-end system tests: training convergence, serving, the ReSiPI
controller in the loop, and the paper pipeline (traffic -> simulate ->
claims) — everything wired together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import get_model
from repro.train.train_step import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ["stablelm-3b", "mamba2-130m"])
def test_training_reduces_loss(arch):
    """30 steps on structured synthetic data must visibly reduce loss."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=64))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, opt_overrides={"lr": 3e-3, "total_steps": 40}),
        donate_argnums=(0,))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.host_slice(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_grad_accumulation_matches_single_batch():
    """accum=2 on batch 8 == accum=1 on the same batch (same grads)."""
    cfg = get_smoke_config("stablelm-3b")
    model = get_model(cfg)
    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=32))
    batch = {k: jnp.asarray(v) for k, v in data.host_slice(0).items()}
    s1 = init_train_state(model, jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = jax.jit(make_train_step(model))
    step2 = jax.jit(make_train_step(model, accum=2))
    n1, m1 = step1(s1, batch)
    n2, m2 = step2(s2, batch)
    np.testing.assert_allclose(
        np.asarray(n1["params"]["ln_f"]["scale"]),
        np.asarray(n2["params"]["ln_f"]["scale"]), atol=2e-4, rtol=2e-4)


def test_serving_engine_end_to_end():
    """Session server over the interposer simulator: a multi-tenant mix
    admits, serves to completion on shared lanes, and every completed
    session bit-matches its standalone SimSession replay."""
    from repro.core import traffic
    from repro.core.simulator import Arch, SimConfig
    from repro.serve.engine import SessionServer, replay_standalone
    from repro.serve.policies import ServerPolicy
    from repro.serve.scheduler import SessionRequest

    sim = SimConfig().with_arch(Arch.RESIPI)
    server = SessionServer(sim, ServerPolicy(lanes=3, chunk_intervals=6,
                                             queue_capacity=8))
    for i in range(5):
        tr = traffic.generate_trace("dedup", 7 + 3 * i,
                                    jax.random.PRNGKey(i))
        out = server.submit(SessionRequest(trace=tr, priority=i % 3))
        assert out["signal"] in ("accept", "throttle")
    server.drain()
    assert len(server.completed) == 5
    for sess in server.completed:
        ref = replay_standalone(sim, sess)
        mine = sess.summary()
        for k in ("mean_latency", "mean_power_mw", "mean_energy",
                  "valid_intervals"):
            assert float(ref[k]) == mine[k], (sess.id, k)


def test_serving_deterministic():
    """Two identical serve runs produce identical session summaries
    (admission order, packing, and the compiled tick are all
    deterministic)."""
    from repro.core import traffic
    from repro.core.simulator import Arch, SimConfig
    from repro.serve.engine import SessionServer
    from repro.serve.policies import ServerPolicy

    sim = SimConfig().with_arch(Arch.RESIPI)

    def one_run():
        server = SessionServer(sim, ServerPolicy(lanes=2, chunk_intervals=5,
                                                 queue_capacity=4))
        for i in range(4):
            tr = traffic.generate_trace("canneal", 9, jax.random.PRNGKey(i))
            server.submit(tr)
        server.drain()
        return [{k: v for k, v in s.summary().items() if k != "session_id"}
                for s in server.completed]

    assert one_run() == one_run()


def test_paper_pipeline_end_to_end():
    """traffic -> 4-arch simulation -> the three headline claims hold."""
    from repro.core import traffic
    from repro.core.simulator import simulate_all_archs
    tr = traffic.generate_trace("streamcluster", 30, jax.random.PRNGKey(0))
    out = simulate_all_archs(tr)
    assert out["resipi"]["mean_latency"] < out["prowaves"]["mean_latency"]
    assert out["resipi"]["mean_energy"] < out["prowaves"]["mean_energy"]
    assert out["resipi"]["mean_energy"] < out["resipi_all"]["mean_energy"]


def test_lane_controller_in_training_loop():
    """Level-2 integration: the train driver's lane metering adapts."""
    from repro.core import reconfig_runtime as lanes
    cfg = lanes.LaneConfig(lane_bytes_per_step=1e5)
    st_ = lanes.LaneState.init(cfg)
    model = get_model(get_smoke_config("stablelm-3b"))
    state = init_train_state(model, jax.random.PRNGKey(0))
    data = SyntheticLM(model.cfg, DataConfig(global_batch=4, seq_len=32))
    step = jax.jit(make_train_step(model))
    widths = []
    for i in range(9):
        batch = {k: jnp.asarray(v) for k, v in data.host_slice(i).items()}
        state, metrics = step(state, batch)
        st_ = lanes.meter_step(st_, metrics["collective_bytes"])
        if (i + 1) % 3 == 0:
            st_, rec = lanes.epoch_update(st_, cfg)
            widths.append(int(rec["lanes_after"]))
    assert len(widths) == 3
    assert all(1 <= w <= 4 for w in widths)
