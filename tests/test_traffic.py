"""Traffic-subsystem tests: spec hierarchy, generator properties, transforms.

Property-based (hypothesis, with the offline fallback shim): every generator
must produce non-negative loads, calibrate its sample mean to the spec's
analytic mean within sampling tolerance, keep ext_frac in (0, 1], reproduce
bit-identically from the same seed, and match its eager path under jit.
The transform satellites (validated slice_trace, load-weighted
concat_traces, clear stack/pad errors) are pinned here too.
"""
try:                                     # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.constants import NETWORK
from repro.core.traffic import (ALL_SYNTHETIC_SPECS, BurstySpec, HotspotSpec,
                                ParsecSpec, PermutationSpec, UniformSpec,
                                as_spec, expected_mean_ext_load, generate,
                                permutation_destinations)

CFG9 = NETWORK.with_topology(n_chiplets=9)


def _spec_of(kind: str, mean_load: float, n_intervals: int, aux: float):
    """Build one spec of each family from drawn parameters."""
    if kind == "uniform":
        return UniformSpec(mean_load=mean_load, cv=aux,
                           n_intervals=n_intervals)
    if kind == "hotspot":
        return HotspotSpec(mean_load=mean_load, hotspot_frac=0.3 + 0.5 * aux,
                           n_hotspots=1 + int(aux > 0.5),
                           n_intervals=n_intervals)
    if kind == "bursty":
        return BurstySpec(mean_load=mean_load, p_on=0.2 + 0.6 * aux,
                          p_off=0.8 - 0.6 * aux, n_intervals=n_intervals)
    if kind == "parsec":
        apps = traffic.APP_NAMES
        return ParsecSpec(app=apps[int(aux * (len(apps) - 1))],
                          n_intervals=n_intervals)
    return PermutationSpec(
        pattern=traffic.PERMUTATION_PATTERNS[
            int(aux * (len(traffic.PERMUTATION_PATTERNS) - 1))],
        mean_load=mean_load, n_intervals=n_intervals)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["uniform", "hotspot", "bursty", "permutation",
                        "parsec"]),
       st.floats(min_value=0.005, max_value=0.05),
       st.integers(min_value=8, max_value=48),
       st.floats(min_value=0.0, max_value=1.0),
       st.integers(min_value=0, max_value=1 << 16))
def test_generator_properties(kind, mean_load, n_intervals, aux, seed):
    spec = _spec_of(kind, mean_load, n_intervals, aux)
    key = jax.random.PRNGKey(seed)
    tr = generate(spec, key, CFG9)

    ext = np.asarray(tr["ext_load"])
    assert ext.shape == (spec.n_intervals, CFG9.n_chiplets)
    assert np.all(ext >= 0), f"{spec} produced negative ext load"
    assert np.all(np.asarray(tr["int_load"]) >= 0)
    assert np.all(np.asarray(tr["mem_load"]) >= 0)
    assert np.all(np.isfinite(ext))

    frac = float(tr["ext_frac"])
    assert 0.0 < frac <= 1.0, f"{spec} ext_frac {frac} outside (0, 1]"

    # Seed reproducibility: same key -> bit-identical trace.
    tr2 = generate(spec, key, CFG9)
    np.testing.assert_array_equal(ext, np.asarray(tr2["ext_load"]))

    # jit-generation parity with the eager path.
    eager = generate(spec, key, CFG9, jit=False)
    np.testing.assert_allclose(ext, np.asarray(eager["ext_load"]),
                               rtol=1e-5, atol=1e-8)


def test_mean_load_calibration():
    """Sample mean of ext_load lands near the analytic calibration target.

    Long traces keep the sampling error small: tolerance is 15% for the
    i.i.d. generators and 35% for bursty (autocorrelated duty cycle).
    """
    specs = [UniformSpec(mean_load=0.03, n_intervals=256),
             HotspotSpec(mean_load=0.03, n_intervals=256),
             PermutationSpec(pattern="transpose", mean_load=0.03,
                             n_intervals=256),
             PermutationSpec(pattern="tornado", mean_load=0.03,
                             n_intervals=256),
             BurstySpec(mean_load=0.03, n_intervals=512)]
    for i, spec in enumerate(specs):
        tr = generate(spec, jax.random.PRNGKey(100 + i), CFG9)
        got = float(np.mean(np.asarray(tr["ext_load"])))
        want = expected_mean_ext_load(spec, CFG9)
        tol = 0.35 if isinstance(spec, BurstySpec) else 0.15
        assert abs(got - want) <= tol * want, \
            f"{spec.name}: sample mean {got:.5f} vs calibrated {want:.5f}"


def test_permutation_self_pairs_divert_to_intra():
    """Transpose diagonal chiplets inject zero ext (their load is intra)."""
    dst = permutation_destinations("transpose", 9)
    self_paired = np.flatnonzero(dst == np.arange(9))
    assert self_paired.tolist() == [0, 4, 8]      # 3x3 grid diagonal
    tr = generate(PermutationSpec(pattern="transpose", n_intervals=16),
                  jax.random.PRNGKey(0), CFG9)
    ext = np.asarray(tr["ext_load"])
    assert np.all(ext[:, self_paired] == 0)
    others = [i for i in range(9) if i not in self_paired]
    assert np.all(ext[:, others] > 0)
    assert np.all(np.asarray(tr["int_load"])[:, self_paired] > 0)
    # tornado/neighbor have no self pairs on 9 chiplets
    for pattern in ("tornado", "neighbor"):
        assert not np.any(permutation_destinations(pattern, 9)
                          == np.arange(9))


def test_bursty_is_actually_bursty():
    """The on/off chain produces zero-load intervals and on-load bursts."""
    spec = BurstySpec(mean_load=0.02, p_on=0.2, p_off=0.3, n_intervals=128)
    tr = generate(spec, jax.random.PRNGKey(7), CFG9)
    ext = np.asarray(tr["ext_load"])
    off_frac = np.mean(ext == 0)
    assert 0.2 < off_frac < 0.9, f"off fraction {off_frac} not bursty"


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown PARSEC app"):
        ParsecSpec(app="nosuchapp")
    with pytest.raises(ValueError, match="mean_load"):
        UniformSpec(mean_load=0.0)
    with pytest.raises(ValueError, match="ext_frac"):
        UniformSpec(ext_frac=1.5)
    with pytest.raises(ValueError, match="n_intervals"):
        UniformSpec(n_intervals=0)
    with pytest.raises(ValueError, match="pattern"):
        PermutationSpec(pattern="zigzag")
    with pytest.raises(ValueError, match="p_on"):
        BurstySpec(p_on=0.0)
    with pytest.raises(ValueError, match="hotspot_frac"):
        HotspotSpec(hotspot_frac=1.0)
    with pytest.raises(TypeError, match="TrafficSpec"):
        as_spec(42)


def test_as_spec_coercion():
    s = as_spec("dedup", n_intervals=17)
    assert isinstance(s, ParsecSpec) and s.n_intervals == 17
    assert as_spec(s) is s


def test_specs_are_hashable_static_keys():
    """Specs must work as jit static args / cache keys (frozen + hashable)."""
    a = UniformSpec(mean_load=0.02)
    b = UniformSpec(mean_load=0.02)
    assert hash(a) == hash(b) and a == b
    assert len({s for s in ALL_SYNTHETIC_SPECS}) == len(ALL_SYNTHETIC_SPECS)


# ---------------------------------------------------------------------------
# Transforms (the satellite fixes)
# ---------------------------------------------------------------------------

def test_slice_trace_validates_inputs():
    with pytest.raises(TypeError, match="trace dict"):
        traffic.slice_trace([1, 2, 3], 2)
    with pytest.raises(ValueError, match="missing.*mem_load"):
        traffic.slice_trace({"ext_load": jnp.zeros((4, 4)),
                             "int_load": jnp.zeros((4, 4)),
                             "ext_frac": 0.4}, 2)
    tr = traffic.generate_trace("dedup", 8, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chiplets"):
        traffic.slice_trace(tr, 99)
    sl = traffic.slice_trace(tr, 2)
    assert sl["ext_load"].shape == (8, 2)


def test_concat_traces_load_weighted_ext_frac():
    """ext_frac is weighted by each segment's total ext load, so a
    near-idle segment cannot drag the composite fraction to its value."""
    heavy = traffic.generate_trace("blackscholes", 20, jax.random.PRNGKey(0))
    light = traffic.generate_trace("facesim", 20, jax.random.PRNGKey(1))
    out = traffic.concat_traces([heavy, light])
    f_heavy = float(heavy["ext_frac"])      # 0.40
    f_light = float(light["ext_frac"])      # 0.25
    f = float(out["ext_frac"])
    unweighted = 0.5 * (f_heavy + f_light)
    w_h = float(jnp.sum(heavy["ext_load"]))
    w_l = float(jnp.sum(light["ext_load"]))
    expected = (f_heavy * w_h + f_light * w_l) / (w_h + w_l)
    np.testing.assert_allclose(f, expected, rtol=1e-5)
    # blackscholes dominates the load, so the weighted frac sits close to
    # its fraction — and strictly above the old unweighted mean.
    assert f > unweighted
    assert out["ext_load"].shape[0] == 40
    assert out["app"] == "blackscholes+facesim"


def test_concat_traces_carries_unknown_keys():
    a = traffic.generate_trace("dedup", 6, jax.random.PRNGKey(0))
    b = traffic.generate_trace("dedup", 4, jax.random.PRNGKey(1))
    a2 = dict(a, phase_id=jnp.arange(6), tag="x")
    b2 = dict(b, phase_id=jnp.arange(4), tag="x")
    out = traffic.concat_traces([a2, b2])
    assert out["phase_id"].shape == (10,)     # per-interval arrays concat
    assert out["tag"] == "x"                  # constants carry through
    # a partial key raises instead of being silently dropped
    with pytest.raises(ValueError, match="only 1/2 segments"):
        traffic.concat_traces([dict(a, extra=1.0), b])
    # conflicting non-array constants raise
    with pytest.raises(ValueError, match="differs across segments"):
        traffic.concat_traces([dict(a, tag="x"), dict(b, tag="y")])


def test_pad_trace_and_length():
    tr = traffic.generate_trace("dedup", 10, jax.random.PRNGKey(0))
    assert traffic.trace_length(tr) == 10
    padded = traffic.pad_trace(tr, 16)
    assert padded["ext_load"].shape == (16, NETWORK.n_chiplets)
    np.testing.assert_array_equal(
        np.asarray(padded["t_mask"]), [1.0] * 10 + [0.0] * 6)
    assert traffic.trace_length(padded) == 10
    assert np.all(np.asarray(padded["ext_load"])[10:] == 0)
    # idempotent re-pad extends the mask
    again = traffic.pad_trace(padded, 20)
    assert traffic.trace_length(again) == 10
    with pytest.raises(ValueError, match="cannot pad"):
        traffic.pad_trace(tr, 4)


def test_concat_preserves_t_mask():
    a = traffic.pad_trace(
        traffic.generate_trace("dedup", 6, jax.random.PRNGKey(0)), 8)
    b = traffic.generate_trace("canneal", 4, jax.random.PRNGKey(1))
    out = traffic.concat_traces([a, b])
    np.testing.assert_array_equal(
        np.asarray(out["t_mask"]), [1.0] * 6 + [0.0] * 2 + [1.0] * 4)
    assert traffic.trace_length(out) == 10
