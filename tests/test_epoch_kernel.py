"""Fused epoch-scan kernel parity: `kernels.epoch_step` vs the lax.scan body.

The kernel runs the whole interval loop — latency model, power model,
gateway controller, fault masking, destination-aware routing — inside one
`pallas_call`, carrying the per-chiplet gateway vector in VMEM scratch
across grid steps. Its oracle is `epoch_step.ref.epoch_run_reference`,
literally `lax.scan(make_step(...))`, i.e. what every entry point runs when
`SimConfig.epoch_kernel` is off. These tests pin:

  * record + final-state parity at 1e-6 in interpret mode: clean, ragged
    `t_mask` (tail-padded and fully masked — carry freeze), full fault
    frames (gateway kills, stuck PCM cells, link flaps, loss drift),
    destination matrices, and both RESIPI controllers;
  * every public entry point (`simulate`, `sweep`, `simulate_batch`,
    `sweep_workload`, `SimSession`, `session_tick`) produces the same
    numbers with `epoch_kernel=True`;
  * compile-once discipline survives: one scan-body trace per shape, warm
    calls hit the cache;
  * the arch guard (PROWAVES/AWGR fall back to the scan body at the
    `_scan_trace` gate; the raw kernel op rejects them loudly).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core import traffic
from repro.core.faults import (GatewayFault, LinkFlap, LossDrift,
                               PcmStuckCell, attach_faults, compile_faults)
from repro.core.simulator import Arch, SimConfig
from repro.kernels.epoch_step.ops import epoch_run_pallas
from repro.kernels.epoch_step.ref import epoch_run_reference

SIM = SimConfig()
SIM_K = dataclasses.replace(SIM, epoch_kernel=True)

FAULT_SPECS = (GatewayFault(chiplet=0, slot=0, start=2),
               PcmStuckCell(chiplet=1, slot=1, mode="on", start=4),
               LinkFlap(chiplet=2, p_down=0.3, p_up=0.5, start=0),
               LossDrift(db_per_interval=0.02, start=3))


def _xs_of(trace, sim):
    ext, mem, intra, ext_frac, t_mask, dmat = S._trace_arrays(trace)
    xs = (ext, mem, intra, jnp.broadcast_to(ext_frac, mem.shape), t_mask)
    flt = S._trace_faults(trace)
    if flt is not None:
        xs = xs + tuple(flt)
    return xs, dmat, flt is not None


def _assert_run_parity(trace, sim, rtol=1e-6, atol=1e-6):
    """Raw kernel vs raw reference on one trace: records + final state."""
    xs, dmat, faulted = _xs_of(trace, sim)
    state0 = S._initial_state(sim)
    tables = S.selection_tables_jax(sim.cfg)
    fs_k, recs_k = epoch_run_pallas(state0, xs, sim, tables,
                                    dest=dmat, faulted=faulted,
                                    interpret=True)
    fs_r, recs_r = epoch_run_reference(state0, xs, sim, tables,
                                       dest=dmat, faulted=faulted)
    assert set(recs_k) == set(recs_r), (set(recs_k), set(recs_r))
    for k in recs_r:
        np.testing.assert_allclose(
            np.asarray(recs_k[k], np.float32),
            np.asarray(recs_r[k], np.float32),
            rtol=rtol, atol=atol, err_msg=f"records[{k}]")
    for lk, lr in zip(jax.tree.leaves(fs_k), jax.tree.leaves(fs_r)):
        np.testing.assert_allclose(np.asarray(lk, np.float32),
                                   np.asarray(lr, np.float32),
                                   rtol=rtol, atol=atol,
                                   err_msg="final state")


@pytest.mark.parametrize("arch", [Arch.RESIPI, Arch.RESIPI_ALL])
def test_kernel_matches_reference_clean(arch):
    tr = traffic.generate(traffic.UniformSpec(n_intervals=37),
                          jax.random.PRNGKey(0))
    _assert_run_parity(tr, SIM.with_arch(arch))


@pytest.mark.parametrize("spec", [
    traffic.PermutationSpec(pattern="transpose", n_intervals=29,
                            mean_load=0.05),
    traffic.ParsecSpec(app="dedup", n_intervals=23),
])
def test_kernel_matches_reference_dest(spec):
    tr = traffic.generate(spec, jax.random.PRNGKey(1), dest=True)
    _assert_run_parity(tr, SIM)
    _assert_run_parity(tr, SIM.with_arch(Arch.RESIPI_ALL))


@pytest.mark.parametrize("n_valid", [0, 9])
def test_kernel_matches_reference_tmask(n_valid):
    """Masked intervals freeze the carry — including the all-masked trace,
    whose final state must equal the initial state on both engines."""
    tr = traffic.generate(traffic.UniformSpec(n_intervals=16),
                          jax.random.PRNGKey(2))
    mask = np.zeros((16,), np.float32)
    mask[:n_valid] = 1.0
    tr = dict(tr, t_mask=jnp.asarray(mask))
    _assert_run_parity(tr, SIM)


@pytest.mark.parametrize("arch", [Arch.RESIPI, Arch.RESIPI_ALL])
def test_kernel_matches_reference_faults(arch):
    tr = traffic.generate(traffic.UniformSpec(n_intervals=21),
                          jax.random.PRNGKey(3))
    frame = compile_faults(FAULT_SPECS, SIM.cfg, 21, seed=7)
    _assert_run_parity(attach_faults(tr, frame), SIM.with_arch(arch))


def test_kernel_matches_reference_faults_dest_tmask():
    """The full stack at once: faults + destination matrix + ragged tail."""
    tr = traffic.generate(
        traffic.PermutationSpec(pattern="tornado", n_intervals=18,
                                mean_load=0.05),
        jax.random.PRNGKey(4), dest=True)
    frame = compile_faults(FAULT_SPECS, SIM.cfg, 18, seed=11)
    tr = attach_faults(tr, frame)
    mask = np.ones((18,), np.float32)
    mask[13:] = 0.0
    _assert_run_parity(dict(tr, t_mask=jnp.asarray(mask)), SIM)


@pytest.mark.parametrize("arch", [Arch.PROWAVES, Arch.AWGR])
def test_kernel_rejects_unsupported_arch(arch):
    """The raw op refuses non-RESIPI controllers (their lambda controllers
    are not fused); the engine-level gate falls back silently instead."""
    sim = SIM.with_arch(arch)
    tr = traffic.generate(traffic.UniformSpec(n_intervals=8),
                          jax.random.PRNGKey(5))
    xs, dmat, _ = _xs_of(tr, sim)
    with pytest.raises(ValueError, match="epoch_step"):
        epoch_run_pallas(S._initial_state(sim), xs, sim,
                         S.selection_tables_jax(sim.cfg), interpret=True)


@pytest.mark.parametrize("arch", list(Arch))
def test_simulate_entrypoint_parity(arch):
    """`simulate` with epoch_kernel=True matches the scan engine for every
    arch — RESIPI archs through the kernel, the rest through the fallback."""
    sim, sim_k = SIM.with_arch(arch), SIM_K.with_arch(arch)
    tr = traffic.generate(traffic.ParsecSpec(app="canneal", n_intervals=19),
                          jax.random.PRNGKey(6), dest=True)
    out_k, out_r = S.simulate(tr, sim_k), S.simulate(tr, sim)
    for k, v in out_r["summary"].items():
        np.testing.assert_allclose(np.asarray(out_k["summary"][k]),
                                   np.asarray(v), rtol=1e-6, atol=1e-6,
                                   err_msg=f"summary[{k}]")
    for k, v in out_r["records"].items():
        np.testing.assert_allclose(
            np.asarray(out_k["records"][k], np.float32),
            np.asarray(v, np.float32), rtol=1e-6, atol=1e-6,
            err_msg=f"records[{k}]")


def test_sweep_entrypoint_parity():
    """Runtime-grid sweeps vmap the kernel with traced overrides (l_m etc.
    ride the SMEM params row, not the cache key)."""
    tr = traffic.generate(traffic.UniformSpec(n_intervals=15),
                          jax.random.PRNGKey(7))
    grids = dict(l_m=[0.01, 0.0152, 0.03], wavelengths=[2, 4, 4])
    out_k = S.sweep(tr, SIM_K, **grids)
    out_r = S.sweep(tr, SIM, **grids)
    for k, v in out_r["summary"].items():
        np.testing.assert_allclose(np.asarray(out_k["summary"][k]),
                                   np.asarray(v), rtol=1e-6, atol=1e-6,
                                   err_msg=f"summary[{k}]")


def test_simulate_batch_and_workload_parity():
    specs = [traffic.UniformSpec(n_intervals=10),
             traffic.PermutationSpec(pattern="transpose", n_intervals=14,
                                     mean_load=0.05)]
    traces = [traffic.generate(s, jax.random.PRNGKey(i), dest=True)
              for i, s in enumerate(specs)]
    bk, br = S.simulate_batch(traces, SIM_K), S.simulate_batch(traces, SIM)
    for k, v in br["summary"].items():
        np.testing.assert_allclose(np.asarray(bk["summary"][k]),
                                   np.asarray(v), rtol=1e-6, atol=1e-6,
                                   err_msg=f"batch summary[{k}]")
    wk = S.sweep_workload(specs, SIM_K, seed=0, dest=True)
    wr = S.sweep_workload(specs, SIM, seed=0, dest=True)
    for k, v in wr["summary"].items():
        np.testing.assert_allclose(np.asarray(wk["summary"][k]),
                                   np.asarray(v), rtol=1e-6, atol=1e-6,
                                   err_msg=f"workload summary[{k}]")


def test_session_chunked_carry_parity():
    """Chunked streaming through the kernel == one-shot simulate: the carry
    (controller g, packets_seen, prev_active) crosses chunk boundaries
    through the VMEM-scratch final-state reconstruction."""
    tr = traffic.generate(traffic.BurstySpec(n_intervals=24),
                          jax.random.PRNGKey(8))
    one = S.simulate(tr, SIM_K)
    sess = S.SimSession.init(SIM_K)
    recs = [sess.step_chunk(ch)["records"]
            for ch in traffic.chunk_trace(tr, 8)]
    for k in one["records"]:
        np.testing.assert_allclose(
            np.concatenate([np.asarray(r[k], np.float32) for r in recs]),
            np.asarray(one["records"][k], np.float32),
            rtol=1e-6, atol=1e-6, err_msg=f"chunked records[{k}]")
    for k, v in one["summary"].items():
        np.testing.assert_allclose(np.asarray(sess.summary()[k]),
                                   np.asarray(v), rtol=1e-5, atol=1e-6,
                                   err_msg=f"session summary[{k}]")


def test_session_tick_parity():
    """The server's vmapped tick: live, frozen, and half-masked lanes all
    match the scan engine, with and without destination matrices."""
    tr = traffic.generate(traffic.UniformSpec(n_intervals=8),
                          jax.random.PRNGKey(9))
    tables = S.selection_tables_jax(SIM.cfg)
    states = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[S._initial_state(SIM_K) for _ in range(3)])
    t = 6
    batch = {"ext_load": jnp.stack([tr["ext_load"][:t]] * 3),
             "mem_load": jnp.stack([tr["mem_load"][:t]] * 3),
             "int_load": jnp.stack([tr["int_load"][:t]] * 3),
             "ext_frac": jnp.stack([tr["ext_frac"]] * 3),
             "t_mask": jnp.stack([
                 jnp.ones((t,)), jnp.zeros((t,)),
                 jnp.concatenate([jnp.ones((3,)), jnp.zeros((3,))])])}
    dmat = traffic.destination_matrix_jax(
        traffic.PermutationSpec(pattern="transpose", mean_load=0.05),
        SIM.cfg)
    for b in (batch, dict(batch, dest=jnp.stack([dmat] * 3))):
        out_k = S.session_tick(states, b, tables, SIM_K)
        out_r = S.session_tick(states, b, tables, SIM)
        for lk, lr in zip(jax.tree.leaves(out_k), jax.tree.leaves(out_r)):
            np.testing.assert_allclose(np.asarray(lk, np.float32),
                                       np.asarray(lr, np.float32),
                                       rtol=1e-6, atol=1e-6)


def test_kernel_compile_once():
    """One scan-body trace per shape with the kernel on; warm calls reuse
    the executable (the fused body must not break the jit cache keys)."""
    tr = traffic.generate(traffic.UniformSpec(n_intervals=12),
                          jax.random.PRNGKey(10))
    S.clear_engine_caches()
    S.reset_engine_stats()
    S.simulate(tr, SIM_K)
    stats = S.engine_stats()
    assert stats["simulate_traces"] == 1, stats
    S.simulate(tr, SIM_K)
    S.simulate(dict(tr, ext_load=tr["ext_load"] * 2.0), SIM_K)
    assert S.engine_stats()["simulate_traces"] == 1, S.engine_stats()
