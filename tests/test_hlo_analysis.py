"""Unit tests for the trip-count-corrected HLO static analyzer."""
import numpy as np

from repro.launch.hlo_analysis import (_shape_info, _split_type,
                                       _wire_bytes, analyze_hlo)

CANNED = """\
HloModule jit_f, entry_computation_layout={(f32[8,16])->f32[8,16]}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%zero, %arg)
  %while = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%while), index=1
}
"""


def test_shape_info():
    nbytes, shapes = _shape_info("f32[8,16]{1,0}")
    assert nbytes == 8 * 16 * 4
    nbytes, shapes = _shape_info("(f32[4], bf16[2,2])")
    assert nbytes == 16 + 8


def test_split_type_tuple():
    t, rest = _split_type("(s32[], f32[8,16]) while(%t0), condition=%c")
    assert t == "(s32[], f32[8,16])"
    assert rest.startswith("while(")


def test_wire_byte_factors():
    assert _wire_bytes("all-reduce", 100, 4) == 2 * 0.75 * 100
    assert _wire_bytes("all-gather", 100, 4) == 0.75 * 100
    assert _wire_bytes("reduce-scatter", 100, 4) == 300
    assert _wire_bytes("collective-permute", 100, 4) == 100


def test_while_trip_count_multiplication():
    res = analyze_hlo(CANNED, n_devices=8)
    # dot flops: 2 * 8*16 * 16 = 4096 per iteration, x10 trips
    assert res["flops_per_device"] == 10 * 2 * 8 * 16 * 16
    # all-reduce wire: group size 4, 8*16*4 bytes, x10
    expect = 10 * 2 * (3 / 4) * (8 * 16 * 4)
    np.testing.assert_allclose(res["collectives"]["all-reduce"], expect)
    assert res["collectives"]["total_wire_bytes"] == \
        res["collectives"]["all-reduce"]


def test_entry_detection():
    res = analyze_hlo(CANNED, n_devices=8)
    assert res["entry"].endswith("main")
