import os

# Tests run on the single host CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
ROOT = Path(__file__).resolve().parents[1]
for p in (str(SRC), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)
