"""Closed-loop self-healing tests: detect -> blocked search -> swap -> bill.

The acceptance scenario from the fault-injection issue: a fault storm
kills routers under live gateways mid-stream; the ResilienceRuntime must
detect the degradation from chunk telemetry (threshold + hysteresis over
an EWMA healthy baseline), re-place gateways off the dead routers with a
warm-restarted device search, swap the placement in live without a
recompile, re-converge within 10% of the pre-fault latency, and charge
the physical PCM switching cost for every move.

Everything is seeded and deterministic — no flake tolerance needed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults, traffic
from repro.core.gateway_controller import ControllerConfig
from repro.core.search import repair_placement
from repro.core.simulator import Arch, SimConfig, SimSession, engine_stats, \
    reset_engine_stats
from repro.serve.resilience import ResiliencePolicy, ResilienceRuntime

CHUNK = 8
T_TOTAL = 64
STORM_T0 = 32
LOAD_SCALE = 2.0


def _sim() -> SimConfig:
    """ReSiPI datapath with the controller pinned at 4 gateways.

    With the adaptive controller at light load, killing 2 of 4 slots is
    absorbed by spare activation (g_eff unchanged) — correct behavior, but
    useless for exercising detection. Pinning g=4 makes a dead slot a real
    capacity loss.
    """
    base = SimConfig().with_arch(Arch.RESIPI)
    return dataclasses.replace(base, ctl=ControllerConfig(
        l_m=base.ctl.l_m, max_gateways=4, min_gateways=4))


def _trace(seed: int = 0, t: int = T_TOTAL) -> dict:
    # x2 load: enough offered traffic that halving the gateways congests
    # the survivors past the 10% detection band (calibrated: storm chunks
    # run 13-18% over baseline, healthy phase noise stays under 5%).
    tr = traffic.generate_trace("dedup", t, jax.random.PRNGKey(seed))
    for k in ("ext_load", "mem_load", "int_load"):
        tr[k] = jnp.asarray(tr[k]) * LOAD_SCALE
    return tr


def _chunks(trace):
    for i, ch in enumerate(traffic.chunk_trace(trace, CHUNK)):
        yield i * CHUNK, ch


def _storm_policy():
    # 10% band: wide enough that workload phase noise never double-breaches,
    # narrow enough that losing half the gateways always does.
    return ResiliencePolicy(threshold_frac=0.10, hysteresis=2, cooldown=1,
                            search_generations=4, search_population=6)


# ---------------------------------------------------------------------------
# Policy validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"threshold_frac": 0.0}, {"threshold_frac": -0.1},
    {"hysteresis": 0}, {"cooldown": -1},
    {"baseline_ewma": 0.0}, {"baseline_ewma": 1.5}])
def test_policy_rejects_bad_parameters(kw):
    with pytest.raises(ValueError):
        ResiliencePolicy(**kw)


# ---------------------------------------------------------------------------
# repair_placement: the deterministic relocation primitive under _heal
# ---------------------------------------------------------------------------

def test_repair_placement_moves_only_blocked_gateways():
    sim = SimConfig()
    runtime = ResilienceRuntime(SimSession.init(sim))
    placement = runtime.session.placement
    blocked = (placement[0],)
    repaired = repair_placement(placement, blocked, sim.cfg)
    assert blocked[0] not in repaired
    # Every survivor keeps its router; positions stay unique.
    assert set(placement) - set(blocked) <= set(repaired)
    assert len(set(repaired)) == len(repaired) == len(placement)
    # The relocated gateway lands on the Manhattan-nearest free router.
    moved = (set(repaired) - set(placement)).pop()
    free = {(x, y) for x in range(sim.cfg.mesh_x) for y in range(sim.cfg.mesh_y)
            } - set(placement) - set(blocked)
    d = lambda a, b: abs(a[0] - b[0]) + abs(a[1] - b[1])
    assert d(moved, blocked[0]) == min(d(f, blocked[0]) for f in free)


def test_repair_placement_is_identity_when_nothing_blocked():
    sim = SimConfig()
    placement = SimSession.init(sim).placement
    assert repair_placement(placement, (), sim.cfg) == placement
    assert repair_placement(placement, ((0, 0),), sim.cfg) == placement \
        or (0, 0) in placement


# ---------------------------------------------------------------------------
# The control loop itself
# ---------------------------------------------------------------------------

def test_healthy_stream_never_heals():
    sim = _sim()
    tr = _trace()
    runtime = ResilienceRuntime(SimSession.init(sim))   # default 15% band
    for _, ch in _chunks(tr):
        out = runtime.observe(ch)
        assert out["healed"] is None
    assert runtime.replacements == 0
    assert runtime.total_pcm_nj == 0.0
    assert runtime.baseline is not None and runtime.baseline > 0
    assert len(runtime.events) == T_TOTAL // CHUNK


def test_report_failed_positions_dedups_and_sorts():
    runtime = ResilienceRuntime(SimSession.init(SimConfig()))
    runtime.report_failed_positions([(3, 1), (0, 2), (3, 1)])
    assert runtime._blocked == ((0, 2), (3, 1))


def test_fault_storm_detect_heal_recover_and_bill():
    """The full acceptance loop, step by step."""
    sim = _sim()
    tr = _trace()
    runtime = ResilienceRuntime(SimSession.init(sim), _storm_policy())
    victims = runtime.session.placement[:2]
    storm = [faults.GatewayFault(start=STORM_T0, position=pos)
             for pos in victims]
    injector = faults.FaultInjector(storm, T_TOTAL)

    reset_engine_stats()
    heal_chunk, prefault_baseline = None, None
    for t0, ch in _chunks(tr):
        if t0 == STORM_T0:
            prefault_baseline = runtime.baseline
        faulted = injector.inject(ch, runtime.current_cfg, t0)
        runtime.report_failed_positions(injector.failed_positions(t0))
        out = runtime.observe(faulted)
        if out["healed"] is not None and heal_chunk is None:
            heal_chunk = t0 // CHUNK
            heal = out["healed"]

    # Detection: the heal fired during the storm, within hysteresis+1
    # chunks of onset (one to breach, one to confirm, one to fire).
    assert heal_chunk is not None, "storm was never detected"
    storm_chunk = STORM_T0 // CHUNK
    assert storm_chunk <= heal_chunk <= storm_chunk + 3

    # The recovered placement avoids every dead router and is live.
    new_p = runtime.session.placement
    assert heal["new_placement"] == new_p
    assert not (set(new_p) & set(victims)), \
        f"healed placement {new_p} still uses dead routers {victims}"
    assert set(heal["blocked_positions"]) == set(victims)

    # Physical bill: every moved gateway pays PCM energy + a stall.
    assert runtime.replacements >= 1
    assert heal["moved_gateways"] >= len(victims)
    assert runtime.total_pcm_nj >= heal["pcm_nj"] > 0.0
    assert runtime.total_stall_cycles >= 100

    # Recovery: post-heal chunks re-converge within 10% of the pre-fault
    # baseline (the EWMA frozen during the breach remembers it).
    post = [e["latency"] for e in runtime.events[heal_chunk + 1:]]
    assert post, "no post-heal telemetry"
    assert np.mean(post) <= 1.10 * prefault_baseline, \
        (np.mean(post), prefault_baseline)

    # The loop never recompiled: chunk stepping traced at most its two
    # executables (clean + faulted) and the search dispatched compiled.
    stats = engine_stats()
    assert stats["simulate_traces"] <= 3, stats


def test_one_chunk_glitch_is_absorbed_by_hysteresis():
    """A transient (single-chunk) fault breaches once; hysteresis=2 holds
    fire and the baseline recovers on its own — no PCM spent."""
    sim = _sim()
    tr = _trace(1, 48)
    runtime = ResilienceRuntime(
        SimSession.init(sim),
        ResiliencePolicy(threshold_frac=0.10, hysteresis=2, cooldown=1,
                         search_generations=4, search_population=6))
    victims = runtime.session.placement[:2]
    glitch = [faults.GatewayFault(start=24, end=24 + CHUNK, position=p)
              for p in victims]
    injector = faults.FaultInjector(glitch, 48)
    for i, ch in enumerate(traffic.chunk_trace(tr, CHUNK)):
        t0 = i * CHUNK
        faulted = injector.inject(ch, runtime.current_cfg, t0)
        runtime.report_failed_positions(injector.failed_positions(t0))
        runtime.observe(faulted)
    assert runtime.replacements == 0
    assert runtime.total_pcm_nj == 0.0


def test_cooldown_blocks_back_to_back_heals():
    """With cooldown=2, a persistent storm triggers ONE heal and then the
    runtime holds fire for the cooldown window even if breaches continue
    (it cannot help further once the survivors are placed)."""
    sim = _sim()
    tr = _trace()
    runtime = ResilienceRuntime(
        SimSession.init(sim),
        ResiliencePolicy(threshold_frac=0.01, hysteresis=1, cooldown=2,
                         search_generations=4, search_population=6))
    victims = runtime.session.placement[:1]
    injector = faults.FaultInjector(
        [faults.GatewayFault(start=STORM_T0, position=victims[0])], T_TOTAL)
    heal_chunks = []
    for t0, ch in _chunks(tr):
        faulted = injector.inject(ch, runtime.current_cfg, t0)
        runtime.report_failed_positions(injector.failed_positions(t0))
        out = runtime.observe(faulted)
        if out["healed"] is not None:
            heal_chunks.append(t0 // CHUNK)
    for a, b in zip(heal_chunks, heal_chunks[1:]):
        assert b - a > 2, f"heals {heal_chunks} violate the cooldown"


def test_baseline_freezes_during_breach():
    """The EWMA must not chase the degraded latency: during consecutive
    breaches the baseline stays at its pre-fault value."""
    sim = _sim()
    tr = _trace()
    runtime = ResilienceRuntime(
        SimSession.init(sim),
        # hysteresis high enough that the storm never triggers a heal —
        # isolates the baseline dynamics.
        ResiliencePolicy(threshold_frac=0.10, hysteresis=99))
    victims = runtime.session.placement[:2]
    injector = faults.FaultInjector(
        [faults.GatewayFault(start=STORM_T0, position=p) for p in victims],
        T_TOTAL)
    baselines = []
    for t0, ch in _chunks(tr):
        faulted = injector.inject(ch, runtime.current_cfg, t0)
        out = runtime.observe(faulted)
        baselines.append((out["breach"], out["baseline"]))
    breached = [b for br, b in baselines if br]
    assert breached, "storm never breached — test setup is wrong"
    frozen = baselines[STORM_T0 // CHUNK - 1][1]
    for br, b in baselines[STORM_T0 // CHUNK:]:
        if br:
            assert b == pytest.approx(frozen), \
                "baseline chased the degraded latency"
