"""Kernel <-> model integration: the Pallas kernels are drop-in equal to
the jnp paths the models trace (on TPU the ops.py wrappers replace them)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
from repro.models.ssm import ssd_chunked


def test_pallas_flash_drop_in_for_model_path():
    """kernels/flash_attention == models' _flash_attend on model shapes."""
    b, s, h, d = 2, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    model_path = L._flash_attend(q, k, v, True, pos, pos, 128, 128)
    kernel_path = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(kernel_path),
                               np.asarray(model_path),
                               atol=3e-5, rtol=3e-5)


def test_pallas_ssd_drop_in_for_model_path():
    """kernels/ssd_scan == models.ssm.ssd_chunked on mamba-block shapes."""
    b, l, h, p, g, n, chunk = 2, 256, 24, 64, 1, 128, 128
    # mamba2-130m block dims (d_inner 1536 = 24 heads x 64)
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, l, g, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, l, g, n)) * 0.3
    y_m, s_m = ssd_chunked(x, dt, a, bb, cc, chunk)
    y_k, s_k = ssd_chunked_pallas(x, dt, a, bb, cc, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_m),
                               atol=2e-4, rtol=2e-4)
