"""Laned gradient sync: lane width changes the compiled collective
schedule but NOT the numerics. Runs in a subprocess with 4 forced host
devices (device count locks at first jax init, so the main test process
can't host it)."""
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, re
try:
    from jax.sharding import AxisType
    mesh_kw = {"axis_types": (AxisType.Auto,)}
except ImportError:          # older jax: axes are implicitly Auto
    mesh_kw = {}
from repro.configs import get_smoke_config
from repro.models import get_model
from repro.train.train_step import init_train_state
from repro.train.laned_sync import make_laned_train_step
from repro.data.pipeline import DataConfig, SyntheticLM

mesh = jax.make_mesh((4,), ("data",), **mesh_kw)
cfg = get_smoke_config("stablelm-3b")
model = get_model(cfg)
state = init_train_state(model, jax.random.PRNGKey(0))
data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=32))
batch = {k: jnp.asarray(v) for k, v in data.host_slice(0).items()}

outs = {}
n_ar = {}
for lanes in (1, 4):
    fn = make_laned_train_step(model, mesh, lanes,
                               opt_overrides={"total_steps": 10})
    new_state, metrics = fn(state, batch)
    outs[lanes] = (float(metrics["loss"]),
                   np.asarray(new_state["params"]["ln_f"]["scale"]))
    shlo = fn.lower(state, batch).as_text()
    n_ar[lanes] = shlo.count("optimization_barrier")

# identical numerics
assert abs(outs[1][0] - outs[4][0]) < 1e-5, (outs[1][0], outs[4][0])
np.testing.assert_allclose(outs[1][1], outs[4][1], atol=1e-5, rtol=1e-5)
# different program structure: 4 barrier-chained lane groups vs 1
assert n_ar[4] == 4 and n_ar[1] == 1, (n_ar[1], n_ar[4])
print(f"OK lane_groups(1)={n_ar[1]} lane_groups(4)={n_ar[4]} "
      f"loss={outs[1][0]:.4f}")
"""


def test_lane_width_changes_schedule_not_numerics():
    src = str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout, r.stdout
