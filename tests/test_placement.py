"""Placement-polymorphic gateway engine tests (PR 3).

Covers the first-class placement axis end to end: config-level placement
fields, validated default/explicit position resolution (including the
small-mesh regression), placement-aware selection tables and access-loss
columns, `sweep_placement` single-compile + per-arch parity with unpadded
`simulate`, composition with topology/runtime sweep axes, the flit-kernel
topology builder, the activation-order rule, and `search_placement` on the
Table 1 system.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import photonics, traffic
from repro.core.constants import NETWORK, NetworkConfig
from repro.core.gateway_controller import activation_order
from repro.core.selection import (build_selection_tables,
                                  default_gateway_positions,
                                  normalize_placement,
                                  resolve_gateway_positions)
from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                  rebuild_selection_tables,
                                  reset_engine_stats, search_placement,
                                  simulate, sweep_placement,
                                  sweep_placement_batch, sweep_topology,
                                  topology_point_config)
from repro.core.simulator import SelectionTables_rebuild  # deprecated alias
from repro.kernels.noc_step.ops import build_topology

SUMMARY_KEYS = ("mean_latency", "mean_power_mw", "mean_energy",
                "mean_gateways", "mean_wavelengths", "saturated_frac",
                "total_reconfig_nj")

CENTER = ((1, 1), (2, 2), (1, 2), (2, 1))
CORNERS = ((0, 0), (3, 3), (0, 3), (3, 0))
PLACEMENTS = [None, CENTER, CORNERS]


@pytest.fixture(scope="module")
def trace():
    return traffic.generate_trace("dedup", 12, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Config-level placement field
# ---------------------------------------------------------------------------

def test_gateway_positions_normalized_and_hashable():
    cfg = NetworkConfig(gateway_positions=[[1, 1], [2, 2], [1, 2], [2, 1]])
    assert cfg.gateway_positions == CENTER
    assert hash(cfg) == hash(NetworkConfig(gateway_positions=CENTER))
    assert NetworkConfig().with_placement(CENTER).gateway_positions == CENTER
    assert NetworkConfig(gateway_positions=CENTER).with_placement(
        None).gateway_positions is None


def test_gateway_positions_bad_shape_raises():
    with pytest.raises(ValueError, match="pairs"):
        NetworkConfig(gateway_positions=(1, 2, 3))


def test_mesh_radix_change_resets_explicit_placement():
    cfg = NetworkConfig(gateway_positions=CENTER)
    assert cfg.with_topology(mesh_radix=6).gateway_positions is None
    assert cfg.with_topology(n_chiplets=8).gateway_positions == CENTER


# ---------------------------------------------------------------------------
# Position resolution + validation (incl. small-mesh regression)
# ---------------------------------------------------------------------------

def test_default_positions_validated_on_small_meshes():
    # 2x2 still hosts all four distinct edge slots.
    pos = default_gateway_positions(NetworkConfig(mesh_x=2, mesh_y=2))
    assert len(np.unique(pos, axis=0)) == 4
    # 1-wide meshes used to produce out-of-bounds coordinates silently.
    with pytest.raises(ValueError, match="outside"):
        default_gateway_positions(NetworkConfig(mesh_x=1, mesh_y=4))
    # 3x1 with two gateways used to produce a silent collision at [1, 0].
    with pytest.raises(ValueError, match="collide"):
        default_gateway_positions(
            NetworkConfig(mesh_x=3, mesh_y=1, max_gateways_per_chiplet=2))
    with pytest.raises(ValueError, match="4 gateway slots"):
        default_gateway_positions(
            NetworkConfig(max_gateways_per_chiplet=5))


def test_explicit_positions_validated():
    with pytest.raises(ValueError, match="outside"):
        resolve_gateway_positions(
            NetworkConfig(gateway_positions=((0, 0), (4, 1), (1, 2), (2, 0))))
    with pytest.raises(ValueError, match="collide"):
        resolve_gateway_positions(
            NetworkConfig(gateway_positions=((1, 1), (1, 1), (0, 2), (2, 0))))
    with pytest.raises(ValueError, match="places 2 gateways"):
        resolve_gateway_positions(
            NetworkConfig(gateway_positions=((1, 1), (2, 2))))
    # Explicit denser-than-4 placements unlock gateways beyond the default 4.
    six = ((0, 0), (3, 3), (0, 3), (3, 0), (1, 1), (2, 2))
    cfg = NetworkConfig(max_gateways_per_chiplet=6, gateway_positions=six)
    assert resolve_gateway_positions(cfg).shape == (6, 2)
    assert build_selection_tables(cfg).src_map.shape == (6, 16)


def test_tables_follow_placement_and_record_loss():
    t_default = build_selection_tables(NetworkConfig())
    t_center = build_selection_tables(NetworkConfig(gateway_positions=CENTER))
    # A centered solo gateway beats the default edge slot on mean hops...
    assert t_center.src_hops[0] < t_default.src_hops[0]
    # ...but pays access-waveguide loss that edge placements avoid.
    np.testing.assert_allclose(t_default.gw_loss_db, 0.0)
    assert np.all(t_center.gw_loss_db > 0)
    np.testing.assert_allclose(
        t_center.gw_loss_db,
        np.cumsum(photonics.gateway_access_loss_db(
            np.asarray(CENTER), NetworkConfig())) / np.arange(1, 5))


def test_activation_order_spread_rule():
    order = activation_order([(0, 0), (1, 1), (3, 3), (0, 3)], NETWORK)
    np.testing.assert_array_equal(order, [1, 2, 3, 0])
    assert normalize_placement(
        [(0, 0), (1, 1), (3, 3), (0, 3)], NETWORK, order="spread") == \
        ((1, 1), (3, 3), (0, 3), (0, 0))
    # Deterministic: same input, same order.
    np.testing.assert_array_equal(
        order, activation_order([(0, 0), (1, 1), (3, 3), (0, 3)], NETWORK))


# ---------------------------------------------------------------------------
# sweep_placement: one compile, per-arch parity with unpadded simulate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(Arch))
def test_sweep_placement_matches_simulate_per_arch(trace, arch):
    """Acceptance: lane k == unpadded simulate with gateway_positions=p[k]."""
    base = SimConfig().with_arch(arch)
    out = sweep_placement(trace, base, PLACEMENTS)
    for k, p in enumerate(PLACEMENTS):
        sim_k = dataclasses.replace(
            base, cfg=base.cfg.with_placement(normalize_placement(p)))
        single = simulate(trace, sim_k)["summary"]
        for key in SUMMARY_KEYS:
            np.testing.assert_allclose(
                np.asarray(out["summary"][key][k]), np.asarray(single[key]),
                rtol=1e-6, atol=1e-6,
                err_msg=f"{arch} lane {k} summary[{key}]")


def test_sweep_placement_is_one_compile(trace):
    base = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                               prowaves_rho_lo=0.307)   # test-owned compile
    reset_engine_stats()
    sweep_placement(trace, base, PLACEMENTS)
    assert engine_stats()["simulate_traces"] == 1
    # Different candidate placements, same population size: zero re-traces.
    sweep_placement(trace, base, [CENTER, CORNERS,
                                  ((1, 0), (2, 3), (0, 2), (3, 1))])
    assert engine_stats()["simulate_traces"] == 1


def test_placement_shifts_latency_power_tradeoff(trace):
    out = sweep_placement(trace, SimConfig().with_arch(Arch.RESIPI),
                          [None, CENTER])["summary"]
    lat = np.asarray(out["mean_latency"])
    pw = np.asarray(out["mean_power_mw"])
    assert lat[1] < lat[0], "centered gateways should cut access hops"
    assert pw[1] > pw[0], "interior gateways should pay waveguide loss"


def test_sweep_placement_composes_with_topology_and_runtime(trace):
    """Placement zips with n_chiplets and runtime l_m in one grid."""
    cfg = NETWORK.with_topology(n_chiplets=9)
    wide = traffic.generate_trace("canneal", 10, jax.random.PRNGKey(2), cfg)
    base = SimConfig().with_arch(Arch.RESIPI)
    lms = [0.008, 0.02]
    out = sweep_placement(wide, base, [CENTER, None], n_chiplets=[4, 9],
                          l_m=jnp.asarray(lms))
    for i, (p, c, lm) in enumerate(zip([CENTER, None], [4, 9], lms)):
        point = topology_point_config(base, n_chiplets=c,
                                      gateway_positions=p)
        point = dataclasses.replace(
            point, ctl=dataclasses.replace(point.ctl, l_m=lm))
        single = simulate(traffic.slice_trace(wide, c), point)
        np.testing.assert_allclose(
            np.asarray(out["summary"]["mean_latency"][i]),
            np.asarray(single["summary"]["mean_latency"]),
            rtol=1e-4, err_msg=f"point {i}")


def test_sweep_placement_batch_shapes(trace):
    tr2 = traffic.generate_trace("facesim", 12, jax.random.PRNGKey(4))
    out = sweep_placement_batch([trace, tr2],
                                SimConfig().with_arch(Arch.RESIPI),
                                PLACEMENTS)
    assert out["summary"]["mean_latency"].shape == (2, len(PLACEMENTS))


def test_sweep_placement_validation(trace):
    base = SimConfig().with_arch(Arch.RESIPI)
    with pytest.raises(ValueError, match="outside"):
        sweep_placement(trace, base, [((9, 9), (1, 1), (2, 2), (0, 2))])
    with pytest.raises(ValueError, match="exceeds"):
        sweep_topology(trace, base, gateways_per_chiplet=[3],
                       gateway_positions=[((1, 1), (2, 2))])
    with pytest.raises(ValueError, match="share one length"):
        sweep_placement(trace, base, [CENTER], n_chiplets=[4, 4])


# ---------------------------------------------------------------------------
# Flit-level kernel topology follows the placement
# ---------------------------------------------------------------------------

def test_build_topology_respects_placement():
    cfg = NetworkConfig(gateway_positions=CORNERS)
    next_mat, drain, buf, gw_idx = build_topology(2, 4, cfg)
    rid = lambda x, y: x * cfg.mesh_y + y
    np.testing.assert_array_equal(
        gw_idx, [rid(*CORNERS[0]), rid(*CORNERS[1])])
    # The corner router ejects straight into its co-located gateway sink.
    r = cfg.routers_per_chiplet
    assert next_mat[rid(*CORNERS[0]), r + 0] == 1.0


# ---------------------------------------------------------------------------
# search_placement on the Table 1 system
# ---------------------------------------------------------------------------

def test_search_placement_beats_or_ties_default(trace):
    base = SimConfig().with_arch(Arch.RESIPI)
    reset_engine_stats()
    res = search_placement(trace, base, generations=4, population=6, seed=1)
    # The entire generation loop shares ONE compiled executable (0 traces
    # when another test already compiled this exact search shape).
    assert engine_stats()["simulate_traces"] <= 1
    assert engine_stats()["search_dispatches"] == 1
    assert res["best_score"] <= res["default_score"]
    assert len(res["history"]) == 4
    assert res["default_placement"] == normalize_placement(
        default_gateway_positions(base.cfg))
    # Best placement is a valid, collision-free 4-gateway layout.
    pos = np.asarray(res["best_placement"])
    assert pos.shape == (4, 2)
    assert len(np.unique(pos, axis=0)) == 4
    assert pos.min() >= 0 and pos.max() < 4
    # The reported best bit-matches a fresh unpadded run of that placement.
    single = simulate(trace, dataclasses.replace(
        base, cfg=base.cfg.with_placement(res["best_placement"])))
    np.testing.assert_allclose(
        res["best_summary"]["mean_latency"],
        float(single["summary"]["mean_latency"]), rtol=1e-6)


def test_search_placement_deterministic_by_seed(trace):
    base = SimConfig().with_arch(Arch.RESIPI)
    a = search_placement(trace, base, generations=3, population=5, seed=7)
    b = search_placement(trace, base, generations=3, population=5, seed=7)
    assert a["best_placement"] == b["best_placement"]
    assert a["best_score"] == b["best_score"]


def test_search_placement_param_validation(trace):
    base = SimConfig().with_arch(Arch.RESIPI)
    with pytest.raises(ValueError, match="population"):
        search_placement(trace, base, population=1)
    with pytest.raises(ValueError, match="generations"):
        search_placement(trace, base, generations=0)
    with pytest.raises(ValueError, match="objective"):
        search_placement(trace, base, generations=1, population=2,
                         objective="nope")


# ---------------------------------------------------------------------------
# PEP8 rename keeps the deprecated alias working
# ---------------------------------------------------------------------------

def test_rebuild_selection_tables_alias(trace):
    assert SelectionTables_rebuild is rebuild_selection_tables
    t = rebuild_selection_tables(NETWORK)
    assert set(t) >= {"src_map", "src_hops", "gw_loss_db"}
