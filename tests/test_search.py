"""Device-resident placement-search engine tests (PR 5).

Covers the traceable placement->tables path (jnp twins vs the numpy
builders at 1e-6 across meshes, exact activation-order parity), the
one-dispatch `lax.scan` search (determinism, host-oracle re-scoring
parity, elitism/annealing invariants, engine_stats accounting), the
vmapped island search with zipped runtime grids, and the engine-selection
wrapper.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import traffic
from repro.core.constants import NetworkConfig
from repro.core.gateway_controller import (activation_order,
                                           activation_order_jnp)
from repro.core.selection import (build_selection_tables, normalize_placement,
                                  placement_tables_jnp)
from repro.core.simulator import (Arch, SimConfig, engine_stats,
                                  reset_engine_stats, search_placement,
                                  search_placement_islands, simulate, sweep)

MESHES = [(4, 4, 4), (5, 5, 4), (6, 6, 4), (4, 4, 6), (3, 3, 2)]
TRIALS_PER_MESH = 10


@pytest.fixture(scope="module")
def trace():
    return traffic.generate_trace("dedup", 12, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def base():
    return SimConfig().with_arch(Arch.RESIPI)


@pytest.fixture(scope="module")
def device_result(trace, base):
    """One compiled device search shared by the assertion tests below."""
    return search_placement(trace, base, generations=4, population=6,
                            seed=1)


def _random_placements(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    coords = [(x, y) for x in range(cfg.mesh_x) for y in range(cfg.mesh_y)]
    g = cfg.max_gateways_per_chiplet
    return [[coords[i] for i in rng.choice(len(coords), g, replace=False)]
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Traceable placement->tables path: jnp twins vs numpy builders
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_twins(cfg):
    return (jax.jit(lambda p: placement_tables_jnp(p, cfg)),
            jax.jit(lambda p: activation_order_jnp(p, cfg)))


@pytest.mark.parametrize("mesh", MESHES)
def test_placement_tables_jnp_match_numpy(mesh):
    """Acceptance: jnp twins == numpy builders at 1e-6 on all meshes."""
    mx, my, g = mesh
    cfg = NetworkConfig(mesh_x=mx, mesh_y=my, max_gateways_per_chiplet=g)
    tables_fn, _ = _jitted_twins(cfg)
    for pos in _random_placements(cfg, TRIALS_PER_MESH, seed=mx * my + g):
        ref = build_selection_tables(
            cfg.with_placement(normalize_placement(pos, cfg)))
        out = tables_fn(jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(out["src_hops"]), ref.src_hops, rtol=1e-6,
            atol=1e-6, err_msg=f"src_hops diverged for {pos} on {mesh}")
        np.testing.assert_allclose(
            np.asarray(out["gw_loss_db"]), ref.gw_loss_db, rtol=1e-6,
            atol=1e-6, err_msg=f"gw_loss_db diverged for {pos} on {mesh}")


@pytest.mark.parametrize("mesh", MESHES)
def test_activation_order_jnp_matches_numpy(mesh):
    """The traceable spread rule is EXACTLY the numpy rule (tie-breaks)."""
    mx, my, g = mesh
    cfg = NetworkConfig(mesh_x=mx, mesh_y=my, max_gateways_per_chiplet=g)
    _, order_fn = _jitted_twins(cfg)
    for pos in _random_placements(cfg, TRIALS_PER_MESH, seed=7 * mx + g):
        np.testing.assert_array_equal(
            np.asarray(order_fn(jnp.asarray(pos, jnp.int32))),
            activation_order(pos, cfg),
            err_msg=f"activation order diverged for {pos} on {mesh}")


def test_placement_tables_jnp_vmappable():
    cfg = NetworkConfig()
    batch = jnp.asarray(_random_placements(cfg, 5, seed=3), jnp.int32)
    out = jax.jit(jax.vmap(lambda p: placement_tables_jnp(p, cfg)))(batch)
    assert out["src_hops"].shape == (5, 4)
    assert out["gw_loss_db"].shape == (5, 4)


# ---------------------------------------------------------------------------
# Device-resident search: determinism, parity, invariants, accounting
# ---------------------------------------------------------------------------

def test_device_search_deterministic_by_seed(trace, base, device_result):
    again = search_placement(trace, base, generations=4, population=6,
                             seed=1)
    assert again["best_placement"] == device_result["best_placement"]
    assert again["best_score"] == device_result["best_score"]
    assert again["history"] == device_result["history"]


def test_device_search_result_structure(device_result, base):
    res = device_result
    assert res["engine"] == "device"
    assert res["objective"] == "inter_latency"
    assert len(res["history"]) == 4
    pos = np.asarray(res["best_placement"])
    g = base.cfg.max_gateways_per_chiplet
    assert pos.shape == (g, 2)
    assert len(np.unique(pos, axis=0)) == g
    assert pos.min() >= 0 and pos.max() < base.cfg.mesh_x
    inc = np.asarray(res["incumbent_placement"])
    assert inc.shape == (g, 2) and len(np.unique(inc, axis=0)) == g


def test_device_search_matches_host_rescoring(trace, base, device_result):
    """The device-path score of the best placement == an unpadded simulate
    of that placement (the host parity oracle) — traced tables vs numpy
    tables end to end."""
    res = device_result
    single = simulate(trace, dataclasses.replace(
        base, cfg=base.cfg.with_placement(res["best_placement"])))
    ref = float(np.mean(np.asarray(
        single["records"]["mean_inter_latency"])))
    np.testing.assert_allclose(res["best_score"], ref, rtol=1e-5)
    np.testing.assert_allclose(
        res["best_summary"]["mean_latency"],
        float(single["summary"]["mean_latency"]), rtol=1e-5)
    # The packed-summary schema must track the engine's summary dict —
    # pins simulator.SUMMARY_KEYS against _summary_from_sums drift.
    assert set(res["best_summary"]) == set(single["summary"])


def test_device_search_elitism_and_annealing(device_result):
    """best_score is the running min of every candidate ever scored and
    never increases (elitist acceptance is monotone)."""
    hist = device_result["history"]
    best = np.asarray([h["best_score"] for h in hist])
    cand = np.asarray([h["best_candidate_score"] for h in hist])
    np.testing.assert_allclose(best, np.minimum.accumulate(cand),
                               rtol=1e-7)
    assert np.all(np.diff(best) <= 0 + 1e-12)
    assert device_result["best_score"] <= device_result["default_score"]
    # Greedy rule: a strictly-improving generation is always accepted.
    for h in hist:
        if h["best_candidate_score"] < h["parent_score"]:
            assert h["accepted"]


def test_device_search_one_trace_one_dispatch(trace):
    # A test-owned config variant guarantees a cold executable here.
    sim = dataclasses.replace(SimConfig().with_arch(Arch.RESIPI),
                              prowaves_rho_lo=0.3093)
    reset_engine_stats()
    search_placement(trace, sim, generations=2, population=4, seed=0)
    stats = engine_stats()
    assert stats["simulate_traces"] == 1, \
        f"expected ONE scan-body trace for the whole search, got {stats}"
    assert stats["search_dispatches"] == 1
    # Warm repeat: one more dispatch, ZERO new traces.
    search_placement(trace, sim, generations=2, population=4, seed=5)
    stats = engine_stats()
    assert stats["simulate_traces"] == 1
    assert stats["search_dispatches"] == 2


def test_engines_agree_on_default_score(trace, base, device_result):
    """Cross-engine parity oracle: both engines score the deterministic
    default edge scheme; the values must match at float tolerance."""
    host = search_placement(trace, base, generations=2, population=4,
                            seed=1, engine="host")
    assert host["engine"] == "host"
    np.testing.assert_allclose(host["default_score"],
                               device_result["default_score"], rtol=1e-5)
    assert host["default_placement"] == device_result["default_placement"]
    assert host["best_score"] <= host["default_score"]


def test_device_search_with_init_scores_default(trace, base):
    """A non-default init still scores the default edge scheme in gen 0 —
    even at the host engine's minimum population of 2 (the device lane-1
    injection replaces the lone proposal that generation)."""
    center = ((1, 1), (2, 2), (1, 2), (2, 1))
    res = search_placement(trace, base, generations=2, population=2,
                           seed=0, init=center)
    assert res["best_score"] <= res["default_score"]
    host = search_placement(trace, base, generations=2, population=2,
                            seed=0, init=center, engine="host")
    np.testing.assert_allclose(host["default_score"],
                               res["default_score"], rtol=1e-5)


def test_search_param_validation(trace, base):
    with pytest.raises(ValueError, match="population"):
        search_placement(trace, base, population=1)
    with pytest.raises(ValueError, match="generations"):
        search_placement(trace, base, generations=0)
    with pytest.raises(ValueError, match="objective"):
        search_placement(trace, base, generations=1, population=2,
                         objective="nope")
    with pytest.raises(ValueError, match="engine"):
        search_placement(trace, base, engine="quantum")
    with pytest.raises(ValueError, match="init places"):
        search_placement(trace, base, init=((0, 0), (1, 1)))


# ---------------------------------------------------------------------------
# Island search: vmapped chains + zipped runtime grids
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def island_result(trace, base):
    return search_placement_islands(
        trace, base, generations=3, population=5, seed=2,
        l_m=[0.008, 0.0152, 0.03])


def test_islands_shapes_and_overall_best(island_result):
    res = island_result
    k = 3
    assert res["islands"] == k
    assert res["island_best_scores"].shape == (k,)
    assert res["island_default_scores"].shape == (k,)
    assert len(res["island_best_placements"]) == k
    assert res["history"]["best_score"].shape == (k, 3)
    # The overall winner is the argmin island, scored against ITS default.
    kb = res["best_island"]
    assert res["best_score"] == res["island_best_scores"][kb]
    assert res["best_score"] == res["island_best_scores"].min()
    assert res["best_placement"] == res["island_best_placements"][kb]
    # Per-island elitism: every island beats or ties its own default
    # (init is None, so the default is scored in generation 0).
    assert np.all(res["island_best_scores"]
                  <= res["island_default_scores"] + 1e-6)


def test_islands_zip_runtime_grid(trace, base, island_result):
    """Island k really runs under l_m[k]: its default-scheme score matches
    a single-lane sweep with that override."""
    lms = [0.008, 0.0152, 0.03]
    out = sweep(trace, base, l_m=jnp.asarray(lms))
    ref = np.asarray(
        jnp.mean(out["records"]["mean_inter_latency"], axis=-1))
    np.testing.assert_allclose(island_result["island_default_scores"], ref,
                               rtol=1e-5)


def test_islands_deterministic(trace, base, island_result):
    again = search_placement_islands(
        trace, base, generations=3, population=5, seed=2,
        l_m=[0.008, 0.0152, 0.03])
    assert again["best_placement"] == island_result["best_placement"]
    np.testing.assert_array_equal(again["island_best_scores"],
                                  island_result["island_best_scores"])


def test_islands_validation(trace, base):
    with pytest.raises(ValueError, match="length islands"):
        search_placement_islands(trace, base, islands=4, l_m=[0.01, 0.02])
    with pytest.raises(ValueError, match="non-sweepable"):
        search_placement_islands(trace, base, islands=2,
                                 mesh_radix=[4, 5])
    with pytest.raises(ValueError, match="share one length"):
        search_placement_islands(trace, base, l_m=[0.01, 0.02],
                                 buffer_sat=[0.5])
