"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import reference_attention
from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_pallas
from repro.kernels.ssd_scan.ref import reference_intra_chunk
from repro.models.ssm import ssd_chunked
from repro.kernels.noc_step.kernel import noc_run_pallas
from repro.kernels.noc_step.ref import reference_noc_run
from repro.kernels.noc_step.ops import build_topology


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,d", [(2, 256, 4, 64), (1, 384, 2, 80),
                                     (2, 512, 3, 128), (1, 128, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_reference(b, s, h, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    out = flash_attention(q, k, v, causal=True)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    ref = reference_attention(qt, kt, vt, causal=True).transpose(0, 2, 1, 3)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=atol)


def test_flash_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, causal=False)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    ref = reference_attention(qt, kt, vt, causal=False).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([128, 256, 384]),
       h=st.integers(min_value=1, max_value=4),
       d=st.sampled_from([32, 64, 96]))
def test_flash_hypothesis_sweep(s, h, d):
    ks = jax.random.split(jax.random.PRNGKey(s * h + d), 3)
    q = jax.random.normal(ks[0], (1, s, h, d))
    k = jax.random.normal(ks[1], (1, s, h, d))
    v = jax.random.normal(ks[2], (1, s, h, d))
    out = flash_attention(q, k, v, causal=True)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    ref = reference_attention(qt, kt, vt, causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def _ssd_inputs(b, l, h, p, g, n, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bb = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    cc = jax.random.normal(ks[4], (b, l, g, n)) * 0.5
    return x, dt, a, bb, cc


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (2, 128, 4, 16, 1, 16, 32), (1, 256, 6, 32, 2, 8, 64),
    (1, 64, 2, 8, 1, 4, 16)])
def test_ssd_kernel_full_scan(b, l, h, p, g, n, chunk):
    x, dt, a, bb, cc = _ssd_inputs(b, l, h, p, g, n)
    y_k, s_k = ssd_chunked_pallas(x, dt, a, bb, cc, chunk)
    y_r, s_r = ssd_chunked(x, dt, a, bb, cc, chunk)
    np.testing.assert_allclose(y_k, y_r, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               atol=1e-4, rtol=1e-4)


def test_ssd_intra_kernel_vs_oracle():
    b, nc, q, h, p, n = 1, 2, 32, 3, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, nc, q, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, nc, q, h, n)) * 0.5
    cc = jax.random.normal(ks[4], (b, nc, q, h, n)) * 0.5
    y_k, s_k = ssd_intra_chunk_pallas(x, dt, a, bb, cc)
    y_r, s_r = reference_intra_chunk(x, dt, a, bb, cc)
    np.testing.assert_allclose(y_k, y_r, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_k, s_r, atol=1e-4, rtol=1e-4)


def test_ssd_chunk_invariance():
    """The chunked algorithm must be exact: result independent of chunk."""
    x, dt, a, bb, cc = _ssd_inputs(1, 128, 2, 8, 1, 8)
    y32, s32 = ssd_chunked(x, dt, a, bb, cc, 32)
    y64, s64 = ssd_chunked(x, dt, a, bb, cc, 64)
    np.testing.assert_allclose(y32, y64, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s32), np.asarray(s64),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# NoC flit kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,w", [(1, 16), (2, 4), (4, 4)])
def test_noc_kernel_vs_oracle(g, w):
    nm, drain, buf, _ = build_topology(g, w)
    n = nm.shape[0]
    arr = (jax.random.uniform(jax.random.PRNGKey(g), (512, n)) <
           0.03).astype(jnp.float32) * 8
    rk, ok, dk = noc_run_pallas(arr, jnp.asarray(nm), jnp.asarray(drain),
                                jnp.asarray(buf), t_chunk=128)
    rr, orr, dr = reference_noc_run(arr, jnp.asarray(nm),
                                    jnp.asarray(drain), jnp.asarray(buf))
    np.testing.assert_allclose(rk, rr, atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(dk, dr, atol=1e-2, rtol=1e-4)


def test_noc_flit_conservation():
    """Flits are conserved: injected = drained + still-queued."""
    nm, drain, buf, _ = build_topology(2, 4)
    n = nm.shape[0]
    arr = (jax.random.uniform(jax.random.PRNGKey(9), (1024, n)) <
           0.02).astype(jnp.float32) * 8
    resid, occ, drained = reference_noc_run(
        arr, jnp.asarray(nm), jnp.asarray(drain), jnp.asarray(buf))
    injected = float(jnp.sum(arr))
    assert float(jnp.sum(drained) + jnp.sum(occ)) == pytest.approx(
        injected, rel=1e-5)
