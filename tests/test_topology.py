"""Router coordinate/adjacency model tests (PR 10 enabling refactor).

Pins the mesh closed forms against the generic BFS machinery (an explicit
coords tuple spelling out the same grid must reproduce every derived-mesh
table), exercises the hexagonal generator (axial distance closed form,
boundary detection, default placements), and checks that the noc_step
hop-greedy router is XY-equivalent on meshes and loop-free on hex.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import topology, traffic
from repro.core.constants import NETWORK
from repro.core.gateway_controller import (activation_order,
                                           activation_order_jnp)
from repro.core.selection import normalize_placement
from repro.kernels.noc_step.ops import build_topology

MESHES = [(4, 4), (5, 3), (6, 6)]


def _mesh_cfg(mx, my, **kw):
    kw.setdefault("gateway_positions", None)
    return dataclasses.replace(NETWORK, mesh_x=mx, mesh_y=my, **kw)


def _explicit_mesh_cfg(mx, my, **kw):
    """The same grid as an explicit coords tuple (BFS paths, no closed
    forms) — every geometry table must agree with the derived mesh."""
    coords = tuple((x, y) for x in range(mx) for y in range(my))
    return _mesh_cfg(mx, my, coords=coords, coord_model="mesh", **kw)


# ---------------------------------------------------------------------------
# Mesh parity: BFS/generic paths == closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mx,my", MESHES)
def test_explicit_mesh_matches_derived_geometry(mx, my):
    mesh, expl = _mesh_cfg(mx, my), _explicit_mesh_cfg(mx, my)
    np.testing.assert_array_equal(topology.router_coords(mesh),
                                  topology.router_coords(expl))
    np.testing.assert_array_equal(topology.hop_matrix(mesh),
                                  topology.hop_matrix(expl))
    np.testing.assert_array_equal(topology.edge_distance(mesh),
                                  topology.edge_distance(expl))
    np.testing.assert_array_equal(topology.router_index_lut(mesh),
                                  topology.router_index_lut(expl))
    assert topology.max_hops(mesh) == topology.max_hops(expl) \
        == mx + my - 2


@pytest.mark.parametrize("mx,my", MESHES)
def test_mesh_router_index_lut_is_flat_order(mx, my):
    lut = topology.router_index_lut(_mesh_cfg(mx, my))
    for x in range(mx):
        for y in range(my):
            assert lut[x, y] == x * my + y


@pytest.mark.parametrize("mx,my", MESHES)
def test_mesh_mean_hops_closed_form_matches_matrix(mx, my):
    mesh = _mesh_cfg(mx, my)
    assert topology.mean_hops(mesh) == pytest.approx(
        float(topology.hop_matrix(mesh).mean()))
    # The explicit path computes the matrix mean directly.
    assert topology.mean_hops(_explicit_mesh_cfg(mx, my)) == pytest.approx(
        topology.mean_hops(mesh))


def test_hop_lut_off_layout_sentinel():
    cfg = _mesh_cfg(4, 4)
    lut = topology.hop_lut(cfg)
    assert lut.shape == (16, 4, 4)
    assert lut.max() == topology.max_hops(cfg)  # full grid: no holes
    hole = topology.hop_lut(topology.hex_config(1))
    assert hole.max() == topology.max_hops(topology.hex_config(1)) + 1


# ---------------------------------------------------------------------------
# Hexagonal generator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rings", [1, 2, 3])
def test_hex_coords_count_and_bounds(rings):
    coords = topology.hex_coords(rings)
    assert len(coords) == 3 * rings * (rings + 1) + 1
    pos = np.asarray(coords)
    assert pos.min() >= 0 and pos.max() <= 2 * rings
    assert len(np.unique(pos, axis=0)) == len(pos)


@pytest.mark.parametrize("rings", [1, 2])
def test_hex_hop_matrix_matches_axial_closed_form(rings):
    cfg = topology.hex_config(rings)
    pos = topology.router_coords(cfg).astype(np.int64) - rings  # unshift
    dq = pos[:, None, 0] - pos[None, :, 0]
    dr = pos[:, None, 1] - pos[None, :, 1]
    want = (np.abs(dq) + np.abs(dr) + np.abs(dq + dr)) // 2
    np.testing.assert_array_equal(topology.hop_matrix(cfg), want)
    assert topology.max_hops(cfg) == 2 * rings


def test_hex_config_sizes():
    cfg = topology.hex_config(2)
    assert cfg.coord_model == "hex"
    assert cfg.routers_per_chiplet == 19
    assert (cfg.mesh_x, cfg.mesh_y) == (5, 5)  # LUT bounding box


def test_hex_boundary_and_default_positions():
    cfg = topology.hex_config(2)
    ed = topology.edge_distance(cfg)
    # Ring-2 patch: the 12 outermost routers are the boundary, the center
    # sits 2 hops in.
    assert int((ed == 0).sum()) == 12
    assert ed.max() == 2
    pos = topology.default_positions(cfg)
    assert pos.shape == (cfg.max_gateways_per_chiplet, 2)
    assert len({tuple(p) for p in pos}) == len(pos)
    lut = topology.edge_lut(cfg)
    assert all(lut[x, y] == 0 for x, y in pos)  # gateways on the boundary


def test_hex_activation_order_numpy_jnp_parity():
    cfg = topology.hex_config(2)
    coords = topology.router_coords(cfg)
    rng = np.random.RandomState(7)
    for _ in range(8):
        pos = coords[rng.choice(len(coords), size=4, replace=False)]
        np.testing.assert_array_equal(
            np.asarray(activation_order_jnp(pos, cfg)),
            activation_order(pos, cfg))


def test_hex_normalize_placement_spread_idempotent():
    cfg = topology.hex_config(2)
    coords = topology.router_coords(cfg)
    pos = coords[np.random.RandomState(1).choice(len(coords), 4,
                                                 replace=False)]
    spread = normalize_placement(pos, cfg, order="spread")
    assert normalize_placement(spread, cfg, order="spread") == spread


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_disconnected_layout_raises():
    cfg = _mesh_cfg(8, 8, coords=((0, 0), (5, 5)))
    with pytest.raises(ValueError, match="disconnected"):
        topology.hop_matrix(cfg)


def test_duplicate_coords_raise():
    with pytest.raises(ValueError, match="duplicate"):
        topology.router_coords(_mesh_cfg(4, 4, coords=((0, 0), (0, 0))))


def test_negative_coords_raise():
    with pytest.raises(ValueError, match="negative"):
        topology.router_coords(_mesh_cfg(4, 4, coords=((-1, 0), (0, 0))))


def test_unknown_coord_model_raises():
    cfg = _mesh_cfg(4, 4, coords=((0, 0), (0, 1)), coord_model="torus")
    with pytest.raises(ValueError, match="coord_model"):
        topology.hop_matrix(cfg)


def test_with_topology_radix_drops_explicit_coords():
    cfg = topology.hex_config(2).with_topology(mesh_radix=4)
    assert cfg.coords is None
    assert (cfg.mesh_x, cfg.mesh_y) == (4, 4)


# ---------------------------------------------------------------------------
# noc_step routing over the coordinate model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mx,my", [(4, 4), (5, 3)])
def test_noc_hop_greedy_routing_is_xy_on_meshes(mx, my):
    gw = ((1, 0), (mx - 1, my - 2), (0, my - 1), (mx - 2, 1))
    mesh = _mesh_cfg(mx, my, gateway_positions=gw)
    expl = _explicit_mesh_cfg(mx, my, gateway_positions=gw)
    for g in (1, 2, 4):
        nm_m, dr_m, buf_m, gi_m = build_topology(g, 4, mesh)
        nm_e, dr_e, buf_e, gi_e = build_topology(g, 4, expl)
        np.testing.assert_array_equal(nm_m, nm_e)
        np.testing.assert_array_equal(dr_m, dr_e)
        np.testing.assert_array_equal(buf_m, buf_e)
        np.testing.assert_array_equal(gi_m, gi_e)


def test_noc_routing_on_hex_is_loop_free():
    cfg = topology.hex_config(2)
    g = cfg.max_gateways_per_chiplet
    next_mat, drain, buf, gw_idx = build_topology(g, 4, cfg)
    r = cfg.routers_per_chiplet
    # Every router forwards to exactly one node; following next hops from
    # any router must reach a gateway sink within the diameter.
    assert np.all(next_mat[:r].sum(axis=1) == 1.0)
    for start in range(r):
        node, steps = start, 0
        while node < r:
            node = int(np.argmax(next_mat[node]))
            steps += 1
            assert steps <= topology.max_hops(cfg) + 1
        assert node >= r  # landed on a sink


def test_simulate_runs_on_hex_config():
    from repro.core.simulator import Arch, SimConfig, simulate

    sim = dataclasses.replace(
        SimConfig().with_arch(Arch.RESIPI), cfg=topology.hex_config(2))
    tr = traffic.generate_trace("dedup", 4, jax.random.PRNGKey(0), sim.cfg)
    out = simulate(tr, sim)["summary"]
    assert np.isfinite(out["mean_latency"]) and out["mean_latency"] > 0
    assert np.isfinite(out["mean_power_mw"]) and out["mean_power_mw"] > 0
