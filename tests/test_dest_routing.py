"""Destination-aware routing fidelity: spec-conditioned `dest` matrices.

Through PR 7 the epoch model consumed only per-chiplet *injected* load, so
permutation workloads were invisible to routing. `traffic.generate(...,
dest=True)` now attaches the spec's row-stochastic destination matrix and
the engine resolves actual source->destination gateway pressure. Pinned
here:

  * opt-in contract: `dest=False` traces are bit-identical to pre-dest
    generation and both engines (jit + eager) agree bitwise on them;
  * the fidelity itself: destination matrices *measurably* move the
    inter-chiplet latency/power numbers, and transpose/tornado separate
    from uniform at the same calibrated mean load — the congestion
    structure ReSiPI's traffic-driven deployment exploits;
  * matrix properties (row-stochastic, self-pair divert parity) across
    every spec family and chiplet count, property-based;
  * memoization per (spec, cfg) and `clear_engine_caches` wiring;
  * transform carry: concat mixes load-weighted, slice renormalizes,
    pad/chunk carry `dest` whole, stacking demands uniformity;
  * padded-topology paths: masked chiplet columns contribute zero with a
    destination matrix attached;
  * the session server serves [C, C] dest-carrying traces (PR 9) and
    refuses batched [K, C, C] matrices instead of silently serving them
    as uniform traffic.
"""
try:                                     # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal container: use shim
    from hypothesis_fallback import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core import traffic
from repro.core.constants import NETWORK
from repro.core.simulator import Arch, SimConfig
from repro.core.traffic import (ParsecSpec, PermutationSpec, UniformSpec,
                                destination_matrix, destination_matrix_jax,
                                permutation_destinations)

SIM = SimConfig()
MEAN_LOAD, T = 0.05, 40


def _spec_of(kind: str, c: int):
    if kind == "uniform":
        return UniformSpec(mean_load=0.02)
    if kind == "bursty":
        return traffic.BurstySpec(mean_load=0.02)
    if kind == "hotspot":
        return traffic.HotspotSpec(mean_load=0.02)
    if kind == "parsec":
        return ParsecSpec(app="dedup")
    pats = traffic.PERMUTATION_PATTERNS
    return PermutationSpec(pattern=pats[c % len(pats)], mean_load=0.02)


# -- opt-in contract ---------------------------------------------------------

def test_dest_is_opt_in():
    key = jax.random.PRNGKey(0)
    spec = PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD,
                           n_intervals=12)
    plain = traffic.generate(spec, key)
    with_d = traffic.generate(spec, key, dest=True)
    assert "dest" not in plain
    assert np.asarray(with_d["dest"]).shape == (NETWORK.n_chiplets,) * 2
    # attaching the matrix must not perturb the load columns at all
    for k in traffic.TRACE_KEYS:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(with_d[k]), err_msg=k)


@pytest.mark.parametrize("arch", list(Arch))
def test_dest_none_bitmatch_per_arch(arch):
    """Destination-free traces ride the exact uniform branch: the jit and
    eager engines agree bitwise, dest threading adds zero numeric drift."""
    sim = SIM.with_arch(arch)
    tr = traffic.generate(UniformSpec(mean_load=MEAN_LOAD, n_intervals=14),
                          jax.random.PRNGKey(1))
    jit_out = S.simulate(tr, sim)
    eager_out = S.simulate_eager(tr, sim)
    for k in S.SUMMARY_KEYS:
        np.testing.assert_array_equal(
            np.asarray(jit_out["summary"][k]),
            np.asarray(eager_out["summary"][k]), err_msg=f"summary[{k}]")


@pytest.mark.parametrize("arch", list(Arch))
def test_dest_oracle_parity_per_arch(arch):
    """With a destination matrix, the compiled engine matches the eager
    per-call-retrace oracle at 1e-6 for every architecture."""
    sim = SIM.with_arch(arch)
    tr = traffic.generate(
        PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD,
                        n_intervals=14),
        jax.random.PRNGKey(2), dest=True)
    jit_out = S.simulate(tr, sim)
    eager_out = S.simulate_eager(tr, sim)
    for k in S.SUMMARY_KEYS:
        np.testing.assert_allclose(
            np.asarray(jit_out["summary"][k]),
            np.asarray(eager_out["summary"][k]),
            rtol=1e-6, atol=1e-6, err_msg=f"summary[{k}]")


# -- the fidelity: destinations move the numbers -----------------------------

def _inter_latency(trace):
    out = S.simulate(trace, SIM)
    tm = np.asarray(trace.get("t_mask", np.ones((T,))))
    mi = np.asarray(out["records"]["mean_inter_latency"])
    return float(mi.sum() / tm.sum()), \
        float(out["summary"]["mean_power_mw"])


@pytest.mark.parametrize("pattern", ["transpose", "tornado"])
def test_dest_changes_the_numbers(pattern):
    """Same trace with/without its destination matrix: the resolved
    gateway pressure must move the inter-chiplet latency measurably
    (routing was destination-blind before, so identical numbers would
    mean the matrix is decorative)."""
    tr = traffic.generate(
        PermutationSpec(pattern=pattern, mean_load=MEAN_LOAD,
                        n_intervals=T),
        jax.random.PRNGKey(3), dest=True)
    with_d, _ = _inter_latency(tr)
    without, _ = _inter_latency({k: v for k, v in tr.items()
                                 if k != "dest"})
    assert abs(with_d - without) / without > 0.02, (with_d, without)


def test_permutation_separates_from_uniform_at_equal_load():
    """The acceptance pin: transpose/tornado vs uniform at the same
    calibrated mean load land on visibly different latency/power points
    once destinations are resolved."""
    def run(spec):
        lat, pw = zip(*[_inter_latency(
            traffic.generate(spec, jax.random.PRNGKey(s), dest=True))
            for s in range(4)])
        return float(np.mean(lat)), float(np.mean(pw))
    u_lat, u_pow = run(UniformSpec(mean_load=MEAN_LOAD, n_intervals=T))
    t_lat, t_pow = run(PermutationSpec(pattern="transpose",
                                       mean_load=MEAN_LOAD, n_intervals=T))
    o_lat, o_pow = run(PermutationSpec(pattern="tornado",
                                       mean_load=MEAN_LOAD, n_intervals=T))
    assert abs(t_lat - u_lat) / u_lat > 0.01, (t_lat, u_lat)
    assert abs(o_lat - u_lat) / u_lat > 0.01, (o_lat, u_lat)
    # transpose self-pairs divert to intra: far fewer lit gateways
    assert abs(t_pow - u_pow) / u_pow > 0.10, (t_pow, u_pow)


# -- matrix properties (property-based) --------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["uniform", "bursty", "hotspot", "parsec",
                        "permutation"]),
       st.sampled_from([4, 9, 16]))
def test_dest_row_stochastic(kind, c):
    cfg = NETWORK.with_topology(n_chiplets=c)
    d = destination_matrix(_spec_of(kind, c), cfg)
    assert d.shape == (c, c)
    assert (d >= 0).all()
    np.testing.assert_allclose(d.sum(axis=1), np.ones((c,)),
                               rtol=1e-5, atol=1e-5)
    if kind != "permutation":       # permutation self-pairs sit on the diag
        assert np.all(np.diag(d) == 0.0)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([4, 9, 16]),
       st.integers(min_value=0, max_value=1 << 16))
def test_permutation_self_pair_divert_parity(c, seed):
    """The divert-parity invariant: the dest diagonal marks exactly the
    self-paired chiplets, and those are exactly the chiplets whose ext
    column the generator diverted to intra traffic (all-zero ext)."""
    pats = traffic.PERMUTATION_PATTERNS
    pattern = pats[seed % len(pats)]
    cfg = NETWORK.with_topology(n_chiplets=c)
    spec = PermutationSpec(pattern=pattern, mean_load=MEAN_LOAD,
                           n_intervals=10)
    d = destination_matrix(spec, cfg)
    dst = np.asarray(permutation_destinations(pattern, c))
    self_pair = dst == np.arange(c)
    np.testing.assert_array_equal(np.diag(d) == 1.0, self_pair)
    # one-hot rows onto the partner
    np.testing.assert_array_equal(np.argmax(d, axis=1), dst)
    tr = traffic.generate(spec, jax.random.PRNGKey(seed), cfg, dest=True)
    ext = np.asarray(tr["ext_load"])
    np.testing.assert_array_equal(np.all(ext == 0.0, axis=0), self_pair)


# -- memoization -------------------------------------------------------------

def test_dest_matrices_are_memoized():
    S.clear_engine_caches()
    spec = PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD)
    a = destination_matrix(spec, NETWORK)
    b = destination_matrix(
        PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD), NETWORK)
    assert a is b, "equal (spec, cfg) keys must share one matrix"
    assert not a.flags.writeable
    j1 = destination_matrix_jax(spec, NETWORK)
    j2 = destination_matrix_jax(spec, NETWORK)
    assert j1 is j2
    assert destination_matrix.cache_info().currsize >= 1
    S.clear_engine_caches()
    assert destination_matrix.cache_info().currsize == 0
    assert destination_matrix_jax.cache_info().currsize == 0


# -- transform carry ---------------------------------------------------------

def test_concat_mixes_dest_load_weighted():
    k = jax.random.PRNGKey(5)
    a = traffic.generate(UniformSpec(mean_load=MEAN_LOAD, n_intervals=8),
                         k, dest=True)
    b = traffic.generate(
        PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD,
                        n_intervals=8), k, dest=True)
    out = traffic.concat_traces([a, b])
    d = np.asarray(out["dest"])
    assert d.shape == (NETWORK.n_chiplets,) * 2
    row = d.sum(axis=1)
    np.testing.assert_allclose(row[row > 0], 1.0, rtol=1e-5)
    with pytest.raises(ValueError, match="dest"):
        traffic.concat_traces([a, {k2: v for k2, v in b.items()
                                   if k2 != "dest"}])


def test_slice_pad_chunk_carry_dest():
    cfg9 = NETWORK.with_topology(n_chiplets=9)
    tr = traffic.generate(
        PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD,
                        n_intervals=9),
        jax.random.PRNGKey(6), cfg9, dest=True)
    sl = traffic.slice_trace(tr, 4)
    d = np.asarray(sl["dest"])
    assert d.shape == (4, 4)
    row = d.sum(axis=1)                  # renormalized after the cut
    np.testing.assert_allclose(row[row > 0], 1.0, rtol=1e-5)
    padded = traffic.pad_trace(tr, 16)
    np.testing.assert_array_equal(np.asarray(padded["dest"]),
                                  np.asarray(tr["dest"]))
    for ch in traffic.chunk_trace(tr, 4, pad=True):
        np.testing.assert_array_equal(np.asarray(ch["dest"]),
                                      np.asarray(tr["dest"]))


def test_stack_traces_demands_dest_uniformity():
    k = jax.random.PRNGKey(7)
    a = traffic.generate(UniformSpec(n_intervals=8), k, dest=True)
    b = traffic.generate(UniformSpec(n_intervals=8), k)
    with pytest.raises(ValueError, match="destination"):
        S.stack_traces([a, b])
    out = S.stack_traces([a, a])
    assert np.asarray(out["dest"]).shape == (2, 4, 4)
    S.simulate_batch([a, a], SIM)        # batched dest passes validation


def test_validate_trace_dest_errors():
    tr = traffic.generate(UniformSpec(n_intervals=6), jax.random.PRNGKey(8))
    with pytest.raises(ValueError, match="square"):
        traffic.validate_trace(dict(tr, dest=np.ones((4, 3), np.float32)))
    with pytest.raises(ValueError, match="square"):
        traffic.validate_trace(dict(tr, dest=np.ones((3, 3), np.float32)))
    with pytest.raises(ValueError, match="non-negative"):
        traffic.validate_trace(
            dict(tr, dest=-np.ones((4, 4), np.float32)))


# -- padded topology ---------------------------------------------------------

def test_padded_topology_zero_contribution_with_dest():
    """Masked chiplet columns stay exactly zero and the real columns match
    unpadded simulate when the trace carries a destination matrix."""
    cfg9 = NETWORK.with_topology(n_chiplets=9)
    tr = traffic.generate(
        PermutationSpec(pattern="transpose", mean_load=MEAN_LOAD,
                        n_intervals=12),
        jax.random.PRNGKey(9), cfg9, dest=True)
    out = S.sweep_topology(tr, SIM, n_chiplets=[4, 9])
    for i, c in enumerate([4, 9]):
        point = S.topology_point_config(SIM, n_chiplets=c)
        single = S.simulate(traffic.slice_trace(tr, c), point)
        for k in S.SUMMARY_KEYS:
            np.testing.assert_allclose(
                np.asarray(out["summary"][k][i]),
                np.asarray(single["summary"][k]),
                rtol=1e-4, atol=1e-4, err_msg=f"summary[{k}] point {i}")
        gl = np.asarray(out["records"]["gw_load"][i])
        assert np.all(gl[:, c:] == 0), f"padded lanes carried load at {c}"


def test_sweep_workload_dest_separates_patterns():
    """One compiled workload sweep, destinations resolved per lane."""
    specs = [UniformSpec(mean_load=MEAN_LOAD, n_intervals=20),
             PermutationSpec(pattern="tornado", mean_load=MEAN_LOAD,
                             n_intervals=20)]
    out = S.sweep_workload(specs, SIM, seed=0, dest=True)
    lat = np.asarray(out["summary"]["mean_latency"])
    assert lat.shape == (2,)
    assert abs(lat[1] - lat[0]) / lat[0] > 0.005, lat


# -- serve guard -------------------------------------------------------------

def test_serve_session_accepts_single_dest_rejects_batched():
    # [C, C] dest traces serve (PR 9: their own lane group per tick,
    # replay parity in tests/test_serve.py); a stacked [K, C, C] batch is
    # a sweep input, not a session, and still fails loudly.
    from repro.serve.policies import ServerPolicy
    from repro.serve.scheduler import ServeSession, SessionRequest
    tr = traffic.generate(UniformSpec(n_intervals=8), jax.random.PRNGKey(10),
                          dest=True)
    sess = ServeSession(SessionRequest(trace=tr), ServerPolicy(),
                        NETWORK.n_chiplets, now=0)
    assert sess.pending and sess.pending[0].get("dest") is not None
    batched = dict(tr, dest=np.stack([np.asarray(tr["dest"])] * 2))
    with pytest.raises(ValueError, match="batched destination"):
        ServeSession(SessionRequest(trace=batched), ServerPolicy(),
                     NETWORK.n_chiplets, now=0)
