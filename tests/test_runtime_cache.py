"""Cold-start elimination tests: persistent compilation cache wiring,
AOT-vs-jit parity per entry point, memoization, warmup, and the
cross-process round-trip (compile in one process, serve the next
process's first dispatch from the serialized executable on disk).
"""
from __future__ import annotations

import json
import logging
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core import traffic
from repro.core.simulator import Arch, SimConfig
from repro.runtime import cache as rcache

REPO = Path(__file__).resolve().parent.parent


def _sim() -> SimConfig:
    return SimConfig().with_arch(Arch.RESIPI)


def _trace(sim, n=8, seed=0, cfg=None):
    return traffic.generate(traffic.UniformSpec(n_intervals=n),
                            jax.random.PRNGKey(seed), cfg or sim.cfg)


def _assert_tree_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture
def cache_tmp(tmp_path):
    """Point the persistent cache at a throwaway dir, restore after."""
    prev = rcache.cache_dir()
    rcache.clear_aot_cache()    # earlier tests' memos would skip persisting
    try:
        yield rcache.enable_persistent_cache(tmp_path / "jax-cache")
    finally:
        rcache._CACHE["dir"] = prev
        jax.config.update("jax_compilation_cache_dir",
                          str(prev) if prev is not None else None)
        rcache.clear_aot_cache()


# ---------------------------------------------------------------------------
# Persistent cache wiring
# ---------------------------------------------------------------------------

def test_enable_persistent_cache_creates_dir_and_reports(cache_tmp):
    assert cache_tmp.is_dir()
    assert rcache.cache_dir() == cache_tmp
    stats = rcache.persistent_cache_stats()
    assert stats["enabled"] and stats["dir"] == str(cache_tmp)


def test_persistent_cache_stats_disabled_default(tmp_path):
    stats = rcache.persistent_cache_stats(tmp_path / "nope")
    assert stats["entries"] == 0 and stats["bytes"] == 0


# ---------------------------------------------------------------------------
# AOT-vs-jit parity (the AotEntry contract: same inputs, same bits)
# ---------------------------------------------------------------------------

def test_aot_simulate_matches_jit():
    sim = _sim()
    tr = _trace(sim)
    exe = rcache.aot_compile("simulate", tr, sim)
    _assert_tree_equal(exe(tr, sim)["summary"],
                       S.simulate(tr, sim)["summary"])


def test_aot_sweep_matches_jit():
    sim = _sim()
    tr = _trace(sim)
    exe = rcache.aot_compile("sweep", tr, sim, l_m=[0.01, 0.02])
    _assert_tree_equal(exe(tr, sim, l_m=[0.01, 0.02])["summary"],
                       S.sweep(tr, sim, l_m=[0.01, 0.02])["summary"])


def test_aot_sweep_topology_matches_jit():
    sim = _sim()
    tr = _trace(sim, cfg=sim.cfg.with_topology(n_chiplets=9))
    exe = rcache.aot_compile("sweep_topology", tr, sim, n_chiplets=[4, 9])
    _assert_tree_equal(exe(tr, sim, n_chiplets=[4, 9])["summary"],
                       S.sweep_topology(tr, sim, n_chiplets=[4, 9])["summary"])


def test_aot_session_tick_matches_jit():
    sim = _sim()
    tr = _trace(sim)
    states = S.init_session_states(sim, 1)
    ext = np.asarray(tr["ext_load"], np.float32)[None]
    batch = {"ext_load": ext,
             "mem_load": np.asarray(tr["mem_load"], np.float32)[None],
             "int_load": np.asarray(tr["int_load"], np.float32)[None],
             "ext_frac": np.asarray([tr["ext_frac"]], np.float32),
             "t_mask": np.ones(ext.shape[:2], np.float32)}
    tables = S.selection_tables_jax(sim.cfg)
    exe = rcache.aot_compile("session_tick", states, batch, tables, sim)
    _assert_tree_equal(exe(states, batch, tables, sim),
                       S.session_tick(states, batch, tables, sim))


def test_aot_search_matches_jit():
    from repro.core import pareto

    sim = _sim()
    tr = _trace(sim, cfg=sim.cfg.with_topology(n_chiplets=9))
    kw = dict(n_chiplets=[4, 9], islands=2, generations=2, population=2,
              archive=8, seed=5)
    exe = rcache.aot_compile("search", tr, sim, **kw)
    built, statics, _ = pareto._codesign_operands(tr, sim, **kw)
    _assert_tree_equal(exe(tr, sim, **kw),
                       pareto._codesign_jit(*built, **statics))
    assert exe is rcache.aot_compile("search", tr, sim, **kw)  # memo hit
    assert "search" in rcache.AOT_ENTRY_POINTS


def test_warmup_search_entry_runs():
    sim = _sim()
    walls = rcache.warmup(sim, n_intervals=4, entries=("search",),
                          grids={"n_chiplets": [sim.cfg.n_chiplets]})
    assert walls["search"] > 0.0


def test_aot_memoizes_on_config_and_shapes():
    sim = _sim()
    tr = _trace(sim)
    a = rcache.aot_compile("simulate", tr, sim)
    b = rcache.aot_compile("simulate", _trace(sim, seed=3), sim)
    assert a is b                       # same shapes: cached handle
    c = rcache.aot_compile("simulate", _trace(sim, n=12), sim)
    assert c is not a                   # new trace length: new executable
    assert rcache.aot_cache_stats()["by_entry"]["simulate"] >= 2


def test_aot_unknown_entry_raises():
    with pytest.raises(ValueError, match="unknown AOT entry"):
        rcache.aot_compile("nope", None, _sim())


# ---------------------------------------------------------------------------
# Warmup
# ---------------------------------------------------------------------------

def test_warmup_runs_every_entry_point():
    sim = _sim()
    walls = rcache.warmup(
        sim, n_intervals=8,
        entries=("simulate", "sweep", "sweep_topology", "session_tick"))
    assert set(walls) == {"simulate", "sweep", "sweep_topology",
                          "session_tick"}
    assert all(w > 0.0 for w in walls.values())


def test_warmup_unknown_entry_raises():
    with pytest.raises(ValueError, match="unknown warmup entry"):
        rcache.warmup(_sim(), entries=("bogus",))


# ---------------------------------------------------------------------------
# Serialized-executable round-trips
# ---------------------------------------------------------------------------

def test_aot_serialized_roundtrip_in_process(cache_tmp, caplog):
    sim = _sim()
    tr = _trace(sim)
    caplog.set_level(logging.INFO, logger="repro.runtime.cache")
    ref = rcache.aot_compile("simulate", tr, sim)(tr, sim)
    files = list((cache_tmp / "aot").glob("*.bin"))
    assert len(files) == 1 and files[0].name.startswith("simulate-")
    rcache.clear_aot_cache()            # drop the memo, keep the disk blob
    caplog.clear()
    out = rcache.aot_compile("simulate", tr, sim)(tr, sim)
    assert any("AOT-loaded" in r.message for r in caplog.records)
    _assert_tree_equal(out["summary"], ref["summary"])


def test_stale_aot_blob_falls_back_to_recompile(cache_tmp, caplog):
    sim = _sim()
    tr = _trace(sim)
    caplog.set_level(logging.INFO, logger="repro.runtime.cache")
    rcache.aot_compile("simulate", tr, sim)
    (path,) = (cache_tmp / "aot").glob("*.bin")
    path.write_bytes(b"not a serialized executable")
    rcache.clear_aot_cache()
    out = rcache.aot_compile("simulate", tr, sim)(tr, sim)
    assert any("recompiling" in r.message for r in caplog.records)
    _assert_tree_equal(out["summary"], S.simulate(tr, sim)["summary"])


_CHILD = r"""
import json, logging, pathlib, sys
import jax, numpy as np
from repro.core import traffic
from repro.core import simulator as S
from repro.core.simulator import Arch, SimConfig
from repro.runtime import cache as rcache

msgs = []
h = logging.Handler()
h.emit = lambda rec: msgs.append(rec.getMessage())
logging.getLogger("repro.runtime.cache").addHandler(h)
logging.getLogger("repro.runtime.cache").setLevel(logging.INFO)

cache_dir = pathlib.Path(sys.argv[1])
rcache.enable_persistent_cache(cache_dir)
sim = SimConfig().with_arch(Arch.RESIPI)
tr = traffic.generate(traffic.UniformSpec(n_intervals=8),
                      jax.random.PRNGKey(0), sim.cfg)
exe = rcache.aot_compile("simulate", tr, sim)
out = exe(tr, sim)
print("RESULT " + json.dumps({
    "mean_latency": float(out["summary"]["mean_latency"]),
    "loaded": any(m.startswith("AOT-loaded") for m in msgs),
    "aot_files": len(list((cache_dir / "aot").glob("*.bin")))}))
"""


def _run_child(cache_dir):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", _CHILD, str(cache_dir)],
                          cwd=REPO, env=env, timeout=600,
                          capture_output=True, text=True)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_persistent_cache_cross_process_roundtrip(tmp_path):
    # Process 1 compiles + persists; process 2's first aot_compile serves
    # the serialized executable from disk (no tracing, no XLA) and
    # bit-matches. This is the fleet workers' warm-start contract.
    cache = tmp_path / "shared-cache"
    first = _run_child(cache)
    assert not first["loaded"] and first["aot_files"] == 1
    second = _run_child(cache)
    assert second["loaded"] and second["aot_files"] == 1
    assert second["mean_latency"] == first["mean_latency"]
